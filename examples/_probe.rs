use bts::cachesim::*;
fn main() {
    for (name, mk) in [("eaglet", 0), ("nf_hi", 1), ("nf_lo", 2)] {
        println!("-- {name}");
        for kb in [256, 512, 1024, 2048, 4096, 8192, 16384, 32768] {
            let cfg = match mk {
                0 => TraceConfig::eaglet(kb * 1024),
                1 => TraceConfig::netflix(kb * 1024, 0.5),
                _ => TraceConfig::netflix(kb * 1024, 0.0625),
            };
            let mut h = Hierarchy::new(CacheConfig::sandy_bridge());
            run_task_trace(&cfg, &mut h);
            println!("{kb:6} KB  l2mpi={:.6}  l3mpi={:.6}  amat={:.1}", h.l2_mpi(), h.l3_mpi(), h.amat());
        }
    }
}
