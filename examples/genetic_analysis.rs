//! Genetic-analysis scenario (the thesis's EAGLET workload, §4.1.1.1):
//! profile the task-size → miss-rate curve offline, size tasks at the
//! kneepoint, and compare the three BashReduce configurations on the
//! real platform — with and without the study's outlier families.
//!
//!     make artifacts && cargo run --release --example genetic_analysis

use std::sync::Arc;

use bts::cachesim::CacheConfig;
use bts::coordinator::{run_job, JobConfig};
use bts::data::eaglet::{EagletConfig, EagletDataset};
use bts::data::{Dataset, Workload};
use bts::kneepoint::{kneepoint_bytes, TaskSizing};
use bts::runtime::Manifest;

fn main() -> bts::Result<()> {
    // Needs `make artifacts` (PJRT path); see examples/end_to_end.rs
    // for the artifact-free executor.
    let manifest = Arc::new(Manifest::load_default()?);

    // Offline step (thesis Fig 3): find the kneepoint for this workload
    // on the reference cache geometry.
    let knee = kneepoint_bytes(Workload::Eaglet, &CacheConfig::sandy_bridge());
    println!(
        "offline kneepoint: {:.2} MB (thesis: 2.5 MB on Sandy Bridge)\n",
        knee as f64 / (1024.0 * 1024.0)
    );

    let full = EagletDataset::generate(
        &manifest.params,
        EagletConfig { families: 200, ..Default::default() },
    );
    let clean = full.without_outliers();

    // Warm the executor pool (compile every bucket once) so the table
    // measures steady-state platform behaviour, not first-touch compile.
    let _ = run_job(
        &full,
        manifest.clone(),
        &JobConfig { sizing: TaskSizing::Tiniest, workers: 4, ..Default::default() },
    )?;

    println!(
        "{:14} {:12} {:>8} {:>9} {:>10} {:>9}",
        "dataset", "sizing", "tasks", "total s", "MB/s", "hit rate"
    );
    for (ds, tag) in [(&full, "with outliers"), (&clean, "no outliers")] {
        for (sizing, name) in [
            (TaskSizing::Kneepoint(knee.min(256 * 1024)), "kneepoint"),
            (TaskSizing::LargeSn { workers: 4 }, "large(Sn)"),
            (TaskSizing::Tiniest, "tiniest"),
        ] {
            let cfg = JobConfig { sizing, workers: 4, ..Default::default() };
            let r = run_job(ds, manifest.clone(), &cfg)?;
            println!(
                "{tag:14} {name:12} {:>8} {:>9.3} {:>10.2} {:>8.0}%",
                r.report.tasks,
                r.report.total_s,
                r.report.throughput_mbs(),
                r.report.prefetch_hit_rate * 100.0,
            );
        }
    }
    println!(
        "\n(total MB here is the synthetic stand-in's size — the paper's \
         ratios\ncome from the simulated testbed; see `bts repro --only \
         fig4,fig8`)"
    );
    let _ = full.total_bytes();
    Ok(())
}
