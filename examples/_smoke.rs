use bts::runtime::{HostTensor, Manifest, Runtime};
use std::sync::Arc;
fn main() {
    let m = Arc::new(Manifest::load("artifacts").unwrap());
    let rt = Runtime::new(m.clone()).unwrap();
    let p = &m.params;
    let e = m.entry("eaglet_map", 1).unwrap().clone();
    let geno = HostTensor::F32(vec![0.5; p.markers * p.individuals], vec![1, p.markers, p.individuals]);
    let pos = HostTensor::F32((0..p.markers).map(|i| i as f32 / p.markers as f32).collect(), vec![1, p.markers]);
    let idx = HostTensor::I32((0..(p.rounds * p.subsample) as i32).map(|i| i % p.markers as i32).collect(), vec![p.rounds, p.subsample]);
    let grid = HostTensor::F32((0..p.grid).map(|i| i as f32 / p.grid as f32).collect(), vec![p.grid]);
    let out = rt.execute(&e, &[geno, pos, idx, grid]).unwrap();
    println!("eaglet map out: {} tensors, first len {} vals {:?}", out.len(), out[0].len(), &out[0][..4]);
    let e2 = m.entry("netflix_reduce", 16).unwrap().clone();
    let parts = HostTensor::F32(vec![1.0; 16*12*3], vec![16,12,3]);
    let out2 = rt.execute(&e2, &[parts]).unwrap();
    println!("netflix reduce: {:?}", &out2[0][..6]);
}
