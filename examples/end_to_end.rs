//! End-to-end validation driver (DESIGN.md §validation): exercises every
//! layer of the system on real small workloads and reports the paper's
//! headline metric. This is the run recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example end_to_end
//!
//! Covered, in order:
//!   1. offline kneepoint profiling (cache simulator)
//!   2. real EAGLET + Netflix jobs through pack → two-step scheduler →
//!      replicated store (adaptive RF, prefetch) → PJRT map → shuffle →
//!      PJRT reduce, across all three sizing policies
//!   3. monitoring on/off overhead (the §4.2.2 experiment)
//!   4. injected node failure → job-level recovery → bit-identical result
//!   5. distributed mode: the same job over TCP leader/workers
//!   6. throughput headline (Mb/s per 12-core-node-equivalent)

use std::net::TcpListener;
use std::sync::Arc;

use bts::cachesim::CacheConfig;
use bts::coordinator::{
    run_job, run_with_recovery, FailurePlan, JobConfig,
};
use bts::data::Workload;
use bts::dfs::LatencyModel;
use bts::kneepoint::{kneepoint_bytes, TaskSizing};
use bts::net::{run_worker, serve_job};
use bts::runtime::Manifest;
use bts::workloads::build_small;

fn main() -> anyhow::Result<()> {
    let manifest = Arc::new(Manifest::load_default()?);
    let cache = CacheConfig::sandy_bridge();
    println!("=== 1. offline kneepoint profiling ===");
    let mut knees = std::collections::HashMap::new();
    for w in [Workload::Eaglet, Workload::NetflixHi, Workload::NetflixLo] {
        let k = kneepoint_bytes(w, &cache);
        println!("  {:12} kneepoint {:.2} MB", w.name(), k as f64 / 1048576.0);
        knees.insert(w, k);
    }

    println!("\n=== 2. real jobs, all sizing policies ===");
    println!(
        "  {:12} {:10} {:>7} {:>9} {:>9} {:>8} {:>4}",
        "workload", "sizing", "tasks", "total s", "MB/s", "hit%", "rf"
    );
    let mut eaglet_total_mb_s = 0.0;
    for (w, samples) in [
        (Workload::Eaglet, 120usize),
        (Workload::NetflixHi, 300),
        (Workload::NetflixLo, 300),
    ] {
        let ds = build_small(w, &manifest.params, samples);
        for (sizing, name) in [
            (TaskSizing::Kneepoint(knees[&w].min(256 * 1024)), "kneepoint"),
            (TaskSizing::LargeSn { workers: 4 }, "large"),
            (TaskSizing::Tiniest, "tiniest"),
        ] {
            let cfg = JobConfig {
                sizing,
                workers: 4,
                data_nodes: 6,
                latency: LatencyModel::lan(),
                ..Default::default()
            };
            let r = run_job(ds.as_ref(), manifest.clone(), &cfg)?;
            println!(
                "  {:12} {:10} {:>7} {:>9.3} {:>9.2} {:>7.0}% {:>4}",
                w.name(),
                name,
                r.report.tasks,
                r.report.total_s,
                r.report.throughput_mbs(),
                r.report.prefetch_hit_rate * 100.0,
                r.report.final_rf,
            );
            if w == Workload::Eaglet && name == "kneepoint" {
                eaglet_total_mb_s = r.report.throughput_mbs();
            }
        }
    }

    println!("\n=== 3. monitoring overhead (§4.2.2) ===");
    let ds = build_small(Workload::Eaglet, &manifest.params, 120);
    let mut times = Vec::new();
    for monitoring in [false, true] {
        let cfg = JobConfig {
            sizing: TaskSizing::Tiniest,
            workers: 4,
            monitoring,
            ..Default::default()
        };
        let r = run_job(ds.as_ref(), manifest.clone(), &cfg)?;
        println!(
            "  monitoring={:5} total {:.3}s startup {:.3}s ({} records)",
            monitoring, r.report.total_s, r.report.startup_s, r.monitor_records
        );
        times.push(r.report.total_s);
    }
    println!(
        "  measured monitoring slowdown: {:+.1}% (paper: +21% startup on \
         MB jobs, +15% runtime on GB jobs on its testbed)",
        (times[1] / times[0] - 1.0) * 100.0
    );

    println!("\n=== 4. job-level recovery ===");
    let clean = run_job(
        ds.as_ref(),
        manifest.clone(),
        &JobConfig { sizing: TaskSizing::Tiniest, workers: 3, ..Default::default() },
    )?;
    let mut cfg = JobConfig {
        sizing: TaskSizing::Tiniest,
        workers: 3,
        ..Default::default()
    };
    cfg.failure =
        Some(FailurePlan { worker: 1, after_tasks: 2, on_attempt: 1 });
    let recovered = run_with_recovery(ds.as_ref(), manifest.clone(), &cfg, 3)?;
    println!(
        "  worker 1 killed after 2 tasks → {} restart(s); result identical: {}",
        recovered.report.restarts,
        recovered.output == clean.output
    );
    assert_eq!(recovered.output, clean.output);

    println!("\n=== 5. distributed mode (TCP leader + 2 workers) ===");
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let report = std::thread::scope(|sc| {
        for w in 0..2u32 {
            let addr = addr.clone();
            let m = manifest.clone();
            sc.spawn(move || run_worker(&addr, w, m).unwrap());
        }
        serve_job(
            listener,
            ds.as_ref(),
            manifest.clone(),
            TaskSizing::Kneepoint(knees[&Workload::Eaglet].min(256 * 1024)),
            2,
            0xB75,
        )
        .unwrap()
    });
    println!(
        "  {} tasks over TCP in {:.3}s ({:.2} MB shipped); result matches \
         in-process: {}",
        report.tasks,
        report.total_s,
        report.bytes_shipped as f64 / 1048576.0,
        {
            let local = run_job(
                ds.as_ref(),
                manifest.clone(),
                &JobConfig {
                    sizing: TaskSizing::Kneepoint(
                        knees[&Workload::Eaglet].min(256 * 1024),
                    ),
                    workers: 2,
                    seed: 0xB75,
                    ..Default::default()
                },
            )
            .unwrap();
            report.output == local.output
        }
    );

    println!("\n=== 6. headline ===");
    println!(
        "  EAGLET kneepoint throughput on 4 worker threads: {:.1} MB/s \
         ({:.0} Mb/s)\n  (paper: 117 Mb/s per 12-core node on its legacy \
         pipeline — our kernel is\n  ~80x lighter, so absolute Mb/s and the \
         sizing margins are not directly\n  comparable at this scale; the \
         paper-scale sizing ratios are carried by\n  the calibrated \
         simulator: `bts repro --only fig4,fig8`)",
        eaglet_total_mb_s,
        eaglet_total_mb_s * 8.0
    );
    println!("\nall layers verified ✔");
    Ok(())
}
