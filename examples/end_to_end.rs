//! End-to-end validation driver (DESIGN.md §7): drive real multi-
//! threaded jobs through the `exec` cluster executor on both thesis
//! workloads and report the metrics the platform is graded on —
//! per-task latency and scheduler overhead.
//!
//!     cargo run --release --example end_to_end
//!
//! Runs on any host: `Backend::auto()` executes through compiled PJRT
//! artifacts when they exist and work, and through the pure-rust
//! kernel backend otherwise. Covered, in order:
//!
//!   1. offline kneepoint profiling (cache simulator)
//!   2. EAGLET + Netflix (hi and lo confidence) jobs through
//!      pack → leader/worker channels → two-step scheduler →
//!      replicated store (adaptive RF, prefetch) → map kernels →
//!      shuffle → reduce tree, under kneepoint and tiniest sizing
//!   3. determinism: worker count must not change the statistic
//!   4. injected node failure → job-level recovery → identical result
//!   5. metrics baseline written to results/exec_baseline.json
//!      (the format future BENCH_*.json trajectory entries follow)

use std::sync::Arc;

use bts::cachesim::CacheConfig;
use bts::coordinator::{FailurePlan, JobOutput};
use bts::data::Workload;
use bts::dfs::LatencyModel;
use bts::exec::{run_cluster, run_cluster_with_recovery, Backend, ExecConfig};
use bts::kneepoint::{kneepoint_bytes, TaskSizing};
use bts::runtime::Exec as _;
use bts::workloads::build_small;

fn main() -> bts::Result<()> {
    let backend = Arc::new(Backend::auto());
    let params = backend.manifest().params.clone();
    println!(
        "=== end-to-end: in-process cluster executor (backend: {}) ===",
        backend.name()
    );

    println!("\n--- 1. offline kneepoint profiling ---");
    let cache = CacheConfig::sandy_bridge();
    let mut knees = std::collections::HashMap::new();
    for w in [Workload::Eaglet, Workload::NetflixHi, Workload::NetflixLo] {
        let k = kneepoint_bytes(w, &cache);
        println!("  {:12} kneepoint {:.2} MB", w.name(), k as f64 / 1048576.0);
        knees.insert(w, k);
    }

    println!("\n--- 2. jobs on 4 worker threads, per-task latency + scheduler overhead ---");
    println!(
        "  {:12} {:10} {:>6} {:>8} {:>8} {:>10} {:>10} {:>11} {:>11}",
        "workload",
        "sizing",
        "tasks",
        "total s",
        "MB/s",
        "exec p50",
        "exec p95",
        "dispatch/t",
        "qwait p50"
    );
    let mut baselines = Vec::new();
    for (w, samples) in [
        (Workload::Eaglet, 120usize),
        (Workload::NetflixHi, 300),
        (Workload::NetflixLo, 300),
    ] {
        let ds = build_small(w, &params, samples);
        for (sizing, name) in [
            (TaskSizing::Kneepoint(knees[&w].min(256 * 1024)), "kneepoint"),
            (TaskSizing::Tiniest, "tiniest"),
        ] {
            let cfg = ExecConfig {
                sizing,
                workers: 4,
                data_nodes: 6,
                latency: LatencyModel::lan(),
                ..Default::default()
            };
            let r = run_cluster(ds.as_ref(), backend.clone(), &cfg)?;
            let dispatch_per_task_us = if r.report.tasks == 0 {
                0.0
            } else {
                r.overhead.dispatch_s / r.report.tasks as f64 * 1e6
            };
            println!(
                "  {:12} {:10} {:>6} {:>8.3} {:>8.2} {:>8.2}ms {:>8.2}ms {:>9.1}µs {:>9.2}ms",
                w.name(),
                name,
                r.report.tasks,
                r.report.total_s,
                r.report.throughput_mbs(),
                r.report.task_exec.p50 * 1e3,
                r.report.task_exec.p95 * 1e3,
                dispatch_per_task_us,
                r.overhead.queue_wait.p50 * 1e3,
            );
            if name == "kneepoint" {
                baselines.push(r.metrics_json());
            }
        }
    }

    println!("\n--- 3. determinism across parallelism ---");
    let ds = build_small(Workload::Eaglet, &params, 60);
    let base = ExecConfig { sizing: TaskSizing::Tiniest, ..Default::default() };
    let r1 = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig { workers: 1, ..base.clone() },
    )?;
    let r4 = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig { workers: 4, ..base.clone() },
    )?;
    assert_eq!(r1.output, r4.output, "parallelism changed the statistic");
    println!("  1-worker and 4-worker runs produced identical output ✔");

    println!("\n--- 4. job-level recovery ---");
    let clean = run_cluster(
        ds.as_ref(),
        backend.clone(),
        &ExecConfig { workers: 3, ..base.clone() },
    )?;
    let mut failing = ExecConfig { workers: 3, ..base.clone() };
    failing.failure =
        Some(FailurePlan { worker: 1, after_tasks: 2, on_attempt: 1 });
    let recovered =
        run_cluster_with_recovery(ds.as_ref(), backend.clone(), &failing, 3)?;
    assert_eq!(recovered.output, clean.output);
    println!(
        "  worker 1 killed after 2 tasks → {} restart(s); result identical ✔",
        recovered.report.restarts
    );
    if let JobOutput::Eaglet { alod, weight } = &clean.output {
        let peak = alod
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        println!(
            "  ALOD over {weight} chunks peaks at grid {} ({:.3})",
            peak.0, peak.1
        );
    }

    println!("\n--- 5. metrics baseline ---");
    let j = bts::util::json::arr(baselines);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/exec_baseline.json", j.to_string_pretty())?;
    println!("  wrote results/exec_baseline.json (BENCH_*.json record format)");
    println!("\nall layers verified ✔");
    Ok(())
}
