//! Sustained-load smoke driver for the serve layer (DESIGN.md §9):
//! start the long-lived multi-tenant service, push a mixed
//! EAGLET/Netflix job set through it with Poisson arrivals, and hold
//! it to the warm-pool contract. CI runs this on every push:
//!
//!     cargo run --release --example serve_load -- --jobs 6 --workers 4
//!
//! Hard assertions (nonzero exit on violation):
//!   1. every admitted job completes and reduces;
//!   2. zero worker respawns — the pool spawned exactly `--workers`
//!      threads for the entire session;
//!   3. at least one deadline-infeasible submission was rejected at
//!      admission (the SLO gate actually fired);
//!   4. a spot-checked job is bit-identical to the same request run
//!      solo through `exec::run_cluster`;
//!   5. results/BENCH_serve.json is written with the latency
//!      percentiles in the baseline record format.

use std::sync::Arc;

use bts::exec::{run_cluster, Backend, ExecConfig};
use bts::runtime::Exec as _;
use bts::serve::{mixed_request, run_load, LoadConfig};

fn main() -> bts::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // Same strict contract as the bts CLI (shared parser): both flag
    // spellings accepted, unknown flags are errors.
    let f = bts::util::cli::Flags::parse(
        &args,
        &["--jobs", "--workers", "--max-active"],
    )?;
    let cfg = LoadConfig {
        jobs: f.num("--jobs", 20)?,
        workers: f.num("--workers", 4)?,
        max_active: f.num("--max-active", 4)?,
        ..Default::default()
    };
    let backend = Arc::new(Backend::auto());
    println!(
        "=== serve load: {} mixed jobs over {} warm workers (backend: {}) ===",
        cfg.jobs,
        cfg.workers,
        backend.name()
    );

    let out = run_load(backend.clone(), &cfg)?;
    for r in &out.results {
        println!("  {}", r.render_row());
    }
    println!("{}", out.report.render());

    // 1. every admitted job completed
    assert_eq!(
        out.report.jobs_completed + out.report.jobs_rejected as usize,
        cfg.jobs,
        "admitted jobs must all complete ({} failed)",
        out.report.jobs_failed
    );
    assert_eq!(out.report.jobs_failed, 0);

    // 2. the pool stayed warm: no respawns, ever
    assert_eq!(
        out.report.workers_spawned, cfg.workers,
        "pool must spawn exactly once"
    );
    assert_eq!(out.report.worker_respawns(), 0);
    let executed: u64 = out.report.worker_executed.iter().sum();
    assert_eq!(
        executed, out.report.tasks_total,
        "warm workers must have executed every task"
    );
    println!(
        "  warm pool ✔ ({} workers spawned once, {} tasks across {} jobs)",
        out.report.workers_spawned,
        executed,
        out.report.jobs_completed
    );

    // 3. the admission gate fired on the infeasible slice (which only
    //    exists once the mix is long enough to contain it)
    if cfg.infeasible_every > 0 && cfg.jobs >= cfg.infeasible_every {
        assert!(
            out.report.jobs_rejected >= 1,
            "expected at least one deadline-infeasible rejection"
        );
        println!(
            "  admission gate ✔ ({} rejected at the door)",
            out.report.jobs_rejected
        );
    } else {
        println!(
            "  admission gate untested (needs --jobs >= {})",
            cfg.infeasible_every
        );
    }

    // 4. multiplexed == solo, bit for bit (spot-check job index 0)
    if cfg.jobs > 0 {
        let req = mixed_request(&cfg, 0);
        let params = backend.manifest().params.clone();
        let ds =
            bts::workloads::build_small(req.workload, &params, req.samples);
        let solo = run_cluster(
            ds.as_ref(),
            backend,
            &ExecConfig {
                sizing: req.sizing,
                seed: req.seed,
                ..Default::default()
            },
        )?;
        let served = out
            .results
            .iter()
            .find(|r| r.id == 1) // ids are 1-based in submission order
            .expect("job 0 (id 1) completed");
        assert_eq!(
            served.output, solo.output,
            "multiplexed job must equal its solo run bit-for-bit"
        );
        println!(
            "  determinism ✔ (served output == solo run_cluster output)"
        );
    }

    // 5. the perf-trail record
    let path = bts::util::bench_record::write(
        "serve",
        vec![out.report.metrics_json()],
    )?;
    let back = bts::util::json::Json::parse(&std::fs::read_to_string(&path)?)
        .map_err(bts::Error::Json)?;
    let rec = match &back {
        bts::util::json::Json::Arr(v) => &v[0],
        _ => panic!("BENCH_serve.json must be a record array"),
    };
    for field in
        ["queue_wait_p50_s", "e2e_p95_s", "tasks_per_s", "worker_respawns"]
    {
        rec.req_f64(field).map_err(bts::Error::Json)?;
    }
    println!("  wrote {path} (queue-wait/latency/throughput percentiles) ✔");
    println!("\nserve load OK");
    Ok(())
}
