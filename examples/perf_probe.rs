//! Perf probe (§Perf in EXPERIMENTS.md): steady-state job timing
//! breakdown for a tiny-task and a kneepoint job on the real engine.

use std::sync::Arc;
use bts::coordinator::{run_job, JobConfig};
use bts::kneepoint::TaskSizing;
use bts::runtime::Manifest;
use bts::workloads::build_small;
use bts::data::Workload;
fn main() {
    let m = Arc::new(Manifest::load_default().unwrap());
    for (w, n) in [(Workload::Eaglet, 400usize), (Workload::NetflixLo, 2000)] {
        for (sizing, name) in [
            (TaskSizing::Tiniest, "tiniest"),
            (TaskSizing::Kneepoint(256 * 1024), "knee256k"),
        ] {
            let cfg = JobConfig { sizing, workers: 4, ..Default::default() };
            let ds = build_small(w, &m.params, n);
            let _warm = run_job(ds.as_ref(), m.clone(), &cfg).unwrap();
            let t = std::time::Instant::now();
            let r = run_job(ds.as_ref(), m.clone(), &cfg).unwrap();
            let wall = t.elapsed().as_secs_f64();
            println!(
                "{:11} {:9} wall {:.3}s | startup {:.3} map {:.3} reduce {:.3} | tasks {} exec p50 {:.2}ms p95 {:.2}ms | fetch p50 {:.3}ms | tput {:.2} MB/s",
                w.name(), name, wall, r.report.startup_s, r.report.map_s, r.report.reduce_s,
                r.report.tasks, r.report.task_exec.p50*1e3, r.report.task_exec.p95*1e3,
                r.report.task_fetch.p50*1e3, r.report.throughput_mbs());
        }
    }
}
