//! Quickstart: run one subsampling job end to end on the BTS platform.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Builds a small synthetic EAGLET dataset, packs it into kneepoint-
//! sized tiny tasks, runs them through the scheduler + replicated store
//! + PJRT runtime, and prints the final ALOD statistic.

use std::sync::Arc;

use bts::coordinator::{run_with_recovery, JobConfig, JobOutput};
use bts::data::eaglet::{EagletConfig, EagletDataset};
use bts::kneepoint::TaskSizing;
use bts::runtime::Manifest;

fn main() -> bts::Result<()> {
    // 1. Load the AOT artifacts (HLO text compiled once by `make
    //    artifacts`; Python never runs from here on). Without them this
    //    exits with a clear message — `examples/end_to_end.rs` runs the
    //    same pipeline through the artifact-free native backend.
    let manifest = Arc::new(Manifest::load_default()?);

    // 2. A small family-linkage dataset (synthetic stand-in for the
    //    thesis's bi-polar SNP study — heavy-tailed, outliers included).
    let dataset = EagletDataset::generate(
        &manifest.params,
        EagletConfig { families: 60, ..Default::default() },
    );

    // 3. Configure the job: kneepoint task sizing, 4 map slots.
    let cfg = JobConfig {
        sizing: TaskSizing::Kneepoint(64 * 1024),
        workers: 4,
        ..Default::default()
    };

    // 4. Run with job-level recovery (the platform's §3.3 policy).
    let result = run_with_recovery(&dataset, manifest, &cfg, 3)?;
    println!("{}", result.report.render());

    let JobOutput::Eaglet { alod, weight } = &result.output else {
        unreachable!("eaglet dataset produces an eaglet output")
    };
    println!("\nALOD over {weight} chunks (peak marks the linked region):");
    for (i, v) in alod.iter().enumerate() {
        let bar = "#".repeat((v.clamp(0.0, 40.0) * 1.5) as usize);
        println!("  grid {i:2} {v:7.3} {bar}");
    }
    Ok(())
}
