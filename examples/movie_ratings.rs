//! E-commerce scenario (the thesis's Netflix workload, §4.1.1.2):
//! estimate per-month mean ratings from subsamples at two confidence
//! levels and show the speed/accuracy trade subsampling buys.
//!
//!     make artifacts && cargo run --release --example movie_ratings

use std::sync::Arc;

use bts::coordinator::{run_job, JobConfig, JobOutput};
use bts::data::netflix::{NetflixConfig, NetflixDataset};
use bts::kneepoint::TaskSizing;
use bts::runtime::Manifest;

fn main() -> bts::Result<()> {
    // Needs `make artifacts` (PJRT path); see examples/end_to_end.rs
    // for the artifact-free executor.
    let manifest = Arc::new(Manifest::load_default()?);
    let mut results = Vec::new();
    for hi in [true, false] {
        let ds = NetflixDataset::generate(
            &manifest.params,
            NetflixConfig {
                movies: 500,
                high_confidence: hi,
                ..Default::default()
            },
        );
        let cfg = JobConfig {
            sizing: TaskSizing::Kneepoint(1024 * 1024), // the thesis's 1 MB
            workers: 4,
            ..Default::default()
        };
        let r = run_job(&ds, manifest.clone(), &cfg)?;
        let JobOutput::Netflix(stats) = r.output.clone() else {
            unreachable!()
        };
        println!(
            "{} confidence: {} tasks in {:.3}s ({:.1} MB/s)",
            if hi { "high" } else { "low " },
            r.report.tasks,
            r.report.total_s,
            r.report.throughput_mbs()
        );
        results.push((hi, stats, r.report.total_s));
    }

    println!(
        "\n{:>5} {:>12} {:>12} {:>14} {:>14}",
        "month", "mean (hi)", "mean (lo)", "95% CI (hi)", "95% CI (lo)"
    );
    let (h, l) = (&results[0].1, &results[1].1);
    for m in 0..h.mean.len() {
        println!(
            "{m:>5} {:>12.3} {:>12.3} {:>14.3} {:>14.3}",
            h.mean[m], l.mean[m], h.ci_half[m], l.ci_half[m]
        );
    }
    let mean_ci = |s: &bts::coordinator::NetflixStats| {
        s.ci_half.iter().filter(|v| v.is_finite()).sum::<f64>()
            / s.ci_half.iter().filter(|v| v.is_finite()).count().max(1) as f64
    };
    println!(
        "\nlow confidence subsamples {}x fewer ratings; its CI is {:.1}x \
         wider\n(the thesis's trade: \"choosing less speedup and more \
         accuracy\")",
        manifest.params.s_hi / manifest.params.s_lo,
        mean_ci(l) / mean_ci(h),
    );
    Ok(())
}
