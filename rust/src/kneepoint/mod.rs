//! Task sizing — the thesis's first contribution (§3.2.1, Fig 3).
//!
//! Offline: `profiler` builds the task-size → miss-rate curve on the
//! cache-simulator "benchmarking node"; `detector` finds the smallest
//! kneepoint. Online: `packing` groups samples into kneepoint-sized
//! tasks before map tasks start.

pub mod detector;
pub mod packing;
pub mod profiler;

pub use detector::{kneepoints, smallest_kneepoint, CurvePoint};
pub use packing::{max_multi_sample_bytes, pack, PackedTask, TaskSizing};
pub use profiler::{default_sizes, profile_workload, Profile, ProfileCache, ProfilePoint};

use crate::cachesim::CacheConfig;
use crate::data::Workload;

/// Default knee elasticity threshold (see detector.rs module docs).
pub const KNEE_THRESHOLD: f64 = 0.8;

/// One-call convenience: offline-profile `workload` on `cache` and return
/// the kneepoint task size in bytes (what BTS configures per §4.1.3:
/// "BTS sets task size to 2.5 MB for EAGLET and 1 MB for Netflix").
pub fn kneepoint_bytes(workload: Workload, cache: &CacheConfig) -> usize {
    // Memoized process-wide: the offline profile is deterministic in
    // (workload, cache geometry) and callers (sim::default_params, the
    // figure generators) ask for it repeatedly.
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    type Key = (Workload, usize, usize);
    static CACHE: OnceLock<Mutex<HashMap<Key, usize>>> = OnceLock::new();
    let key = (workload, cache.l2_bytes, cache.l3_bytes);
    let map = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(&v) = map.lock().unwrap().get(&key) {
        return v;
    }
    let p = profile_workload(workload, cache, &default_sizes(), None);
    let knee = smallest_kneepoint(&p.l2_curve(), KNEE_THRESHOLD)
        .unwrap_or(2 * 1024 * 1024);
    map.lock().unwrap().insert(key, knee);
    knee
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kneepoint_bytes_in_range() {
        let k = kneepoint_bytes(Workload::Eaglet, &CacheConfig::sandy_bridge());
        assert!((128 * 1024..=32 * 1024 * 1024).contains(&k), "{k}");
    }
}
