//! Kneepoint detection on the task-size → miss-rate curve (thesis Fig 3).
//!
//! "We size tasks at the smallest kneepoint on the task size to miss rate
//! curve. The smallest kneepoint is the largest task size before the
//! first increase in the cache-miss growth rate." The offline profiler
//! produces the curve; this module finds the knees.
//!
//! Implementation note: the thesis pseudo-code compares raw growth rates
//! (Δmiss/Δsize) against the first observed rate. Raw rates are
//! scale-dependent and fragile under measurement noise, while the thesis
//! itself reports that "kneepoint selection is insensitive to small
//! errors" — so we detect knees on the log-log *elasticity*
//! e = Δlog(miss)/Δlog(size): flat-cache regions have e ≈ 0, and a knee
//! is the last size before e first exceeds a threshold. This preserves
//! the algorithm's contract (largest task size before the first increase
//! in miss-rate growth) and is robust to ±5% noise.

/// One measured point of the offline profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    pub task_bytes: usize,
    pub miss_rate: f64,
}

const FLOOR: f64 = 1e-12;

fn elasticities(curve: &[CurvePoint]) -> Vec<(usize, f64)> {
    curve
        .windows(2)
        .filter(|w| w[1].task_bytes > w[0].task_bytes)
        .map(|w| {
            let e = ((w[1].miss_rate.max(FLOOR)) / (w[0].miss_rate.max(FLOOR)))
                .ln()
                / ((w[1].task_bytes as f64) / (w[0].task_bytes as f64)).ln();
            (w[0].task_bytes, e)
        })
        .collect()
}

/// The *smallest kneepoint*: the largest task size before the miss-rate
/// growth first becomes significant (elasticity > `threshold`; the
/// thesis's default behaviour corresponds to threshold ≈ 0.8, i.e. the
/// miss rate starts growing nearly linearly in task size). Returns the
/// largest measured size when the curve never turns up.
pub fn smallest_kneepoint(curve: &[CurvePoint], threshold: f64) -> Option<usize> {
    if curve.len() < 2 {
        return None;
    }
    for (size, e) in elasticities(curve) {
        if e > threshold {
            return Some(size);
        }
    }
    curve.last().map(|p| p.task_bytes)
}

/// All kneepoints: starts of rising regions. A segment opens a knee when
/// its elasticity exceeds `threshold` and either the previous segment was
/// calm or the elasticity jumped ≥2× (two stacked knees — the L2 knee and
/// the L3 knee of Fig 2 — appear as a second acceleration inside one
/// rising region).
pub fn kneepoints(curve: &[CurvePoint], threshold: f64) -> Vec<usize> {
    let es = elasticities(curve);
    let mut knees = Vec::new();
    let mut prev_e = 0.0f64;
    for (size, e) in es {
        let calm_before = prev_e <= threshold;
        if e > threshold && (calm_before || e > 2.0 * prev_e) {
            knees.push(size);
        }
        prev_e = e;
    }
    knees
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(kb: usize, mr: f64) -> CurvePoint {
        CurvePoint { task_bytes: kb * 1024, miss_rate: mr }
    }

    /// Synthetic two-knee curve shaped like Fig 2.
    fn fig2_like() -> Vec<CurvePoint> {
        vec![
            pt(256, 0.0010),
            pt(512, 0.0011),
            pt(1024, 0.0012),
            pt(2560, 0.0014), // knee 1 ~2.5MB: growth jumps after here
            pt(4096, 0.0060),
            pt(8192, 0.0130),
            pt(11264, 0.0180), // knee 2 ~11MB: second acceleration
            pt(16384, 0.0900),
            pt(25600, 0.2200),
        ]
    }

    #[test]
    fn finds_smallest_kneepoint() {
        let k = smallest_kneepoint(&fig2_like(), 0.8).unwrap();
        assert_eq!(k, 2560 * 1024, "expected the 2.5MB knee, got {k}");
    }

    #[test]
    fn finds_both_knees() {
        let ks = kneepoints(&fig2_like(), 0.8);
        assert!(
            ks.contains(&(2560 * 1024)),
            "missing first knee in {ks:?}"
        );
        assert!(
            ks.iter().any(|&k| k >= 8192 * 1024),
            "missing second knee in {ks:?}"
        );
    }

    #[test]
    fn flat_curve_returns_largest() {
        let c = vec![pt(1, 0.001), pt(2, 0.001), pt(4, 0.001), pt(8, 0.001)];
        assert_eq!(smallest_kneepoint(&c, 0.8), Some(8 * 1024));
        assert!(kneepoints(&c, 0.8).is_empty());
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(smallest_kneepoint(&[], 0.8), None);
        assert_eq!(smallest_kneepoint(&[pt(1, 0.1)], 0.8), None);
    }

    #[test]
    fn monotone_growth_below_threshold_has_no_knee() {
        // Steadily rising curve whose elasticity stays ≈0.26 (<0.8)
        // everywhere: growth never *accelerates*, so there is no knee —
        // the detector returns the largest measured size (run the
        // biggest task the curve blesses).
        let mut c = Vec::new();
        let mut mr = 0.001;
        for i in 0..10u32 {
            c.push(pt(1usize << i, mr));
            mr *= 1.2; // +20% per size doubling
        }
        assert_eq!(
            smallest_kneepoint(&c, 0.8),
            Some(c.last().unwrap().task_bytes)
        );
        assert!(kneepoints(&c, 0.8).is_empty());
    }

    #[test]
    fn single_point_profile_has_no_knee() {
        let c = [pt(512, 0.01)];
        assert_eq!(smallest_kneepoint(&c, 0.8), None);
        assert!(kneepoints(&c, 0.8).is_empty());
    }

    #[test]
    fn plateau_rise_plateau_yields_exactly_one_knee() {
        // flat → rise → flat: the knee is the last flat size before the
        // rise; the trailing plateau must not register a second knee.
        let c = vec![
            pt(1024, 0.001),
            pt(2048, 0.001),
            pt(4096, 0.001),
            pt(8192, 0.02),
            pt(16384, 0.02),
            pt(32768, 0.02),
        ];
        assert_eq!(smallest_kneepoint(&c, 0.8), Some(4096 * 1024));
        assert_eq!(kneepoints(&c, 0.8), vec![4096 * 1024]);
    }

    #[test]
    fn duplicate_sizes_are_skipped_not_fatal() {
        // Repeated measurements at one size produce a zero-width
        // segment; the elasticity filter drops it instead of dividing
        // by ln(1) = 0.
        let c = vec![
            pt(1024, 0.001),
            pt(1024, 0.002),
            pt(2048, 0.001),
            pt(4096, 0.05),
        ];
        assert_eq!(smallest_kneepoint(&c, 0.8), Some(2048 * 1024));
    }

    #[test]
    fn declining_curve_has_no_knee() {
        // Miss rate falling with task size (negative elasticity): no
        // knee anywhere, largest size returned.
        let c = vec![pt(1024, 0.04), pt(2048, 0.02), pt(4096, 0.01)];
        assert_eq!(smallest_kneepoint(&c, 0.8), Some(4096 * 1024));
        assert!(kneepoints(&c, 0.8).is_empty());
    }

    #[test]
    fn zero_miss_rates_do_not_panic() {
        let c = vec![pt(64, 0.0), pt(128, 0.0), pt(256, 0.02)];
        let k = smallest_kneepoint(&c, 0.8).unwrap();
        assert_eq!(k, 128 * 1024);
    }

    #[test]
    fn tolerance_suppresses_noise() {
        // small wiggles should not register as a knee
        let c = vec![
            pt(256, 0.0010),
            pt(512, 0.0011),
            pt(1024, 0.00105),
            pt(2048, 0.00125),
            pt(4096, 0.0013),
            pt(8192, 0.0200), // real knee precedes this jump
        ];
        let k = smallest_kneepoint(&c, 0.8).unwrap();
        assert_eq!(k, 4096 * 1024);
    }

    #[test]
    fn insensitive_to_small_errors() {
        // thesis §3.2.1: "kneepoint selection is insensitive to small
        // errors" — perturb the curve by ±5% and expect the same knee.
        let base = fig2_like();
        for seed in 0..50u64 {
            let mut rng = crate::util::rng::Rng::new(seed);
            let noisy: Vec<CurvePoint> = base
                .iter()
                .map(|p| CurvePoint {
                    task_bytes: p.task_bytes,
                    miss_rate: p.miss_rate * (0.95 + 0.1 * rng.f64()),
                })
                .collect();
            let k = smallest_kneepoint(&noisy, 0.8).unwrap();
            assert!(
                (1024 * 1024..=4096 * 1024).contains(&k),
                "seed {seed}: knee drifted to {k}"
            );
        }
    }
}
