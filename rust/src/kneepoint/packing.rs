//! Online phase (thesis §3.2.1, right column of Fig 3): pack samples
//! into tasks of (kneepoint) size before starting map tasks.
//!
//! "We modified our platform to group samples into tasks of equal
//! (kneepoint) size before starting map tasks." Samples are atomic (an
//! EAGLET family is "the atomic part for computing the statistic"), so a
//! task holds whole samples; a task may exceed the byte target only when
//! a single sample alone does (the 15×/7× outliers).

use crate::data::SampleMeta;

/// How the platform sizes tasks — one arm per experimental configuration
/// (§4.1.3: BTS / BLT / BTT).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TaskSizing {
    /// BTS: pack to the offline-detected kneepoint (bytes).
    Kneepoint(usize),
    /// BLT: one task per worker holding all samples partitioned to it.
    LargeSn { workers: usize },
    /// BTT: one sample per task.
    Tiniest,
    /// Fixed byte target (sweeps, e.g. the Fig 8 x-axis).
    Fixed(usize),
}

/// One packed map task (ids reference the dataset's sample metas).
#[derive(Debug, Clone, PartialEq)]
pub struct PackedTask {
    pub seq: usize,
    pub sample_ids: Vec<u64>,
    pub units: u32,
    pub bytes: usize,
}

/// Pack `metas` into tasks under the given sizing policy.
pub fn pack(metas: &[SampleMeta], sizing: TaskSizing) -> Vec<PackedTask> {
    match sizing {
        TaskSizing::Kneepoint(target) | TaskSizing::Fixed(target) => {
            pack_to_bytes(metas, target.max(1))
        }
        TaskSizing::Tiniest => metas
            .iter()
            .enumerate()
            .map(|(seq, m)| PackedTask {
                seq,
                sample_ids: vec![m.id],
                units: m.units,
                bytes: m.bytes,
            })
            .collect(),
        TaskSizing::LargeSn { workers } => pack_large(metas, workers.max(1)),
    }
}

fn pack_to_bytes(metas: &[SampleMeta], target: usize) -> Vec<PackedTask> {
    let mut out = Vec::new();
    let mut cur = PackedTask { seq: 0, sample_ids: Vec::new(), units: 0, bytes: 0 };
    for m in metas {
        if !cur.sample_ids.is_empty() && cur.bytes + m.bytes > target {
            let seq = out.len();
            out.push(PackedTask { seq, ..std::mem::replace(&mut cur, PackedTask {
                seq: 0,
                sample_ids: Vec::new(),
                units: 0,
                bytes: 0,
            }) });
        }
        cur.sample_ids.push(m.id);
        cur.units += m.units;
        cur.bytes += m.bytes;
    }
    if !cur.sample_ids.is_empty() {
        let seq = out.len();
        out.push(PackedTask { seq, ..cur });
    }
    out
}

/// BLT: split samples into `workers` contiguous groups of roughly equal
/// byte size — "the master node referred to all samples on a node within
/// a single file" (§4.1.3).
fn pack_large(metas: &[SampleMeta], workers: usize) -> Vec<PackedTask> {
    let total: usize = metas.iter().map(|m| m.bytes).sum();
    let per = total.div_ceil(workers).max(1);
    let tasks = pack_to_bytes(metas, per);
    // pack_to_bytes may produce slightly more groups than workers when
    // boundaries land badly; that still models "one big file per node".
    tasks
}

/// Sanity bound used by callers and property tests: the largest packed
/// task under Kneepoint/Fixed sizing, discounting single-sample tasks.
pub fn max_multi_sample_bytes(tasks: &[PackedTask]) -> usize {
    tasks
        .iter()
        .filter(|t| t.sample_ids.len() > 1)
        .map(|t| t.bytes)
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn metas_from(rng: &mut Rng, n: usize) -> Vec<SampleMeta> {
        (0..n as u64)
            .map(|id| {
                let units = rng.range(1, 8) as u32;
                SampleMeta { id, bytes: units as usize * 2304, units }
            })
            .collect()
    }

    #[test]
    fn tiniest_is_one_sample_per_task() {
        let mut rng = Rng::new(1);
        let metas = metas_from(&mut rng, 40);
        let tasks = pack(&metas, TaskSizing::Tiniest);
        assert_eq!(tasks.len(), 40);
        assert!(tasks.iter().all(|t| t.sample_ids.len() == 1));
    }

    #[test]
    fn large_sn_groups_to_worker_count() {
        let mut rng = Rng::new(2);
        let metas = metas_from(&mut rng, 100);
        let tasks = pack(&metas, TaskSizing::LargeSn { workers: 6 });
        assert!((6..=8).contains(&tasks.len()), "{} groups", tasks.len());
    }

    #[test]
    fn kneepoint_respects_target_except_outliers() {
        let mut metas = vec![SampleMeta { id: 0, bytes: 100_000, units: 30 }];
        let mut rng = Rng::new(3);
        metas.extend(metas_from(&mut rng, 50).into_iter().map(|mut m| {
            m.id += 1;
            m
        }));
        let tasks = pack(&metas, TaskSizing::Kneepoint(10_000));
        // the outlier is alone in its task
        let outlier_task = tasks
            .iter()
            .find(|t| t.sample_ids.contains(&0))
            .unwrap();
        assert_eq!(outlier_task.sample_ids.len(), 1);
        assert!(max_multi_sample_bytes(&tasks) <= 10_000);
    }

    /// Property: packing conserves samples exactly, never duplicates,
    /// and respects the byte target for multi-sample tasks.
    #[test]
    fn prop_packing_conserves_samples() {
        check("packing conserves samples", 300, |rng| {
            let n = rng.range(1, 120) as usize;
            let metas = metas_from(rng, n);
            let sizing = match rng.below(4) {
                0 => TaskSizing::Tiniest,
                1 => TaskSizing::LargeSn { workers: rng.range(1, 12) as usize },
                2 => TaskSizing::Kneepoint(rng.range(1_000, 60_000) as usize),
                _ => TaskSizing::Fixed(rng.range(1_000, 60_000) as usize),
            };
            let tasks = pack(&metas, sizing);
            let mut ids: Vec<u64> =
                tasks.iter().flat_map(|t| t.sample_ids.clone()).collect();
            ids.sort_unstable();
            let mut want: Vec<u64> = metas.iter().map(|m| m.id).collect();
            want.sort_unstable();
            prop_assert!(ids == want, "ids mismatch under {sizing:?}");
            for t in &tasks {
                let b: usize = t
                    .sample_ids
                    .iter()
                    .map(|id| metas.iter().find(|m| m.id == *id).unwrap().bytes)
                    .sum();
                prop_assert!(b == t.bytes, "bytes bookkeeping off");
                let u: u32 = t
                    .sample_ids
                    .iter()
                    .map(|id| metas.iter().find(|m| m.id == *id).unwrap().units)
                    .sum();
                prop_assert!(u == t.units, "units bookkeeping off");
            }
            if let TaskSizing::Kneepoint(target) | TaskSizing::Fixed(target) = sizing {
                prop_assert!(
                    max_multi_sample_bytes(&tasks) <= target,
                    "multi-sample task exceeds target {target}"
                );
            }
            // seq numbering is dense
            for (i, t) in tasks.iter().enumerate() {
                prop_assert!(t.seq == i, "seq not dense");
            }
            Ok(())
        });
    }

    #[test]
    fn empty_input_empty_output() {
        assert!(pack(&[], TaskSizing::Tiniest).is_empty());
        assert!(pack(&[], TaskSizing::Kneepoint(1000)).is_empty());
    }
}
