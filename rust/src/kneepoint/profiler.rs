//! Offline profiling phase (thesis §3.2.1, left column of Fig 3).
//!
//! "During an offline phase, we collect data on the relationship between
//! task size and cache misses. On a benchmarking node, we run OProfile.
//! We run map tasks in isolation, varying the number of samples in the
//! task's working set." Our benchmarking node is the cache simulator
//! (DESIGN.md §2) — the curve shape comes from the same subsampling
//! access pattern the real tasks execute.
//!
//! The offline phase is a one-time cost per (dataset, hardware) pair
//! (~3% of online time in the thesis); `ProfileCache` memoizes it.

use std::collections::HashMap;

use super::detector::CurvePoint;
use crate::cachesim::{run_task_trace, CacheConfig, Hierarchy, TraceConfig};
use crate::data::Workload;

/// Full per-point measurements (Fig 2 plots l2 mpi + normalized AMAT).
#[derive(Debug, Clone)]
pub struct ProfilePoint {
    pub task_bytes: usize,
    pub l2_mpi: f64,
    pub l3_mpi: f64,
    pub amat: f64,
    pub cpi: f64,
}

#[derive(Debug, Clone)]
pub struct Profile {
    pub workload: Workload,
    pub points: Vec<ProfilePoint>,
}

impl Profile {
    pub fn l2_curve(&self) -> Vec<CurvePoint> {
        self.points
            .iter()
            .map(|p| CurvePoint { task_bytes: p.task_bytes, miss_rate: p.l2_mpi })
            .collect()
    }

    pub fn l3_curve(&self) -> Vec<CurvePoint> {
        self.points
            .iter()
            .map(|p| CurvePoint { task_bytes: p.task_bytes, miss_rate: p.l3_mpi })
            .collect()
    }
}

/// Trace shape for a workload; `frac` overrides the subsample fraction
/// (the Fig 9 confidence-level sweep).
fn trace_for(workload: Workload, task_bytes: usize, frac: Option<f64>) -> TraceConfig {
    match workload {
        Workload::Eaglet => {
            let mut t = TraceConfig::eaglet(task_bytes);
            if let Some(f) = frac {
                t.subsample_frac = f;
            }
            t
        }
        Workload::NetflixHi => TraceConfig::netflix(task_bytes, frac.unwrap_or(0.5)),
        Workload::NetflixLo => TraceConfig::netflix(task_bytes, frac.unwrap_or(0.0625)),
        // SeqAddr's sequential-addressing windows stream like the
        // EAGLET scan (windowed sequential reads, modest reuse).
        Workload::SeqAddr => {
            let mut t = TraceConfig::eaglet(task_bytes);
            if let Some(f) = frac {
                t.subsample_frac = f;
            }
            t
        }
        // SSAG re-walks the full series once per ladder rung — access
        // pattern matches a high-fraction subsample scan.
        Workload::Ssag => TraceConfig::netflix(task_bytes, frac.unwrap_or(0.5)),
    }
}

/// Default task-size ladder: 0.25 MB … 32 MB, log-spaced (brackets the
/// thesis's 2.5 MB / 11 MB knees).
pub fn default_sizes() -> Vec<usize> {
    let mut v = Vec::new();
    let mut kb = 256usize;
    while kb <= 48 * 1024 {
        v.push(kb * 1024);
        // ~1.5× steps give enough resolution around the knees
        kb = kb * 3 / 2;
    }
    v
}

pub fn profile_workload(
    workload: Workload,
    cache: &CacheConfig,
    sizes: &[usize],
    frac: Option<f64>,
) -> Profile {
    let points = sizes
        .iter()
        .map(|&task_bytes| {
            let mut h = Hierarchy::new(cache.clone());
            run_task_trace(&trace_for(workload, task_bytes, frac), &mut h);
            ProfilePoint {
                task_bytes,
                l2_mpi: h.l2_mpi(),
                l3_mpi: h.l3_mpi(),
                amat: h.amat(),
                cpi: h.cpi(1.0),
            }
        })
        .collect();
    Profile { workload, points }
}

/// Memoized profiles per (workload, cache-identity, frac-mil).
#[derive(Default)]
pub struct ProfileCache {
    map: HashMap<(Workload, usize, u64), Profile>,
}

impl ProfileCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(
        &mut self,
        workload: Workload,
        cache: &CacheConfig,
        frac: Option<f64>,
    ) -> &Profile {
        let key = (
            workload,
            cache.l2_bytes ^ (cache.l3_bytes << 1),
            (frac.unwrap_or(-1.0) * 1000.0) as u64,
        );
        self.map.entry(key).or_insert_with(|| {
            profile_workload(workload, cache, &default_sizes(), frac)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kneepoint::detector::smallest_kneepoint;
    use crate::kneepoint::KNEE_THRESHOLD;

    #[test]
    fn eaglet_profile_has_a_knee_below_l3() {
        let p = profile_workload(
            Workload::Eaglet,
            &CacheConfig::sandy_bridge(),
            &default_sizes(),
            None,
        );
        let knee = smallest_kneepoint(&p.l2_curve(), KNEE_THRESHOLD).unwrap();
        assert!(
            (256 * 1024..=16 * 1024 * 1024).contains(&knee),
            "knee {knee} out of plausible range"
        );
        // miss rate at the largest size dwarfs the smallest (35× in the
        // thesis; we require a strong ordering, not the exact factor)
        let first = p.points.first().unwrap().l2_mpi.max(1e-9);
        let last = p.points.last().unwrap().l2_mpi;
        assert!(last > 8.0 * first, "{last} vs {first}");
    }

    #[test]
    fn amat_grows_dramatically() {
        // thesis: >1000× AMAT growth tiniest → largest. Our normalized
        // AMAT starts at ~1 cycle; require a large multiple.
        let p = profile_workload(
            Workload::Eaglet,
            &CacheConfig::sandy_bridge(),
            &default_sizes(),
            None,
        );
        let a0 = p.points.first().unwrap().amat;
        let a1 = p.points.last().unwrap().amat;
        assert!(a1 / a0 > 8.0, "amat growth {a0} -> {a1}");
    }

    #[test]
    fn netflix_hi_knee_not_after_lo_knee() {
        let cfg = CacheConfig::sandy_bridge();
        let hi = profile_workload(Workload::NetflixHi, &cfg, &default_sizes(), None);
        let lo = profile_workload(Workload::NetflixLo, &cfg, &default_sizes(), None);
        let k_hi = smallest_kneepoint(&hi.l2_curve(), KNEE_THRESHOLD).unwrap();
        let k_lo = smallest_kneepoint(&lo.l2_curve(), KNEE_THRESHOLD).unwrap();
        assert!(
            k_hi <= k_lo,
            "hi-confidence knee {k_hi} should not exceed lo {k_lo}"
        );
    }

    #[test]
    fn cache_memoizes() {
        let mut c = ProfileCache::new();
        let cfg = CacheConfig::sandy_bridge();
        let a = c.get(Workload::Eaglet, &cfg, None).points.len();
        let b = c.get(Workload::Eaglet, &cfg, None).points.len();
        assert_eq!(a, b);
        assert_eq!(c.map.len(), 1);
    }

    #[test]
    fn bigger_cache_moves_knee_right() {
        // Opteron's larger L2/L3 should tolerate larger tasks (thesis
        // §4.2.4 re-ran task sizing on type-3 hardware).
        let sizes = default_sizes();
        let sb = profile_workload(
            Workload::Eaglet,
            &CacheConfig::sandy_bridge(),
            &sizes,
            None,
        );
        let op = profile_workload(
            Workload::Eaglet,
            &CacheConfig::opteron(),
            &sizes,
            None,
        );
        let k_sb = smallest_kneepoint(&sb.l2_curve(), KNEE_THRESHOLD).unwrap();
        let k_op = smallest_kneepoint(&op.l2_curve(), KNEE_THRESHOLD).unwrap();
        assert!(k_op >= k_sb, "opteron knee {k_op} < sandy bridge {k_sb}");
    }
}
