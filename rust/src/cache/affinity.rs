//! Worker block-affinity registry: which worker most recently held
//! which block key.
//!
//! Every worker's prefetcher records the keys it fetches; the
//! two-step scheduler consults the registry when it builds a refill
//! batch, preferring tasks whose blocks the claiming worker already
//! holds (cache-affinity dispatch). The registry is advisory and
//! bounded — losing an entry costs at most one re-fetch, so shards
//! prune themselves to a capacity instead of growing with the job
//! history.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::rng::fnv1a;

struct Slot {
    worker: usize,
    stamp: u64,
}

struct AffShard {
    map: HashMap<String, Slot>,
    clock: u64,
}

/// See module docs. One per executor/pool, shared by every worker.
pub struct AffinityIndex {
    shards: Vec<Mutex<AffShard>>,
    cap_per_shard: usize,
    recorded: AtomicU64,
}

impl AffinityIndex {
    /// Registry bounded to roughly `capacity` keys across 8 shards.
    pub fn new(capacity: usize) -> AffinityIndex {
        let shards = 8;
        AffinityIndex {
            shards: (0..shards)
                .map(|_| {
                    Mutex::new(AffShard { map: HashMap::new(), clock: 0 })
                })
                .collect(),
            cap_per_shard: (capacity / shards).max(16),
            recorded: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &str) -> usize {
        (fnv1a(key.as_bytes()) % self.shards.len() as u64) as usize
    }

    /// Note that `worker` now holds `key` (last writer wins).
    pub fn record(&self, worker: usize, key: &str) {
        let mut s = self.shards[self.shard(key)].lock().unwrap();
        s.clock += 1;
        let stamp = s.clock;
        s.map.insert(key.to_string(), Slot { worker, stamp });
        if s.map.len() > self.cap_per_shard {
            // prune the stalest half; O(n log n) every cap/2 inserts
            let mut stamps: Vec<u64> =
                s.map.values().map(|v| v.stamp).collect();
            stamps.sort_unstable();
            let cutoff = stamps[stamps.len() / 2];
            s.map.retain(|_, v| v.stamp >= cutoff);
        }
        self.recorded.fetch_add(1, Ordering::Relaxed);
    }

    /// Worker last seen holding `key`, if any.
    pub fn owner(&self, key: &str) -> Option<usize> {
        let s = self.shards[self.shard(key)].lock().unwrap();
        s.map.get(key).map(|v| v.worker)
    }

    /// How many of `keys` the registry attributes to `worker`.
    pub fn score<I>(&self, worker: usize, keys: I) -> usize
    where
        I: IntoIterator<Item = String>,
    {
        keys.into_iter()
            .filter(|k| self.owner(k) == Some(worker))
            .count()
    }

    /// Forget every key under `prefix` (tenant cleanup; keeps a
    /// retired job's keys from skewing future refill scores).
    pub fn forget_prefix(&self, prefix: &str) {
        for shard in &self.shards {
            let mut s = shard.lock().unwrap();
            s.map.retain(|k, _| !k.starts_with(prefix));
        }
    }

    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The scheduler's view of the registry: the index plus the job's key
/// namespace, so a per-job scheduler can rebuild block keys from its
/// [`crate::scheduler::TaskSpec`]s alone.
#[derive(Clone)]
pub struct AffinityHook {
    pub index: Arc<AffinityIndex>,
    pub ns: Arc<str>,
}

impl AffinityHook {
    pub fn new(index: Arc<AffinityIndex>, ns: Arc<str>) -> AffinityHook {
        AffinityHook { index, ns }
    }
}

impl fmt::Debug for AffinityHook {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("AffinityHook")
            .field("ns", &self.ns)
            .field("keys", &self.index.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_scores_ownership() {
        let a = AffinityIndex::new(1024);
        a.record(0, "j1/b0:1");
        a.record(0, "j1/b0:2");
        a.record(1, "j1/b0:3");
        assert_eq!(a.owner("j1/b0:1"), Some(0));
        assert_eq!(a.owner("j1/b0:3"), Some(1));
        assert_eq!(a.owner("ghost"), None);
        let keys = |ids: &[u64]| {
            ids.iter()
                .map(|i| format!("j1/b0:{i}"))
                .collect::<Vec<_>>()
        };
        assert_eq!(a.score(0, keys(&[1, 2, 3])), 2);
        assert_eq!(a.score(1, keys(&[1, 2, 3])), 1);
        assert_eq!(a.recorded(), 3);
    }

    #[test]
    fn last_writer_wins() {
        let a = AffinityIndex::new(1024);
        a.record(0, "k");
        a.record(3, "k");
        assert_eq!(a.owner("k"), Some(3));
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn capacity_is_bounded_and_keeps_fresh_entries() {
        let a = AffinityIndex::new(128); // 16 per shard
        for i in 0..2000 {
            a.record(0, &format!("b{i}"));
        }
        assert!(a.len() <= 8 * 17, "registry grew unbounded: {}", a.len());
        // the freshest key always survives its own insert
        a.record(2, "fresh");
        assert_eq!(a.owner("fresh"), Some(2));
    }

    #[test]
    fn forget_prefix_scopes_to_one_namespace() {
        let a = AffinityIndex::new(1024);
        a.record(0, "j1/x");
        a.record(1, "j2/x");
        a.forget_prefix("j1/");
        assert_eq!(a.owner("j1/x"), None);
        assert_eq!(a.owner("j2/x"), Some(1));
        assert!(!a.is_empty());
    }
}
