//! The cache subsystem: a worker-side tiered block cache with
//! cache-affinity scheduling and cross-tenant dedup (DESIGN.md §10).
//!
//! The thesis's tiny-task argument only wins while cache-miss savings
//! are not eclipsed by data-distribution cost — yet without this
//! layer every task fetch pays the full modeled data-node round trip,
//! even when the same worker (or another tenant) just staged the
//! identical block. Three pieces close that gap:
//!
//! * [`BlockCache`] — a sharded, byte-budgeted 2Q/LRU cache with
//!   admission control that the dfs client reads through
//!   (`Dfs::attach_cache`). Entries are keyed by content hash, so
//!   tenants staging byte-identical sample blocks under different job
//!   namespaces share one resident copy (cross-tenant dedup) instead
//!   of double-fetching. Invalidation is wired into `Dfs::remove` /
//!   `Dfs::put` and `Prefetcher::purge_prefix`, so a removed or
//!   overwritten key can never serve stale bytes.
//! * [`AffinityIndex`] — which worker last held which block, recorded
//!   by the prefetchers and consulted by the two-step scheduler's
//!   refill step, which prefers tasks whose blocks the claiming
//!   worker already holds ([`AffinityHook`] carries the job
//!   namespace). Busy-skip round-robin and work stealing are
//!   untouched — affinity reorders refills, it never starves anyone.
//! * [`CacheLayer`] — the small builder both executors share: attach
//!   a budgeted cache to a store and/or stand up an affinity
//!   registry, from the `--cache-mb` / `--affinity` knobs.
//!
//! Determinism is untouched by construction: the cache returns the
//! same bytes the store would, and affinity only changes *where* a
//! task runs — per-task seeds and the seq-ordered reduce make the job
//! statistic independent of placement (asserted end to end in
//! `rust/tests/integration_cache.rs`).

pub mod affinity;
pub mod block_cache;

use std::sync::Arc;

pub use affinity::{AffinityHook, AffinityIndex};
pub use block_cache::{content_hash, BlockCache, CacheStats};

use crate::dfs::Dfs;

/// Default shard count for executor-attached caches.
pub const DEFAULT_SHARDS: usize = 8;

/// Default affinity-registry capacity (keys) for executor runs.
pub const DEFAULT_AFFINITY_KEYS: usize = 1 << 16;

/// What one executor run (or one serve pool) holds of the cache
/// subsystem. Either half can be disabled independently.
pub struct CacheLayer {
    pub cache: Option<Arc<BlockCache>>,
    pub affinity: Option<Arc<AffinityIndex>>,
}

impl CacheLayer {
    /// Stand the layer up against `dfs`: a `cache_mb`-MiB block cache
    /// attached to the store (0 disables), plus an affinity registry
    /// when `affinity` is set.
    pub fn build(dfs: &Dfs, cache_mb: usize, affinity: bool) -> CacheLayer {
        let cache = (cache_mb > 0).then(|| {
            let c = Arc::new(BlockCache::new(cache_mb << 20, DEFAULT_SHARDS));
            dfs.attach_cache(c.clone());
            c
        });
        let affinity = affinity
            .then(|| Arc::new(AffinityIndex::new(DEFAULT_AFFINITY_KEYS)));
        CacheLayer { cache, affinity }
    }

    /// The scheduler hook for one job's namespace, when affinity is on.
    pub fn hook(&self, ns: Arc<str>) -> Option<AffinityHook> {
        self.affinity
            .as_ref()
            .map(|a| AffinityHook::new(a.clone(), ns))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::LatencyModel;

    #[test]
    fn layer_build_respects_the_knobs() {
        let dfs = Dfs::new(2, 1, LatencyModel::none());
        let off = CacheLayer::build(&dfs, 0, false);
        assert!(off.cache.is_none() && off.affinity.is_none());
        assert!(off.hook("j1/".into()).is_none());

        let dfs = Dfs::new(2, 1, LatencyModel::none());
        let on = CacheLayer::build(&dfs, 16, true);
        assert!(on.cache.is_some() && on.affinity.is_some());
        let hook = on.hook("j1/".into()).unwrap();
        assert_eq!(&*hook.ns, "j1/");
        assert!(dfs.cache().is_some(), "cache not attached to the store");
    }
}
