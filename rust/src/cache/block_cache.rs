//! The tiered worker-side block cache: sharded, byte-budgeted,
//! 2Q-over-LRU with admission control and content-hash dedup.
//!
//! Layout: a *key index* (block key → content hash) and a *content
//! store* (content hash → bytes + the keys referencing them), each
//! sharded behind its own small mutexes so a fetch never touches the
//! executor's hot-path locks. Replacement is 2Q-style: a block enters
//! on probation and is promoted to the protected (LRU) side on its
//! first re-reference, so a one-pass scan over a big job cannot flush
//! the blocks hot tenants keep re-reading. Admission control refuses
//! objects larger than a shard-budget fraction outright.
//!
//! Dedup: entries are keyed by a content hash, so two tenants staging
//! byte-identical sample blocks under different job namespaces share
//! one resident copy — the second tenant's keys *alias* the first's
//! bytes ([`BlockCache::register_put`]) instead of double-fetching.
//! Hash collisions are disarmed by comparing the actual bytes before
//! any alias is created.
//!
//! Coherence: [`BlockCache::remove_key`] (driven by `Dfs::remove`) and
//! [`BlockCache::purge_prefix`] (driven by `Prefetcher::purge_prefix`)
//! drop the key → content mapping immediately, so a removed or
//! overwritten key can never resurrect stale bytes; the unreferenced
//! content itself stays resident until the byte budget evicts it,
//! which is what keeps a *later* identical tenant warm.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::rng::{fnv1a, mix64};

/// Content fingerprint of a block's bytes (dedup key).
#[inline]
pub fn content_hash(bytes: &[u8]) -> u64 {
    mix64(fnv1a(bytes))
}

/// One resident content entry plus every key aliasing it. The
/// `newer`/`older` fields are intrusive recency-list links (neighbor
/// content hashes), so a touch is pointer surgery on entries already
/// in the map — the warm cache-hit path allocates nothing.
struct Entry {
    data: Arc<Vec<u8>>,
    keys: Vec<String>,
    /// Next-more-recent entry's content hash (`None` = recency head).
    newer: Option<u64>,
    /// Next-less-recent entry's content hash (`None` = recency tail).
    older: Option<u64>,
    /// 2Q state: false = probation (first touch), true = protected.
    protected: bool,
}

/// One key-index shard: key → content hash, plus an invalidation
/// epoch. The epoch is bumped by every invalidation touching the
/// shard; a read-through fill that began before the bump is refused
/// at mapping-commit time, so a racing `put`/`remove` can never be
/// overwritten by stale bytes fetched earlier ([`BlockCache::fill`]).
struct IxShard {
    map: HashMap<String, u64>,
    epoch: u64,
}

/// One content shard: entries threaded onto an intrusive recency list
/// (`head` = most recent, `tail` = least recent). No side structure
/// orders the entries, so touching one on a hit is alloc-free.
struct Shard {
    entries: HashMap<u64, Entry>,
    head: Option<u64>,
    tail: Option<u64>,
    bytes: usize,
}

impl Shard {
    fn new() -> Shard {
        Shard { entries: HashMap::new(), head: None, tail: None, bytes: 0 }
    }

    /// Detach `h` from the recency list: neighbors (or the list ends)
    /// are patched around it. `h`'s own links are left stale — the
    /// caller either relinks it ([`Shard::push_front`]) or removes it.
    fn unlink(&mut self, h: u64) {
        let (newer, older) = match self.entries.get(&h) {
            Some(e) => (e.newer, e.older),
            None => return,
        };
        match newer {
            Some(n) => {
                if let Some(e) = self.entries.get_mut(&n) {
                    e.older = older;
                }
            }
            None => self.head = older,
        }
        match older {
            Some(o) => {
                if let Some(e) = self.entries.get_mut(&o) {
                    e.newer = newer;
                }
            }
            None => self.tail = newer,
        }
    }

    /// Link a detached `h` in at the most-recent end.
    fn push_front(&mut self, h: u64) {
        let old_head = self.head;
        match self.entries.get_mut(&h) {
            Some(e) => {
                e.newer = None;
                e.older = old_head;
            }
            None => return,
        }
        match old_head {
            Some(o) => {
                if let Some(e) = self.entries.get_mut(&o) {
                    e.newer = Some(h);
                }
            }
            None => self.tail = Some(h),
        }
        self.head = Some(h);
    }

    /// Move `h` to the recency front — pure pointer surgery on the
    /// intrusive links, the zero-allocation half of the warm-hit
    /// guarantee `benches/transport_overhead.rs` asserts.
    fn touch(&mut self, h: u64) {
        if self.head == Some(h) || !self.entries.contains_key(&h) {
            return;
        }
        self.unlink(h);
        self.push_front(h);
    }

    /// Eviction victim, oldest-first within class: unreferenced
    /// content goes before probation, probation before protected.
    /// Walks the recency list tail → head.
    fn victim(&self) -> Option<u64> {
        let mut first_probation = None;
        let mut cur = self.tail;
        while let Some(h) = cur {
            let e = &self.entries[&h];
            if e.keys.is_empty() {
                return Some(h);
            }
            if first_probation.is_none() && !e.protected {
                first_probation = Some(h);
            }
            cur = e.newer;
        }
        // No unreferenced, no probation: the oldest entry overall.
        first_probation.or(self.tail)
    }

    /// Evict until the shard fits `budget`; returns the keys of every
    /// evicted entry so the caller can clean the key index.
    fn evict_to(&mut self, budget: usize) -> Vec<(u64, Vec<String>)> {
        let mut out = Vec::new();
        while self.bytes > budget {
            let Some(h) = self.victim() else { break };
            self.unlink(h);
            if let Some(e) = self.entries.remove(&h) {
                self.bytes -= e.data.len();
                out.push((h, e.keys));
            }
        }
        out
    }
}

/// Point-in-time cache counters (tests, `ServeReport`, BENCH records).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserted: u64,
    /// New keys that aliased already-resident content (a data-node
    /// round trip another tenant would otherwise have paid twice).
    pub dedup_hits: u64,
    pub evicted: u64,
    /// Inserts refused by admission control (oversized objects and
    /// the astronomically unlikely verified hash collision).
    pub rejected: u64,
    /// Key mappings dropped for coherence (remove / overwrite / purge).
    pub invalidated: u64,
    pub resident_bytes: u64,
    pub resident_blocks: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// See module docs. One per shared store ([`crate::dfs::Dfs`]).
pub struct BlockCache {
    index: Vec<Mutex<IxShard>>,
    data: Vec<Mutex<Shard>>,
    shard_budget: usize,
    max_object: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    inserted: AtomicU64,
    dedup_hits: AtomicU64,
    evicted: AtomicU64,
    rejected: AtomicU64,
    invalidated: AtomicU64,
}

impl BlockCache {
    /// A cache holding at most `budget_bytes` across `shards` shards.
    /// Objects above a quarter of one shard's budget are never
    /// admitted (they would evict a whole working set for one block).
    pub fn new(budget_bytes: usize, shards: usize) -> BlockCache {
        let shards = shards.clamp(1, 64);
        let shard_budget = (budget_bytes / shards).max(1);
        BlockCache {
            index: (0..shards)
                .map(|_| {
                    Mutex::new(IxShard { map: HashMap::new(), epoch: 0 })
                })
                .collect(),
            data: (0..shards).map(|_| Mutex::new(Shard::new())).collect(),
            shard_budget,
            max_object: (shard_budget / 4).max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserted: AtomicU64::new(0),
            dedup_hits: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            invalidated: AtomicU64::new(0),
        }
    }

    fn ishard(&self, key: &str) -> usize {
        (mix64(fnv1a(key.as_bytes())) % self.index.len() as u64) as usize
    }

    fn dshard(&self, h: u64) -> usize {
        (h % self.data.len() as u64) as usize
    }

    /// Look `key` up; a hit promotes the entry out of probation. A
    /// stale index mapping (content already evicted) is cleaned and
    /// reported as a miss.
    pub fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        let h = {
            let ix = self.index[self.ishard(key)].lock().unwrap();
            ix.map.get(key).copied()
        };
        let Some(h) = h else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        let found = {
            let mut s = self.data[self.dshard(h)].lock().unwrap();
            let data = match s.entries.get_mut(&h) {
                Some(e) => {
                    e.protected = true;
                    Some(e.data.clone())
                }
                None => None,
            };
            if data.is_some() {
                s.touch(h);
            }
            data
        };
        match found {
            Some(data) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(data)
            }
            None => {
                let mut ix = self.index[self.ishard(key)].lock().unwrap();
                if ix.map.get(key) == Some(&h) {
                    ix.map.remove(key);
                }
                drop(ix);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// The key's current invalidation epoch. A read-through caller
    /// snapshots this *before* it fetches from the store and hands it
    /// to [`BlockCache::fill`], which refuses the mapping if any
    /// invalidation touched the shard in between.
    pub fn key_epoch(&self, key: &str) -> u64 {
        self.index[self.ishard(key)].lock().unwrap().epoch
    }

    /// Admit `key` → `data` after a store fetch (the read-through
    /// fill). Byte-identical content already resident is aliased, not
    /// duplicated.
    pub fn insert(&self, key: &str, data: &Arc<Vec<u8>>) {
        self.insert_inner(key, data, None);
    }

    /// Read-through fill: like [`BlockCache::insert`], but the key
    /// mapping only commits if the shard's invalidation epoch still
    /// equals `observed_epoch` (snapshotted before the store fetch) —
    /// a concurrent `put`/`remove` wins over the in-flight stale fill.
    pub fn fill(&self, key: &str, data: &Arc<Vec<u8>>, observed_epoch: u64) {
        self.insert_inner(key, data, Some(observed_epoch));
    }

    fn insert_inner(
        &self,
        key: &str,
        data: &Arc<Vec<u8>>,
        observed_epoch: Option<u64>,
    ) {
        if data.len() > self.max_object {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let h = content_hash(data);
        let evicted = {
            let mut s = self.data[self.dshard(h)].lock().unwrap();
            let resident = match s.entries.get_mut(&h) {
                Some(e) if *e.data != **data => {
                    // verified 64-bit collision: refuse the alias
                    self.rejected.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                Some(e) => {
                    if !e.keys.iter().any(|k| k == key) {
                        e.keys.push(key.to_string());
                        self.dedup_hits.fetch_add(1, Ordering::Relaxed);
                    }
                    true
                }
                None => false,
            };
            if resident {
                s.touch(h);
                Vec::new()
            } else {
                s.bytes += data.len();
                s.entries.insert(
                    h,
                    Entry {
                        data: data.clone(),
                        keys: vec![key.to_string()],
                        newer: None,
                        older: None,
                        protected: false,
                    },
                );
                s.push_front(h);
                self.inserted.fetch_add(1, Ordering::Relaxed);
                s.evict_to(self.shard_budget)
            }
        };
        if !self.index_set(key, h, observed_epoch) {
            // a put/remove invalidated the key while the store fetch
            // was in flight: the stale mapping must not commit (the
            // content entry stays as unreferenced dedup fodder)
            self.deref_content(h, key);
        }
        self.clean_evicted(evicted);
    }

    /// Coherence + dedup hook for `Dfs::put`: the key's old mapping is
    /// invalidated (its content may have changed); if byte-identical
    /// content is already resident, the key aliases it so this
    /// tenant's reads hit without refetching.
    pub fn register_put(&self, key: &str, data: &Arc<Vec<u8>>) {
        let h = content_hash(data);
        // Re-putting identical content (e.g. the adaptive-RF re-pin
        // sweep re-staging every key) is a mapping no-op: don't drop
        // the key (readers would take a spurious miss) and don't count
        // an invalidation or a dedup hit.
        if data.len() <= self.max_object {
            let existing = {
                let ix = self.index[self.ishard(key)].lock().unwrap();
                ix.map.get(key).copied()
            };
            if existing == Some(h) {
                let mut s = self.data[self.dshard(h)].lock().unwrap();
                let same = s
                    .entries
                    .get(&h)
                    .is_some_and(|e| *e.data == **data);
                if same {
                    s.touch(h);
                    return;
                }
            }
        }
        self.remove_key(key);
        if data.len() > self.max_object {
            return;
        }
        let aliased = {
            let mut s = self.data[self.dshard(h)].lock().unwrap();
            let aliased = match s.entries.get_mut(&h) {
                Some(e) if *e.data == **data => {
                    if !e.keys.iter().any(|k| k == key) {
                        e.keys.push(key.to_string());
                    }
                    true
                }
                _ => false,
            };
            if aliased {
                s.touch(h);
            }
            aliased
        };
        if aliased {
            self.dedup_hits.fetch_add(1, Ordering::Relaxed);
            self.index_set(key, h, None);
        }
    }

    /// Drop `key`'s mapping (invalidation). The content stays resident
    /// for other keys — and, unreferenced, as first-in-line eviction
    /// fodder that still warms a later identical tenant.
    pub fn remove_key(&self, key: &str) {
        let old = {
            let mut ix = self.index[self.ishard(key)].lock().unwrap();
            // bump even when no mapping exists: an in-flight fill may
            // be about to commit bytes fetched before this removal
            ix.epoch += 1;
            ix.map.remove(key)
        };
        if let Some(h) = old {
            self.invalidated.fetch_add(1, Ordering::Relaxed);
            self.deref_content(h, key);
        }
    }

    /// Drop every key mapping under `prefix` (tenant cleanup).
    pub fn purge_prefix(&self, prefix: &str) {
        for ix in &self.index {
            let removed: Vec<(String, u64)> = {
                let mut s = ix.lock().unwrap();
                s.epoch += 1;
                let gone: Vec<String> = s
                    .map
                    .keys()
                    .filter(|k| k.starts_with(prefix))
                    .cloned()
                    .collect();
                gone.into_iter()
                    .filter_map(|k| s.map.remove(&k).map(|h| (k, h)))
                    .collect()
            };
            for (k, h) in removed {
                self.invalidated.fetch_add(1, Ordering::Relaxed);
                self.deref_content(h, &k);
            }
        }
    }

    /// True iff `key` currently maps to resident content.
    pub fn contains_key(&self, key: &str) -> bool {
        let h = {
            let ix = self.index[self.ishard(key)].lock().unwrap();
            ix.map.get(key).copied()
        };
        match h {
            Some(h) => {
                let s = self.data[self.dshard(h)].lock().unwrap();
                s.entries.contains_key(&h)
            }
            None => false,
        }
    }

    pub fn stats(&self) -> CacheStats {
        let mut resident_bytes = 0u64;
        let mut resident_blocks = 0u64;
        for d in &self.data {
            let s = d.lock().unwrap();
            resident_bytes += s.bytes as u64;
            resident_blocks += s.entries.len() as u64;
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserted: self.inserted.load(Ordering::Relaxed),
            dedup_hits: self.dedup_hits.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            invalidated: self.invalidated.load(Ordering::Relaxed),
            resident_bytes,
            resident_blocks,
        }
    }

    /// Commit `key` → `h`. With `expected_epoch` set (a read-through
    /// fill), the commit is refused — returning false — when the
    /// shard's invalidation epoch moved since the caller snapshotted
    /// it, i.e. when the fetched bytes may predate a `put`/`remove`.
    fn index_set(
        &self,
        key: &str,
        h: u64,
        expected_epoch: Option<u64>,
    ) -> bool {
        let old = {
            let mut ix = self.index[self.ishard(key)].lock().unwrap();
            if let Some(e0) = expected_epoch {
                if ix.epoch != e0 {
                    return false;
                }
            }
            ix.map.insert(key.to_string(), h)
        };
        if let Some(oh) = old {
            if oh != h {
                self.invalidated.fetch_add(1, Ordering::Relaxed);
                self.deref_content(oh, key);
            }
        }
        true
    }

    /// Unlink `key` from content `h` (the entry itself stays resident).
    fn deref_content(&self, h: u64, key: &str) {
        let mut s = self.data[self.dshard(h)].lock().unwrap();
        if let Some(e) = s.entries.get_mut(&h) {
            e.keys.retain(|k| k != key);
        }
    }

    /// After an eviction, drop the evictees' index mappings (done
    /// outside the data-shard lock, so the two lock families never
    /// nest).
    fn clean_evicted(&self, evicted: Vec<(u64, Vec<String>)>) {
        for (h, keys) in evicted {
            self.evicted.fetch_add(1, Ordering::Relaxed);
            for k in keys {
                let mut ix = self.index[self.ishard(&k)].lock().unwrap();
                if ix.map.get(&k) == Some(&h) {
                    ix.map.remove(&k);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(fill: u8, len: usize) -> Arc<Vec<u8>> {
        Arc::new(vec![fill; len])
    }

    #[test]
    fn insert_get_round_trip_counts_hits() {
        let c = BlockCache::new(1 << 20, 4);
        assert!(c.get("a").is_none());
        c.insert("a", &block(1, 100));
        let got = c.get("a").unwrap();
        assert_eq!(got[0], 1);
        let st = c.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        assert_eq!(st.resident_blocks, 1);
        assert_eq!(st.resident_bytes, 100);
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn budget_evicts_oldest_probation_first() {
        // one shard, 1000-byte budget, 250-byte max object
        let c = BlockCache::new(1000, 1);
        for i in 0..4 {
            c.insert(&format!("k{i}"), &block(i as u8, 240));
        }
        // promote k1 to protected
        assert!(c.get("k1").is_some());
        // two more inserts overflow the budget twice; k0 (oldest
        // probation) and k2 go, protected k1 survives
        c.insert("k4", &block(4, 240));
        c.insert("k5", &block(5, 240));
        assert!(c.contains_key("k1"), "protected entry evicted");
        assert!(!c.contains_key("k0"), "oldest probation survived");
        let st = c.stats();
        assert_eq!(st.evicted, 2);
        assert!(st.resident_bytes <= 1000);
    }

    #[test]
    fn admission_rejects_oversized_objects() {
        let c = BlockCache::new(1000, 1); // max object = 250
        c.insert("big", &block(9, 600));
        assert!(!c.contains_key("big"));
        assert_eq!(c.stats().rejected, 1);
        assert_eq!(c.stats().resident_bytes, 0);
    }

    #[test]
    fn identical_content_dedupes_across_keys() {
        let c = BlockCache::new(1 << 20, 4);
        c.insert("j1/b0", &block(7, 500));
        c.insert("j2/b0", &block(7, 500));
        let st = c.stats();
        assert_eq!(st.resident_blocks, 1, "same bytes stored twice");
        assert_eq!(st.resident_bytes, 500);
        assert_eq!(st.dedup_hits, 1);
        // both keys serve the shared bytes
        assert_eq!(c.get("j1/b0").unwrap()[0], 7);
        assert_eq!(c.get("j2/b0").unwrap()[0], 7);
        // dropping one alias keeps the other readable
        c.remove_key("j1/b0");
        assert!(!c.contains_key("j1/b0"));
        assert_eq!(c.get("j2/b0").unwrap()[0], 7);
    }

    #[test]
    fn register_put_aliases_resident_content_only() {
        let c = BlockCache::new(1 << 20, 4);
        // nothing resident: a put registers no mapping
        c.register_put("j1/b0", &block(3, 64));
        assert!(!c.contains_key("j1/b0"));
        // a read-through fill makes the content resident...
        c.insert("j1/b0", &block(3, 64));
        // ...so a second tenant staging identical bytes goes warm
        c.register_put("j2/b0", &block(3, 64));
        assert!(c.contains_key("j2/b0"));
        assert_eq!(c.stats().dedup_hits, 1);
        assert_eq!(c.stats().resident_blocks, 1);
    }

    #[test]
    fn identical_reput_is_a_mapping_noop() {
        // the adaptive-RF re-pin sweep re-puts every key with the
        // same bytes: no invalidation, no dedup hit, mapping intact
        let c = BlockCache::new(1 << 20, 2);
        c.insert("k", &block(4, 80));
        let before = c.stats();
        c.register_put("k", &block(4, 80));
        let after = c.stats();
        assert!(c.contains_key("k"), "re-put dropped the mapping");
        assert_eq!(after.invalidated, before.invalidated);
        assert_eq!(after.dedup_hits, before.dedup_hits);
        assert_eq!(after.resident_blocks, 1);
    }

    #[test]
    fn overwrite_invalidates_the_old_mapping() {
        let c = BlockCache::new(1 << 20, 2);
        c.insert("k", &block(1, 50));
        // the key's content changes: register_put must not let the
        // cache keep serving the old bytes
        c.register_put("k", &block(2, 50));
        assert!(
            !c.contains_key("k"),
            "stale mapping survived an overwrite"
        );
        assert!(c.stats().invalidated >= 1);
    }

    #[test]
    fn purge_prefix_clears_one_namespace_only() {
        let c = BlockCache::new(1 << 20, 4);
        for i in 0..4 {
            c.insert(&format!("j1/b{i}"), &block(i as u8, 40 + i));
            c.insert(&format!("j2/b{i}"), &block(10 + i as u8, 80 + i));
        }
        c.purge_prefix("j1/");
        for i in 0..4 {
            assert!(!c.contains_key(&format!("j1/b{i}")));
            assert!(c.contains_key(&format!("j2/b{i}")));
        }
    }

    #[test]
    fn unreferenced_content_warms_a_later_identical_key() {
        let c = BlockCache::new(1 << 20, 2);
        c.insert("j1/b0", &block(5, 128));
        c.remove_key("j1/b0");
        // the bytes are unreferenced but resident: a new tenant
        // staging the same content aliases them instead of refetching
        c.register_put("j9/b0", &block(5, 128));
        assert!(c.contains_key("j9/b0"));
        assert_eq!(c.get("j9/b0").unwrap().len(), 128);
    }

    #[test]
    fn stale_fill_is_refused_after_a_racing_invalidation() {
        // simulate the read-through race: a fill whose bytes were
        // fetched before a put/remove landed must not commit
        let c = BlockCache::new(1 << 20, 2);
        c.insert("k", &block(1, 50));
        let epoch = c.key_epoch("k");
        // the "concurrent" invalidation (Dfs::remove / overwrite)
        c.remove_key("k");
        // the in-flight fill resumes with pre-invalidation bytes
        c.fill("k", &block(1, 50), epoch);
        assert!(
            !c.contains_key("k"),
            "stale fill resurrected a removed key"
        );
        // a fresh fill (snapshotted after the invalidation) commits
        let epoch = c.key_epoch("k");
        c.fill("k", &block(2, 50), epoch);
        assert_eq!(c.get("k").unwrap()[0], 2);
    }

    #[test]
    fn content_hash_is_stable_and_content_sensitive() {
        let a = content_hash(&[1, 2, 3]);
        assert_eq!(a, content_hash(&[1, 2, 3]));
        assert_ne!(a, content_hash(&[1, 2, 4]));
    }
}
