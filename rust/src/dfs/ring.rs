//! Consistent-hash ring with virtual nodes (the Cassandra-style placement
//! substrate under the data distribution layer, thesis §3.5 / [44]).
//!
//! The BTS data layer starts from *full replication on a few data nodes*
//! and adapts the replication factor; the ring provides the general
//! placement primitive: `replicas(key, rf)` walks clockwise from the
//! key's position over distinct physical nodes.

use crate::util::rng::{fnv1a, mix64};

/// fnv1a mixes short, similar strings poorly in the high bits the ring
/// orders by; finish with the shared avalanche.
#[inline]
fn ring_hash(bytes: &[u8]) -> u64 {
    mix64(fnv1a(bytes))
}

#[derive(Debug, Clone)]
pub struct Ring {
    /// sorted (hash, node) points
    points: Vec<(u64, usize)>,
    nodes: usize,
    vnodes: usize,
}

impl Ring {
    pub fn new(nodes: usize, vnodes: usize) -> Self {
        assert!(nodes > 0 && vnodes > 0);
        let mut points = Vec::with_capacity(nodes * vnodes);
        for n in 0..nodes {
            for v in 0..vnodes {
                let h = ring_hash(format!("node{n}#v{v}").as_bytes());
                points.push((h, n));
            }
        }
        points.sort_unstable();
        points.dedup_by_key(|p| p.0);
        Ring { points, nodes, vnodes }
    }

    pub fn node_count(&self) -> usize {
        self.nodes
    }

    /// Primary owner of `key`.
    pub fn primary(&self, key: &str) -> usize {
        self.replicas(key, 1)[0]
    }

    /// First `rf` *distinct* nodes clockwise from the key's hash.
    pub fn replicas(&self, key: &str, rf: usize) -> Vec<usize> {
        let rf = rf.clamp(1, self.nodes);
        let h = ring_hash(key.as_bytes());
        let start = self
            .points
            .partition_point(|&(ph, _)| ph < h)
            % self.points.len();
        let mut out = Vec::with_capacity(rf);
        for i in 0..self.points.len() {
            let (_, n) = self.points[(start + i) % self.points.len()];
            if !out.contains(&n) {
                out.push(n);
                if out.len() == rf {
                    break;
                }
            }
        }
        out
    }

    /// The hash arcs `node` owns, as half-open `(from, to]` intervals
    /// on the ring (with `from > to` marking the single wrap-around
    /// arc through `u64::MAX`/0). A key hashing into one of these arcs
    /// has `node` as its [`Ring::primary`]. Used by the front-door's
    /// shard map display and for cache-footprint accounting: summing
    /// arc widths over `u64::MAX` approximates the node's key share.
    pub fn owned(&self, node: usize) -> Vec<(u64, u64)> {
        assert!(node < self.nodes, "node {node} out of range");
        let len = self.points.len();
        let mut arcs = Vec::new();
        for i in 0..len {
            let (h, n) = self.points[i];
            if n != node {
                continue;
            }
            let prev = self.points[(i + len - 1) % len].0;
            // prev == h only in a one-point ring: that node owns
            // everything, represented as the full wrap arc.
            arcs.push((prev, h));
        }
        arcs
    }

    /// Add a node (used by the adaptive replication controller when it
    /// widens the data-node set).
    pub fn grow(&self) -> Ring {
        Ring::new(self.nodes + 1, self.vnodes)
    }

    /// Drop the highest-numbered node (elastic membership shrink).
    /// Vnode positions are per-node and independent of the node count,
    /// so survivors keep every key they already own — only the
    /// departed node's ~1/n share re-homes, without refetching
    /// anything the survivors have cached.
    pub fn shrink(&self) -> Ring {
        assert!(self.nodes > 1, "cannot shrink a one-node ring");
        Ring::new(self.nodes - 1, self.vnodes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;
    use std::collections::HashMap;

    #[test]
    fn replicas_distinct_and_bounded() {
        let r = Ring::new(5, 32);
        for k in 0..100 {
            let reps = r.replicas(&format!("key{k}"), 3);
            assert_eq!(reps.len(), 3);
            let mut d = reps.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 3);
            assert!(reps.iter().all(|&n| n < 5));
        }
    }

    #[test]
    fn rf_clamped_to_node_count() {
        let r = Ring::new(3, 16);
        assert_eq!(r.replicas("x", 10).len(), 3);
        assert_eq!(r.replicas("x", 0).len(), 1);
    }

    #[test]
    fn balanced_within_factor() {
        let r = Ring::new(6, 64);
        let mut counts = HashMap::new();
        for k in 0..6000 {
            *counts.entry(r.primary(&format!("blk:{k}"))).or_insert(0usize) += 1;
        }
        let min = counts.values().min().copied().unwrap_or(0);
        let max = counts.values().max().copied().unwrap();
        assert!(counts.len() == 6, "some node owns nothing: {counts:?}");
        assert!(
            max < min * 4,
            "imbalance too high: min {min} max {max}"
        );
    }

    #[test]
    fn prop_growth_is_mostly_monotone() {
        // consistent hashing: adding a node remaps only a bounded share
        // of keys
        check("ring growth monotone", 20, |rng| {
            let n = rng.range(3, 10) as usize;
            let r1 = Ring::new(n, 48);
            let r2 = r1.grow();
            let total = 2000;
            let mut moved = 0;
            for k in 0..total {
                let key = format!("k{k}");
                let a = r1.primary(&key);
                let b = r2.primary(&key);
                if a != b {
                    // keys may only move to the NEW node under growth
                    prop_assert!(
                        b == n,
                        "key moved between old nodes {a}->{b} (n={n})"
                    );
                    moved += 1;
                }
            }
            let expected = total / (n + 1);
            prop_assert!(
                moved < expected * 3,
                "too many keys moved: {moved} vs expected ~{expected}"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_shrink_remaps_bounded() {
        // removing one node strands only that node's keys: survivors
        // keep their owner (vnode positions are per-node, independent
        // of the node count), and the moved share is ~1/n
        check("ring shrink monotone", 20, |rng| {
            let n = rng.range(4, 11) as usize;
            let big = Ring::new(n, 48);
            let small = big.shrink();
            let total = 2000;
            let mut moved = 0;
            for k in 0..total {
                let key = format!("s{k}");
                let a = big.primary(&key);
                let b = small.primary(&key);
                if a != b {
                    prop_assert!(
                        a == n - 1,
                        "key left a surviving node {a}->{b} (n={n})"
                    );
                    moved += 1;
                }
            }
            let expected = total / n;
            prop_assert!(
                moved < expected * 3,
                "too many keys moved on shrink: {moved} vs ~{expected}"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_replica_sets_distinct_and_stable_under_growth() {
        // replica sets are duplicate-free at every (n, rf), and
        // growing the ring by one node disturbs each set by at most
        // one member (the walk sequence only gains the new node)
        check("ring replica sets", 20, |rng| {
            let n = rng.range(3, 9) as usize;
            let rf = rng.range(2, (n as u64).min(4) + 1) as usize;
            let r1 = Ring::new(n, 48);
            let r2 = r1.grow();
            for k in 0..300 {
                let key = format!("r{k}");
                let old = r1.replicas(&key, rf);
                let new = r2.replicas(&key, rf);
                for set in [&old, &new] {
                    let mut d = (*set).clone();
                    d.sort_unstable();
                    d.dedup();
                    prop_assert!(
                        d.len() == rf,
                        "replica set has duplicates: {set:?} (rf={rf})"
                    );
                }
                let lost = old
                    .iter()
                    .filter(|&&m| !new.contains(&m))
                    .count();
                prop_assert!(
                    lost <= 1,
                    "growth displaced {lost} replicas: {old:?} -> {new:?}"
                );
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "cannot shrink")]
    fn shrink_below_one_node_panics() {
        let _ = Ring::new(1, 8).shrink();
    }

    /// membership test against the `(from, to]`-with-wrap encoding
    fn arc_contains(arcs: &[(u64, u64)], h: u64) -> bool {
        arcs.iter().any(|&(from, to)| {
            if from < to {
                h > from && h <= to
            } else {
                // wrap-around arc through u64::MAX/0
                h > from || h <= to
            }
        })
    }

    #[test]
    fn prop_owned_arcs_agree_with_primary() {
        check("ring owned arcs", 20, |rng| {
            let n = rng.range(2, 8) as usize;
            let r = Ring::new(n, 32);
            let per_node: Vec<Vec<(u64, u64)>> =
                (0..n).map(|node| r.owned(node)).collect();
            for k in 0..500 {
                let key = format!("own{k}");
                let h = ring_hash(key.as_bytes());
                let p = r.primary(&key);
                prop_assert!(
                    arc_contains(&per_node[p], h),
                    "primary {p} of key {key} not in its owned arcs"
                );
                for (node, arcs) in per_node.iter().enumerate() {
                    if node != p {
                        prop_assert!(
                            !arc_contains(arcs, h),
                            "key {key} in arcs of non-primary {node}"
                        );
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn owned_arcs_cover_the_whole_ring_exactly_once() {
        let r = Ring::new(5, 48);
        let mut all: Vec<(u64, u64)> =
            (0..5).flat_map(|n| r.owned(n)).collect();
        // exactly one wrap arc, and sorted by endpoint the arcs chain:
        // each arc starts where the previous one ended
        let wraps = all.iter().filter(|&&(f, t)| f >= t).count();
        assert_eq!(wraps, 1, "expected one wrap-around arc");
        all.sort_unstable_by_key(|&(_, to)| to);
        for w in all.windows(2) {
            assert_eq!(
                w[1].0, w[0].1,
                "gap or overlap between arcs {:?} and {:?}",
                w[0], w[1]
            );
        }
        let last = all.last().unwrap();
        let first = all.first().unwrap();
        assert_eq!(first.0, last.1, "ring does not close");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn owned_rejects_unknown_node() {
        let _ = Ring::new(3, 8).owned(3);
    }

    #[test]
    fn deterministic() {
        let a = Ring::new(4, 16);
        let b = Ring::new(4, 16);
        for k in 0..50 {
            let key = format!("z{k}");
            assert_eq!(a.replicas(&key, 2), b.replicas(&key, 2));
        }
    }
}
