//! Prefetcher: hide data-fetch latency behind task execution.
//!
//! Thesis §3.5: "Since the tasks are assigned in groups, we pre-fetch the
//! data based on scheduler. While a task is being processed, data
//! required for the next k tasks are pre-fetched. K is decided
//! dynamically from the average data fetch time and average task
//! execution time."

use std::collections::HashMap;
use std::sync::Arc;

use super::client::{BlockSource, CacheLookup};
use crate::cache::AffinityIndex;
use crate::error::Result;
use crate::util::stats::Ewma;

/// Dynamic prefetch depth: enough fetches in flight to cover one task's
/// execution window, clamped.
pub fn prefetch_depth(avg_fetch_s: f64, avg_exec_s: f64, max_k: usize) -> usize {
    if avg_exec_s <= 0.0 {
        return 1;
    }
    let k = (avg_fetch_s / avg_exec_s).ceil() as usize + 1;
    k.clamp(1, max_k.max(1))
}

/// Worker-local block cache fed ahead of execution. Single-threaded by
/// design — each worker owns one (fetches happen between task executions
/// on the worker's thread; the *k* depth bounds how far ahead it reads).
/// Generic over the [`BlockSource`] data plane: the local replicated
/// store for in-proc workers, a leader-proxied socket path for remote
/// ones — prefetch depth, hit accounting and affinity recording are
/// transport-independent.
pub struct Prefetcher {
    src: Arc<dyn BlockSource>,
    cache: HashMap<String, Arc<Vec<u8>>>,
    /// keys queued but not yet fetched, in task order
    pending: std::collections::VecDeque<String>,
    pub max_k: usize,
    fetch_ewma: Ewma,
    exec_ewma: Ewma,
    pub hits: u64,
    pub misses: u64,
    /// Shared-cache ([`crate::cache::BlockCache`]) outcomes, counted
    /// only when the store has a cache attached.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// (worker id, registry) — every fetched key is recorded so the
    /// scheduler's refill step can route tasks back to this worker.
    affinity: Option<(usize, Arc<AffinityIndex>)>,
}

impl Prefetcher {
    pub fn new(src: Arc<dyn BlockSource>, max_k: usize) -> Self {
        Prefetcher {
            src,
            cache: HashMap::new(),
            pending: std::collections::VecDeque::new(),
            max_k,
            fetch_ewma: Ewma::new(0.3),
            exec_ewma: Ewma::new(0.3),
            hits: 0,
            misses: 0,
            cache_hits: 0,
            cache_misses: 0,
            affinity: None,
        }
    }

    /// Record this worker's fetches in `index` (cache-affinity
    /// dispatch feeds off it).
    pub fn with_affinity(
        mut self,
        worker: usize,
        index: Arc<AffinityIndex>,
    ) -> Self {
        self.affinity = Some((worker, index));
        self
    }

    /// Account one store fetch: shared-cache outcome + affinity.
    fn note_fetch(&mut self, key: &str, lookup: CacheLookup) {
        match lookup {
            CacheLookup::Hit => self.cache_hits += 1,
            CacheLookup::Miss => self.cache_misses += 1,
            CacheLookup::Unattached => {}
        }
        if let Some((worker, index)) = &self.affinity {
            index.record(*worker, key);
        }
    }

    /// Enqueue upcoming block keys (in the order tasks will run).
    pub fn enqueue(&mut self, keys: impl IntoIterator<Item = String>) {
        self.pending.extend(keys);
    }

    /// Record a task execution time (feeds the dynamic k).
    pub fn observe_exec(&mut self, secs: f64) {
        self.exec_ewma.observe(secs);
    }

    pub fn depth(&self) -> usize {
        prefetch_depth(
            self.fetch_ewma.get_or(1e-4),
            self.exec_ewma.get_or(1e-3),
            self.max_k,
        )
    }

    /// Pull queued blocks into the cache up to the current depth. Called
    /// between task executions ("while a task is being processed, data
    /// can be fetched for the tasks in the queue").
    pub fn pump(&mut self) -> Result<()> {
        let want = self.depth().saturating_sub(self.cache.len());
        for _ in 0..want {
            let Some(key) = self.pending.pop_front() else { break };
            if self.cache.contains_key(&key) {
                continue;
            }
            let (data, secs, lookup) = self.src.get_traced(&key)?;
            self.fetch_ewma.observe(secs);
            self.note_fetch(&key, lookup);
            self.cache.insert(key, data);
        }
        Ok(())
    }

    /// Fetch a block for immediate use: from cache if prefetched,
    /// otherwise synchronously (a prefetch miss — the task waits).
    pub fn take(&mut self, key: &str) -> Result<Arc<Vec<u8>>> {
        if let Some(data) = self.cache.remove(key) {
            self.hits += 1;
            // still this worker's block — keep its affinity fresh
            if let Some((worker, index)) = &self.affinity {
                index.record(*worker, key);
            }
            return Ok(data);
        }
        self.misses += 1;
        // remove from pending if queued (we're fetching it now)
        if let Some(pos) = self.pending.iter().position(|k| k == key) {
            self.pending.remove(pos);
        }
        let (data, secs, lookup) = self.src.get_traced(key)?;
        self.fetch_ewma.observe(secs);
        self.note_fetch(key, lookup);
        Ok(data)
    }

    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Drop every queued and cached key under `prefix`. The full
    /// tenant-cleanup purge: the worker-local queue and buffer, the
    /// store's shared block cache, and the affinity registry — a
    /// departing tenant leaves no key mappings behind anywhere.
    ///
    /// Pool workers aborting a job attempt use
    /// [`Prefetcher::purge_prefix_local`] instead: the job's staged
    /// blocks are unchanged across attempts, so its shared-cache
    /// entries stay coherent and keep the restart warm — the shared
    /// purge runs once, at tenant retirement.
    pub fn purge_prefix(&mut self, prefix: &str) {
        self.purge_prefix_local(prefix);
        self.src.cache_purge_prefix(prefix);
        if let Some((_, index)) = &self.affinity {
            index.forget_prefix(prefix);
        }
    }

    /// The worker-local half of [`Prefetcher::purge_prefix`]: clears
    /// only this prefetcher's pending queue and buffered blocks.
    pub fn purge_prefix_local(&mut self, prefix: &str) {
        self.pending.retain(|k| !k.starts_with(prefix));
        self.cache.retain(|k, _| !k.starts_with(prefix));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::store::LatencyModel;
    use crate::dfs::Dfs;

    #[test]
    fn depth_grows_with_fetch_time() {
        assert_eq!(prefetch_depth(0.001, 0.010, 8), 2);
        assert_eq!(prefetch_depth(0.010, 0.010, 8), 2);
        assert_eq!(prefetch_depth(0.050, 0.010, 8), 6);
        assert_eq!(prefetch_depth(10.0, 0.010, 8), 8); // clamped
        assert_eq!(prefetch_depth(0.0, 0.010, 8), 1);
    }

    fn dfs_with_blocks(n: usize) -> Arc<Dfs> {
        let d = Dfs::new(2, 2, LatencyModel::none());
        for k in 0..n {
            d.put(&format!("b{k}"), Arc::new(vec![k as u8; 128]));
        }
        d
    }

    #[test]
    fn pump_then_take_hits() {
        let d = dfs_with_blocks(10);
        let mut p = Prefetcher::new(d, 8);
        p.enqueue((0..10).map(|k| format!("b{k}")));
        p.observe_exec(0.01);
        p.pump().unwrap();
        assert!(p.cached() >= 1);
        let first_cached = p.cached();
        let data = p.take("b0").unwrap();
        assert_eq!(data[0], 0);
        assert_eq!(p.hits + p.misses, 1);
        assert!(p.cached() <= first_cached);
    }

    #[test]
    fn take_without_prefetch_still_works() {
        let d = dfs_with_blocks(3);
        let mut p = Prefetcher::new(d, 4);
        let data = p.take("b2").unwrap();
        assert_eq!(data[0], 2);
        assert_eq!(p.misses, 1);
    }

    #[test]
    fn sequential_workflow_mostly_hits() {
        let d = dfs_with_blocks(32);
        let mut p = Prefetcher::new(d, 8);
        p.enqueue((0..32).map(|k| format!("b{k}")));
        // simulate slow-ish fetches vs fast tasks => small k, but pump
        // before each take keeps the next block ready
        for k in 0..32 {
            p.pump().unwrap();
            p.take(&format!("b{k}")).unwrap();
            p.observe_exec(0.002);
        }
        assert!(
            p.hits >= 28,
            "expected mostly prefetch hits, got {} hits {} misses",
            p.hits,
            p.misses
        );
    }

    #[test]
    fn purge_prefix_clears_one_namespace_only() {
        let d = Dfs::new(2, 2, LatencyModel::none());
        for k in 0..4 {
            d.put(&format!("j1/b{k}"), Arc::new(vec![1u8; 32]));
            d.put(&format!("j2/b{k}"), Arc::new(vec![2u8; 32]));
        }
        let mut p = Prefetcher::new(d, 8);
        p.enqueue((0..4).map(|k| format!("j1/b{k}")));
        p.enqueue((0..4).map(|k| format!("j2/b{k}")));
        p.observe_exec(0.01);
        p.pump().unwrap();
        p.purge_prefix("j1/");
        // all of j1 is gone from cache and pending; j2 still flows
        assert!(p.take("j2/b0").is_ok());
        let hits_before = p.hits;
        p.pump().unwrap();
        for k in 1..4 {
            p.take(&format!("j2/b{k}")).unwrap();
        }
        assert!(p.hits > hits_before || p.misses > 0);
        // purged keys are refetchable (they were only evicted locally)
        assert!(p.take("j1/b0").is_ok());
    }

    #[test]
    fn shared_cache_counters_and_affinity_recording() {
        let d = dfs_with_blocks(8);
        d.attach_cache(Arc::new(crate::cache::BlockCache::new(1 << 20, 2)));
        let index = Arc::new(AffinityIndex::new(1024));
        let mut p = Prefetcher::new(d.clone(), 4).with_affinity(3, index.clone());
        // cold pass: every store fetch is a shared-cache miss
        for k in 0..8 {
            p.take(&format!("b{k}")).unwrap();
        }
        assert_eq!(p.cache_hits, 0);
        assert_eq!(p.cache_misses, 8);
        // every fetched key is now attributed to worker 3
        assert_eq!(index.owner("b0"), Some(3));
        assert_eq!(index.owner("b7"), Some(3));
        // warm pass: served by the shared cache
        for k in 0..8 {
            p.take(&format!("b{k}")).unwrap();
        }
        assert_eq!(p.cache_hits, 8);
        // purging a prefix forgets its affinity entries too
        p.purge_prefix("b");
        assert_eq!(index.owner("b0"), None);
        assert!(!d.cache().unwrap().contains_key("b0"));
    }

    #[test]
    fn missing_block_propagates_error() {
        let d = dfs_with_blocks(1);
        let mut p = Prefetcher::new(d, 2);
        assert!(p.take("ghost").is_err());
    }
}
