//! "CassandraLite" — the scalable distributed in-memory data layer
//! (thesis §3.5, Fig 7, built on Cassandra in the original system [44]).
//!
//! Components:
//! - `ring`:        consistent-hash placement with virtual nodes
//! - `store`:       in-memory data nodes with a service-time model
//! - `client`:      response-time-aware replica selection (`Dfs`)
//! - `replication`: the adaptive replication-factor controller
//! - `prefetch`:    scheduler-driven prefetching with dynamic depth k

pub mod client;
pub mod prefetch;
pub mod replication;
pub mod ring;
pub mod store;

pub use client::{BlockSource, CacheLookup, Dfs};

/// Key prefix isolating one job's blocks in a shared store. The serve
/// layer multiplexes many tenants over a single [`Dfs`]; prefixing every
/// block key with the job id keeps two in-flight jobs that stage the
/// same sample ids from colliding. Solo `exec` runs (one private store
/// per job) use the empty namespace `""`.
pub fn job_ns(job: u64) -> String {
    format!("j{job}/")
}
pub use prefetch::{prefetch_depth, Prefetcher};
pub use replication::{
    decide, initial_data_nodes, ControllerState, ReplicationPolicy,
};
pub use ring::Ring;
pub use store::{DataNode, LatencyModel};
