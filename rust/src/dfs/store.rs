//! In-memory data node — one member of the "CassandraLite" store.
//!
//! Thesis §3.5: "we need a distributed in-memory storage system that
//! would have significantly low fetch time compared to job execution
//! time". Each node holds immutable blocks behind an RwLock; fetches are
//! cheap Arc clones. An optional service-time model (base + per-MB +
//! load penalty) lets experiments reproduce the response-time dynamics
//! that drive adaptive replication, without needing a real remote
//! cluster.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

use crate::error::{Error, Result};

/// Service-time model for one node.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// Fixed per-request overhead (network RTT + lookup), seconds.
    pub base_s: f64,
    /// Transfer time per MiB, seconds.
    pub per_mib_s: f64,
    /// Extra delay per concurrent in-flight request (queueing).
    pub per_inflight_s: f64,
    /// Actually sleep for the modeled duration (end-to-end experiments)
    /// vs just report it (fast unit tests / benches).
    pub sleep: bool,
}

impl LatencyModel {
    /// Instant fetches; still tracks counters.
    pub fn none() -> Self {
        LatencyModel { base_s: 0.0, per_mib_s: 0.0, per_inflight_s: 0.0, sleep: false }
    }

    /// A LAN-attached in-memory store (the platform's intended regime).
    pub fn lan() -> Self {
        LatencyModel {
            base_s: 120e-6,
            per_mib_s: 8e-3, // ~1 Gb/s
            per_inflight_s: 60e-6,
            sleep: true,
        }
    }
}

pub struct DataNode {
    pub id: usize,
    blocks: RwLock<HashMap<String, Arc<Vec<u8>>>>,
    latency: LatencyModel,
    inflight: AtomicUsize,
    pub fetches: AtomicU64,
    pub bytes_served: AtomicU64,
}

impl DataNode {
    pub fn new(id: usize, latency: LatencyModel) -> Self {
        DataNode {
            id,
            blocks: RwLock::new(HashMap::new()),
            latency,
            inflight: AtomicUsize::new(0),
            fetches: AtomicU64::new(0),
            bytes_served: AtomicU64::new(0),
        }
    }

    pub fn put(&self, key: String, data: Arc<Vec<u8>>) {
        self.blocks.write().unwrap().insert(key, data);
    }

    pub fn remove(&self, key: &str) {
        self.blocks.write().unwrap().remove(key);
    }

    pub fn contains(&self, key: &str) -> bool {
        self.blocks.read().unwrap().contains_key(key)
    }

    pub fn block_count(&self) -> usize {
        self.blocks.read().unwrap().len()
    }

    /// Snapshot of stored keys (re-replication / tests).
    pub fn keys(&self) -> Vec<String> {
        self.blocks.read().unwrap().keys().cloned().collect()
    }

    pub fn stored_bytes(&self) -> usize {
        self.blocks.read().unwrap().values().map(|b| b.len()).sum()
    }

    /// Fetch a block. Returns (data, modeled_service_seconds).
    pub fn get(&self, key: &str) -> Result<(Arc<Vec<u8>>, f64)> {
        let q = self.inflight.fetch_add(1, Ordering::SeqCst);
        let out = (|| {
            let data = self
                .blocks
                .read()
                .unwrap()
                .get(key)
                .cloned()
                .ok_or_else(|| {
                    Error::Dfs(format!("node {}: missing block {key}", self.id))
                })?;
            let mib = data.len() as f64 / (1024.0 * 1024.0);
            let service = self.latency.base_s
                + mib * self.latency.per_mib_s
                + q as f64 * self.latency.per_inflight_s;
            if self.latency.sleep && service > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(service));
            }
            self.fetches.fetch_add(1, Ordering::Relaxed);
            self.bytes_served
                .fetch_add(data.len() as u64, Ordering::Relaxed);
            Ok((data, service))
        })();
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_round_trip() {
        let n = DataNode::new(0, LatencyModel::none());
        n.put("a".into(), Arc::new(vec![1, 2, 3]));
        let (d, s) = n.get("a").unwrap();
        assert_eq!(*d, vec![1, 2, 3]);
        assert_eq!(s, 0.0);
        assert_eq!(n.fetches.load(Ordering::Relaxed), 1);
        assert_eq!(n.bytes_served.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn missing_block_errors() {
        let n = DataNode::new(1, LatencyModel::none());
        assert!(n.get("nope").is_err());
    }

    #[test]
    fn service_time_scales_with_size() {
        let mut lm = LatencyModel::lan();
        lm.sleep = false; // just model, don't wait
        let n = DataNode::new(0, lm);
        n.put("small".into(), Arc::new(vec![0u8; 1024]));
        n.put("big".into(), Arc::new(vec![0u8; 4 * 1024 * 1024]));
        let (_, s_small) = n.get("small").unwrap();
        let (_, s_big) = n.get("big").unwrap();
        assert!(s_big > 4.0 * s_small, "{s_big} vs {s_small}");
    }

    #[test]
    fn remove_and_contains() {
        let n = DataNode::new(0, LatencyModel::none());
        n.put("k".into(), Arc::new(vec![9]));
        assert!(n.contains("k"));
        n.remove("k");
        assert!(!n.contains("k"));
        assert_eq!(n.block_count(), 0);
    }
}
