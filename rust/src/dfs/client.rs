//! Distributed store client: replica selection by observed response time.
//!
//! Thesis §3.5: "A data modelling engine collects the data fetch time
//! from each node" — per-node EWMAs of response time; `get` prefers the
//! replica with the lowest estimate (with an occasional exploration probe
//! so recovered nodes are rediscovered), and every fetch feeds the
//! estimate back.
//!
//! An optional worker-side [`BlockCache`] sits in front of replica
//! selection ([`Dfs::attach_cache`]): `get` serves cached blocks
//! without touching a data node, fills the cache on a miss, and keeps
//! it coherent — `put` invalidates (and dedup-aliases) the key,
//! `remove` drops it everywhere.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::Instant;

use super::ring::Ring;
use super::store::{DataNode, LatencyModel};
use crate::cache::{BlockCache, CacheStats};
use crate::error::{Error, Result};
use crate::util::stats::Ewma;

/// How the optional shared cache participated in one fetch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheLookup {
    Hit,
    Miss,
    /// No cache attached to this store.
    Unattached,
}

/// A worker's view of the data plane: where its [`super::Prefetcher`]
/// gets blocks from. In-proc workers hold the replicated [`Dfs`]
/// directly; remote workers hold a
/// [`crate::transport::remote::RemoteDfs`] that proxies fetches over
/// the job socket (the leader answers from this same store, so
/// replica selection, response-time EWMAs and the shared block cache
/// still apply to them). Abstracting the source — not the prefetcher
/// — is what lets the data-distribution overhead be a measured,
/// swappable axis.
pub trait BlockSource: Send + Sync {
    /// Fetch one block: (bytes, wall seconds, cache outcome).
    fn get_traced(&self, key: &str)
        -> Result<(Arc<Vec<u8>>, f64, CacheLookup)>;

    /// Drop key mappings under `prefix` from any cache this source
    /// fronts (tenant retirement / job abort). Default: nothing to
    /// purge.
    fn cache_purge_prefix(&self, _prefix: &str) {}
}

impl BlockSource for Dfs {
    fn get_traced(
        &self,
        key: &str,
    ) -> Result<(Arc<Vec<u8>>, f64, CacheLookup)> {
        // Inherent method (takes precedence over the trait's name).
        Dfs::get_traced(self, key)
    }

    fn cache_purge_prefix(&self, prefix: &str) {
        Dfs::cache_purge_prefix(self, prefix);
    }
}

pub struct Dfs {
    pub nodes: Vec<Arc<DataNode>>,
    ring: RwLock<Ring>,
    rf: AtomicUsize,
    /// EWMA of measured wall response time per node (seconds).
    response: Mutex<Vec<Ewma>>,
    /// every Nth fetch probes a non-best replica
    probe_every: u64,
    fetch_seq: AtomicU64,
    /// Optional read-through block cache (set once, before traffic).
    cache: OnceLock<Arc<BlockCache>>,
}

impl Dfs {
    pub fn new(n_nodes: usize, rf: usize, latency: LatencyModel) -> Arc<Self> {
        assert!(n_nodes > 0);
        let nodes = (0..n_nodes)
            .map(|id| Arc::new(DataNode::new(id, latency.clone())))
            .collect();
        Arc::new(Dfs {
            nodes,
            ring: RwLock::new(Ring::new(n_nodes, 64)),
            rf: AtomicUsize::new(rf.clamp(1, n_nodes)),
            response: Mutex::new(vec![Ewma::new(0.3); n_nodes]),
            probe_every: 16,
            fetch_seq: AtomicU64::new(0),
            cache: OnceLock::new(),
        })
    }

    /// Attach a shared read-through block cache. First attach wins;
    /// returns false (and leaves the existing cache) on later calls.
    pub fn attach_cache(&self, cache: Arc<BlockCache>) -> bool {
        self.cache.set(cache).is_ok()
    }

    pub fn cache(&self) -> Option<&Arc<BlockCache>> {
        self.cache.get()
    }

    /// Snapshot of the attached cache's counters, if any.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.get().map(|c| c.stats())
    }

    /// Drop every cached key under `prefix` (tenant cleanup — wired
    /// from [`super::Prefetcher::purge_prefix`]).
    pub fn cache_purge_prefix(&self, prefix: &str) {
        if let Some(c) = self.cache.get() {
            c.purge_prefix(prefix);
        }
    }

    pub fn replication_factor(&self) -> usize {
        self.rf.load(Ordering::SeqCst)
    }

    /// Change the replication factor; re-replicates (or trims) every
    /// stored block to match. Called by the adaptive controller.
    pub fn set_replication_factor(&self, rf: usize) {
        let rf = rf.clamp(1, self.nodes.len());
        let old = self.rf.swap(rf, Ordering::SeqCst);
        if rf == old {
            return;
        }
        // Re-place all keys currently on node 0's view of the world: walk
        // every node's blocks, collect the union of keys, re-pin.
        let mut keys: Vec<(String, Arc<Vec<u8>>)> = Vec::new();
        {
            let ring = self.ring.read().unwrap();
            let mut seen = std::collections::HashSet::new();
            for n in &self.nodes {
                // snapshot keys (cheap: blocks are Arc'd)
                for key in n.keys() {
                    if seen.insert(key.clone()) {
                        if let Ok((data, _)) = self.get_from_replicas(
                            &ring.replicas(&key, self.nodes.len()),
                            &key,
                        ) {
                            keys.push((key, data));
                        }
                    }
                }
            }
        }
        for (key, data) in keys {
            self.put(&key, data);
        }
    }

    /// Store a block on the current replica set.
    pub fn put(&self, key: &str, data: Arc<Vec<u8>>) {
        let rf = self.replication_factor();
        let ring = self.ring.read().unwrap();
        let reps = ring.replicas(key, rf);
        for &n in &reps {
            self.nodes[n].put(key.to_string(), data.clone());
        }
        // trim stale copies beyond the replica set
        for n in 0..self.nodes.len() {
            if !reps.contains(&n) {
                self.nodes[n].remove(key);
            }
        }
        // cache coherence: the key's old mapping is stale now; if the
        // new content is already resident (another tenant staged the
        // same bytes), alias it — the cross-tenant dedup path.
        if let Some(c) = self.cache.get() {
            c.register_put(key, &data);
        }
    }

    /// Delete a key from every node. The serve layer unstages a job's
    /// namespaced blocks through this when the job completes, so a
    /// long-lived shared store does not accumulate dead tenants.
    pub fn remove(&self, key: &str) {
        for n in &self.nodes {
            n.remove(key);
        }
        if let Some(c) = self.cache.get() {
            c.remove_key(key);
        }
    }

    /// Fetch a block from the best replica; records response time.
    pub fn get(&self, key: &str) -> Result<(Arc<Vec<u8>>, f64)> {
        self.get_traced(key).map(|(data, wall, _)| (data, wall))
    }

    /// Like [`Dfs::get`], but reports whether the attached cache
    /// served the block (per-task hit/miss accounting upstream).
    pub fn get_traced(
        &self,
        key: &str,
    ) -> Result<(Arc<Vec<u8>>, f64, CacheLookup)> {
        let Some(cache) = self.cache.get() else {
            let (data, wall) = self.get_uncached(key)?;
            return Ok((data, wall, CacheLookup::Unattached));
        };
        let t = Instant::now();
        // epoch first: if a put/remove lands between this snapshot and
        // the fill below, the fill is refused rather than committing
        // bytes that predate the invalidation
        let epoch = cache.key_epoch(key);
        if let Some(data) = cache.get(key) {
            return Ok((data, t.elapsed().as_secs_f64(), CacheLookup::Hit));
        }
        let (data, wall) = self.get_uncached(key)?;
        cache.fill(key, &data, epoch);
        Ok((data, wall, CacheLookup::Miss))
    }

    fn get_uncached(&self, key: &str) -> Result<(Arc<Vec<u8>>, f64)> {
        let rf = self.replication_factor();
        let reps = self.ring.read().unwrap().replicas(key, rf);
        self.get_from_replicas(&reps, key)
    }

    fn get_from_replicas(
        &self,
        reps: &[usize],
        key: &str,
    ) -> Result<(Arc<Vec<u8>>, f64)> {
        let seq = self.fetch_seq.fetch_add(1, Ordering::Relaxed);
        let choice = {
            let resp = self.response.lock().unwrap();
            let mut order: Vec<usize> = reps.to_vec();
            order.sort_by(|&a, &b| {
                resp[a]
                    .get_or(0.0)
                    .partial_cmp(&resp[b].get_or(0.0))
                    .unwrap()
            });
            if seq % self.probe_every == 0 && order.len() > 1 {
                order[1 + (seq as usize / self.probe_every as usize) % (order.len() - 1)]
            } else {
                order[0]
            }
        };
        let mut last_err = None;
        // try chosen first, fall back over the remaining replicas
        let mut tries = vec![choice];
        tries.extend(reps.iter().copied().filter(|&n| n != choice));
        for n in tries {
            let t = Instant::now();
            match self.nodes[n].get(key) {
                Ok((data, _service)) => {
                    let wall = t.elapsed().as_secs_f64();
                    self.response.lock().unwrap()[n].observe(wall);
                    return Ok((data, wall));
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err
            .unwrap_or_else(|| Error::Dfs(format!("no replicas for {key}"))))
    }

    /// Mean observed response time across nodes that served anything.
    pub fn mean_response(&self) -> Option<f64> {
        let resp = self.response.lock().unwrap();
        let vals: Vec<f64> = resp.iter().filter_map(|e| e.get()).collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f64>() / vals.len() as f64)
        }
    }

    pub fn per_node_response(&self) -> Vec<Option<f64>> {
        self.response.lock().unwrap().iter().map(|e| e.get()).collect()
    }

    pub fn total_fetches(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| n.fetches.load(Ordering::Relaxed))
            .sum()
    }

    /// Total payload bytes served across all data nodes — the job's
    /// data-plane volume (replica re-fetches included).
    pub fn bytes_served(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| n.bytes_served.load(Ordering::Relaxed))
            .sum()
    }

    /// Total bytes resident across every data node, replicas included
    /// — the store's live footprint. Leak tests snapshot this before a
    /// job and assert it returns there after unstaging (blocks *and*
    /// shuffle fragments), including runs that lost a worker mid-
    /// shuffle.
    pub fn stored_bytes(&self) -> usize {
        self.nodes.iter().map(|n| n.stored_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(n: usize, rf: usize) -> Arc<Dfs> {
        Dfs::new(n, rf, LatencyModel::none())
    }

    #[test]
    fn put_get_round_trip() {
        let d = store(4, 2);
        d.put("k1", Arc::new(vec![1, 2, 3]));
        let (data, _) = d.get("k1").unwrap();
        assert_eq!(*data, vec![1, 2, 3]);
    }

    #[test]
    fn replication_factor_controls_copies() {
        let d = store(5, 3);
        for k in 0..40 {
            d.put(&format!("k{k}"), Arc::new(vec![k as u8]));
        }
        let copies: usize = d.nodes.iter().map(|n| n.block_count()).sum();
        assert_eq!(copies, 40 * 3);
    }

    #[test]
    fn set_rf_rereplicates() {
        let d = store(5, 1);
        for k in 0..20 {
            d.put(&format!("k{k}"), Arc::new(vec![k as u8; 10]));
        }
        assert_eq!(
            d.nodes.iter().map(|n| n.block_count()).sum::<usize>(),
            20
        );
        d.set_replication_factor(4);
        assert_eq!(
            d.nodes.iter().map(|n| n.block_count()).sum::<usize>(),
            80
        );
        // every key still readable
        for k in 0..20 {
            assert!(d.get(&format!("k{k}")).is_ok());
        }
        d.set_replication_factor(2);
        assert_eq!(
            d.nodes.iter().map(|n| n.block_count()).sum::<usize>(),
            40
        );
    }

    #[test]
    fn prefers_fast_replica() {
        // two nodes, one artificially slow: after warm-up, the fast one
        // should take the vast majority of fetches.
        let slow = LatencyModel {
            base_s: 3e-3,
            per_mib_s: 0.0,
            per_inflight_s: 0.0,
            sleep: true,
        };
        let nodes = vec![
            Arc::new(DataNode::new(0, LatencyModel::none())),
            Arc::new(DataNode::new(1, slow)),
        ];
        let d = Dfs {
            nodes,
            ring: RwLock::new(Ring::new(2, 64)),
            rf: AtomicUsize::new(2),
            response: Mutex::new(vec![Ewma::new(0.3); 2]),
            probe_every: 16,
            fetch_seq: AtomicU64::new(0),
            cache: OnceLock::new(),
        };
        d.put("x", Arc::new(vec![0u8; 64]));
        for _ in 0..60 {
            d.get("x").unwrap();
        }
        let f0 = d.nodes[0].fetches.load(Ordering::Relaxed);
        let f1 = d.nodes[1].fetches.load(Ordering::Relaxed);
        assert!(f0 > 3 * f1, "fast {f0} vs slow {f1}");
    }

    #[test]
    fn remove_unstages_from_every_node() {
        let d = store(4, 3);
        d.put("gone", Arc::new(vec![7u8; 16]));
        d.put("kept", Arc::new(vec![8u8; 16]));
        d.remove("gone");
        assert!(d.get("gone").is_err());
        assert!(d.get("kept").is_ok());
        assert!(d.nodes.iter().all(|n| !n.contains("gone")));
    }

    #[test]
    fn missing_key_errors() {
        let d = store(3, 2);
        assert!(d.get("ghost").is_err());
    }

    #[test]
    fn read_through_cache_serves_and_stays_coherent() {
        let d = store(3, 2);
        assert!(d.attach_cache(Arc::new(BlockCache::new(1 << 20, 2))));
        assert!(!d.attach_cache(Arc::new(BlockCache::new(1 << 20, 2))));
        d.put("k", Arc::new(vec![1u8; 64]));
        // first read fills, second is served by the cache
        let (_, _, l1) = d.get_traced("k").unwrap();
        let (_, _, l2) = d.get_traced("k").unwrap();
        assert_eq!(l1, CacheLookup::Miss);
        assert_eq!(l2, CacheLookup::Hit);
        let fetches = d.total_fetches();
        d.get("k").unwrap();
        assert_eq!(d.total_fetches(), fetches, "cache hit touched a node");
        // overwrite: the cache must serve the new bytes, not v1
        d.put("k", Arc::new(vec![2u8; 64]));
        let (data, _, _) = d.get_traced("k").unwrap();
        assert_eq!(data[0], 2);
        // remove: the cache must not resurrect a deleted key
        d.remove("k");
        assert!(d.get("k").is_err());
    }

    #[test]
    fn identical_content_dedupes_across_namespaced_keys() {
        let d = store(3, 2);
        d.attach_cache(Arc::new(BlockCache::new(1 << 20, 2)));
        let bytes = vec![9u8; 128];
        d.put("j1/b", Arc::new(bytes.clone()));
        d.get("j1/b").unwrap(); // fill: content now resident
        // a second tenant stages byte-identical content under its own
        // namespace — its very first read must hit the shared copy
        d.put("j2/b", Arc::new(bytes));
        let fetches = d.total_fetches();
        let (_, _, lookup) = d.get_traced("j2/b").unwrap();
        assert_eq!(lookup, CacheLookup::Hit, "second tenant refetched");
        assert_eq!(d.total_fetches(), fetches);
        let st = d.cache_stats().unwrap();
        assert!(st.dedup_hits >= 1, "no dedup recorded: {st:?}");
        assert_eq!(st.resident_blocks, 1);
    }

    #[test]
    fn uncached_store_reports_unattached() {
        let d = store(2, 1);
        d.put("a", Arc::new(vec![1]));
        let (_, _, lookup) = d.get_traced("a").unwrap();
        assert_eq!(lookup, CacheLookup::Unattached);
        assert!(d.cache_stats().is_none());
        d.cache_purge_prefix("a"); // no-op without a cache
    }

    #[test]
    fn mean_response_tracks() {
        let d = store(2, 2);
        assert!(d.mean_response().is_none());
        d.put("a", Arc::new(vec![1]));
        d.get("a").unwrap();
        assert!(d.mean_response().unwrap() >= 0.0);
    }
}
