//! Adaptive replication controller (thesis §3.5, Fig 7).
//!
//! "Since we know the task size and the number worker nodes prior to
//! execution, we decide a few initial data nodes that all worker nodes
//! access. Data is fully replicated across these nodes. Based on the
//! response times from the initial set of data nodes, we estimate the
//! cache interference between task execution and data fetch cycles; the
//! replication factor (number of data nodes) is varied accordingly to
//! meet the SLOs of tiny tasks."
//!
//! Controller: fetch time should stay a small fraction of task execution
//! time ("time needed to read input data should not be a significant
//! factor compared to task durations", §1.1.4). When the observed
//! fetch/exec ratio exceeds the budget, widen the replica set (more data
//! nodes → less queueing per node); when it is far under budget and above
//! the floor, shrink to save memory.

#[derive(Debug, Clone)]
pub struct ReplicationPolicy {
    /// Target ceiling for fetch_time / exec_time.
    pub budget: f64,
    /// Shrink when the ratio falls below `budget * shrink_margin`.
    pub shrink_margin: f64,
    pub min_rf: usize,
    pub max_rf: usize,
    /// Consecutive over-budget observations required before growing
    /// (hysteresis against transient spikes — cf. replication-for-
    /// predictability works [3],[32]).
    pub patience: u32,
}

impl Default for ReplicationPolicy {
    fn default() -> Self {
        ReplicationPolicy {
            budget: 0.25,
            shrink_margin: 0.25,
            min_rf: 2,
            max_rf: 16,
            patience: 2,
        }
    }
}

/// Decide the initial number of data nodes from what is known before
/// execution (task size, worker count, link speed vs expected task time).
pub fn initial_data_nodes(
    workers: usize,
    task_bytes: usize,
    expected_task_s: f64,
    policy: &ReplicationPolicy,
) -> usize {
    // Each worker generates ~1 fetch of task_bytes per task; a data node
    // serving `c` concurrent workers needs task transfer time * c to stay
    // under budget * task time.
    let mib = task_bytes as f64 / (1024.0 * 1024.0);
    let xfer_s = 120e-6 + mib * 8e-3; // LAN model (store::LatencyModel::lan)
    let per_node_capacity =
        ((policy.budget * expected_task_s) / xfer_s).max(1.0);
    let rf = (workers as f64 / per_node_capacity).ceil() as usize;
    rf.clamp(policy.min_rf, policy.max_rf)
}

#[derive(Debug, Clone, Default)]
pub struct ControllerState {
    over_budget_streak: u32,
    under_budget_streak: u32,
}

/// One control step. Returns the new replication factor.
pub fn decide(
    policy: &ReplicationPolicy,
    state: &mut ControllerState,
    avg_fetch_s: f64,
    avg_exec_s: f64,
    current_rf: usize,
) -> usize {
    let exec = avg_exec_s.max(1e-9);
    let ratio = avg_fetch_s / exec;
    if ratio > policy.budget {
        state.over_budget_streak += 1;
        state.under_budget_streak = 0;
        if state.over_budget_streak >= policy.patience {
            state.over_budget_streak = 0;
            return (current_rf + 1).min(policy.max_rf);
        }
    } else if ratio < policy.budget * policy.shrink_margin {
        state.under_budget_streak += 1;
        state.over_budget_streak = 0;
        if state.under_budget_streak >= policy.patience * 2 {
            state.under_budget_streak = 0;
            return current_rf.saturating_sub(1).max(policy.min_rf);
        }
    } else {
        state.over_budget_streak = 0;
        state.under_budget_streak = 0;
    }
    current_rf
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_when_fetch_dominates() {
        let p = ReplicationPolicy::default();
        let mut st = ControllerState::default();
        let mut rf = 2;
        for _ in 0..4 {
            rf = decide(&p, &mut st, 0.5, 1.0, rf); // ratio 0.5 > 0.25
        }
        assert!(rf > 2, "rf should grow, got {rf}");
    }

    #[test]
    fn shrinks_when_fetch_negligible() {
        let p = ReplicationPolicy::default();
        let mut st = ControllerState::default();
        let mut rf = 8;
        for _ in 0..10 {
            rf = decide(&p, &mut st, 0.001, 1.0, rf);
        }
        assert!(rf < 8, "rf should shrink, got {rf}");
        assert!(rf >= p.min_rf);
    }

    #[test]
    fn stable_inside_band() {
        let p = ReplicationPolicy::default();
        let mut st = ControllerState::default();
        let mut rf = 4;
        for _ in 0..20 {
            rf = decide(&p, &mut st, 0.15, 1.0, rf); // 0.0625 < 0.15 < 0.25
        }
        assert_eq!(rf, 4);
    }

    #[test]
    fn respects_bounds() {
        let p = ReplicationPolicy { max_rf: 5, min_rf: 2, ..Default::default() };
        let mut st = ControllerState::default();
        let mut rf = 5;
        for _ in 0..10 {
            rf = decide(&p, &mut st, 10.0, 1.0, rf);
        }
        assert_eq!(rf, 5);
        let mut rf = 2;
        for _ in 0..20 {
            rf = decide(&p, &mut st, 0.0, 1.0, rf);
        }
        assert_eq!(rf, 2);
    }

    #[test]
    fn hysteresis_ignores_single_spike() {
        let p = ReplicationPolicy::default();
        let mut st = ControllerState::default();
        let rf = decide(&p, &mut st, 10.0, 1.0, 4); // one spike
        assert_eq!(rf, 4);
        let rf = decide(&p, &mut st, 0.1, 1.0, 4); // back to normal
        assert_eq!(rf, 4);
    }

    #[test]
    fn initial_nodes_scale_with_workers_and_task_size() {
        let p = ReplicationPolicy::default();
        let small = initial_data_nodes(12, 256 * 1024, 0.5, &p);
        let many_workers = initial_data_nodes(72, 256 * 1024, 0.5, &p);
        let big_tasks = initial_data_nodes(12, 24 * 1024 * 1024, 0.5, &p);
        assert!(many_workers >= small);
        assert!(big_tasks >= small);
        assert!(small >= p.min_rf);
    }
}
