//! Native kernel backend: the map/reduce statistics computed in pure
//! rust against a synthetic manifest.
//!
//! These functions are line-for-line ports of the pure-jnp oracles in
//! `python/compile/kernels/ref.py` and the entry points in
//! `python/compile/model.py` (same shapes, same epsilons, same masking
//! semantics), so a job produces the same statistic whether its tasks
//! execute through compiled PJRT artifacts or through this backend.
//! All arithmetic is f32, like the artifacts; within one backend the
//! computation is bit-deterministic (fixed iteration order), which is
//! what job-level recovery's restart ⇒ identical-result contract needs.
//!
//! [`NativeExec`] implements [`Exec`] over [`Manifest::synthetic`], so
//! everything written against the artifact contract — `MapTask`
//! assembly, bucket lookup, the reduce tree — runs unchanged.

use crate::data::ModelParams;
use crate::error::{Error, Result};
use crate::runtime::{Entry, Exec, HostTensor, Manifest, Runtime};

/// Variance floor in the per-marker linkage score (shapes.SCORE_EPS).
pub const SCORE_EPS: f32 = 1e-3;
/// Denominator floor in the grid-weighted average (shapes.WEIGHT_EPS).
pub const WEIGHT_EPS: f32 = 1e-6;

/// `eaglet_map`: per-chunk ALOD over `rounds` subsample rounds.
///
/// Inputs follow the artifact contract: `geno [bucket, M, I]`,
/// `pos [bucket, M]`, `idx [R, S]`, `grid [G]`; returns `[bucket, G]`
/// row-major. Padding rows (all-zero geno) produce zero scores and are
/// discarded later by `TaskPartial::from_map_output`.
pub fn eaglet_map(
    p: &ModelParams,
    bucket: usize,
    geno: &[f32],
    pos: &[f32],
    idx: &[i32],
    grid: &[f32],
) -> Vec<f32> {
    let (m, i, g) = (p.markers, p.individuals, p.grid);
    let (rounds, sub) = (p.rounds, p.subsample);
    let bw = p.bandwidth as f32;
    let mut out = vec![0.0f32; bucket * g];
    let mut num = vec![0.0f32; g];
    let mut den = vec![0.0f32; g];
    for b in 0..bucket {
        let geno_b = &geno[b * m * i..(b + 1) * m * i];
        let pos_b = &pos[b * m..(b + 1) * m];
        let out_b = &mut out[b * g..(b + 1) * g];
        for r in 0..rounds {
            num.iter_mut().for_each(|v| *v = 0.0);
            den.iter_mut().for_each(|v| *v = WEIGHT_EPS);
            for s in 0..sub {
                let mk = idx[r * sub + s] as usize;
                let row = &geno_b[mk * i..(mk + 1) * i];
                let mean = row.iter().sum::<f32>() / i as f32;
                let var = row
                    .iter()
                    .map(|x| (x - mean) * (x - mean))
                    .sum::<f32>()
                    / i as f32;
                let score = mean * mean / (var + SCORE_EPS);
                let pm = pos_b[mk];
                for (gi, &gp) in grid.iter().enumerate() {
                    let u = (pm - gp).abs() / bw;
                    if u < 1.0 {
                        let w = (1.0 - u * u * u).powi(3);
                        num[gi] += score * w;
                        den[gi] += w;
                    }
                }
            }
            for gi in 0..g {
                out_b[gi] += num[gi] / den[gi];
            }
        }
        for v in out_b.iter_mut() {
            *v /= rounds as f32;
        }
    }
    out
}

/// `netflix_map`: per-movie, per-month `(sum, sumsq, count)` over the
/// task's subsample draw.
///
/// Inputs: `vals/months/mask [bucket, N]`, `idx [S]` (shared across the
/// batch, like the compiled graph); returns `[bucket, months, 3]`.
/// A draw landing on a padded slot contributes nothing (mask 0), and a
/// month value only buckets when it is within 0.5 of an integral month
/// — exactly ref.py's one-hot condition.
pub fn netflix_map(
    p: &ModelParams,
    bucket: usize,
    vals: &[f32],
    months: &[f32],
    mask: &[f32],
    idx: &[i32],
) -> Vec<f32> {
    let n = p.ratings_cap;
    let (mo, f) = (p.months, p.stat_fields);
    let mut out = vec![0.0f32; bucket * mo * f];
    for b in 0..bucket {
        let base = b * n;
        let out_b = &mut out[b * mo * f..(b + 1) * mo * f];
        for &j in idx {
            let j = base + j as usize;
            let k = mask[j];
            if k == 0.0 {
                continue;
            }
            let mth = months[j];
            let mi = mth.round();
            if (mth - mi).abs() < 0.5 && mi >= 0.0 && (mi as usize) < mo {
                let v = vals[j];
                let o = mi as usize * f;
                out_b[o] += v * k;
                out_b[o + 1] += v * v * k;
                out_b[o + 2] += k;
            }
        }
    }
    out
}

/// `eaglet_reduce`: weighted combine of `reduce_fan` ALOD partials.
/// Returns `(weighted sum [G], total weight)`; the final division
/// happens in the reduce tree, like the artifact.
pub fn eaglet_reduce(
    p: &ModelParams,
    parts: &[f32],
    weights: &[f32],
) -> (Vec<f32>, f32) {
    let g = p.grid;
    let mut wsum = vec![0.0f32; g];
    for (ki, &w) in weights.iter().enumerate().take(p.reduce_fan) {
        if w == 0.0 {
            continue;
        }
        for gi in 0..g {
            wsum[gi] += parts[ki * g + gi] * w;
        }
    }
    (wsum, weights.iter().sum())
}

/// `netflix_reduce`: sum `reduce_fan` stat tensors into one.
pub fn netflix_reduce(p: &ModelParams, parts: &[f32]) -> Vec<f32> {
    let f = p.months * p.stat_fields;
    let mut out = vec![0.0f32; f];
    for ki in 0..p.reduce_fan {
        for fi in 0..f {
            out[fi] += parts[ki * f + fi];
        }
    }
    out
}

/// `seqaddr_map`: windowed means under sequential addressing
/// (Pan et al. 2021). Every row reads the same `sa_rounds` window
/// start offsets (the contiguous-access pattern the workload is
/// about); each window mean is accumulated as `(sum, sumsq, count)`
/// into the address bin its start offset falls in.
///
/// Inputs: `series [bucket, sa_len]`, `idx [sa_rounds]`; returns
/// `[bucket, sa_bins, stat_fields]` row-major. Padding rows produce
/// zero-mean windows and are discarded by `from_map_output`.
pub fn seqaddr_map(
    p: &ModelParams,
    bucket: usize,
    series: &[f32],
    idx: &[i32],
) -> Vec<f32> {
    let (len, w) = (p.sa_len, p.sa_window);
    let (bins, f) = (p.sa_bins, p.stat_fields);
    let starts = len - w + 1;
    let mut out = vec![0.0f32; bucket * bins * f];
    for b in 0..bucket {
        let s_b = &series[b * len..(b + 1) * len];
        let out_b = &mut out[b * bins * f..(b + 1) * bins * f];
        for &o in idx {
            let o = o as usize;
            let mean = s_b[o..o + w].iter().sum::<f32>() / w as f32;
            let bin = o * bins / starts;
            let base = bin * f;
            out_b[base] += mean;
            out_b[base + 1] += mean * mean;
            out_b[base + 2] += 1.0;
        }
    }
    out
}

/// `ssag_map`: scalable-subsampling aggregation (Politis 2021). For
/// each rung `g` of the block-size ladder `b_g = ssag_b·(g+1)`, split
/// the series into `q = ssag_len / b_g` non-overlapping blocks and
/// emit the subsampling variance estimate `b_g · Var(block means)`.
/// Deterministic — the blocks *are* the subsamples, no idx input.
///
/// Inputs: `series [bucket, ssag_len]`; returns `[bucket, ssag_points]`.
pub fn ssag_map(p: &ModelParams, bucket: usize, series: &[f32]) -> Vec<f32> {
    let len = p.ssag_len;
    let pts = p.ssag_points;
    let mut out = vec![0.0f32; bucket * pts];
    let mut means = Vec::with_capacity(len / p.ssag_b.max(1) + 1);
    for b in 0..bucket {
        let s_b = &series[b * len..(b + 1) * len];
        let out_b = &mut out[b * pts..(b + 1) * pts];
        for g in 0..pts {
            let bg = p.ssag_b * (g + 1);
            let q = len / bg;
            if q == 0 {
                continue; // ladder rung larger than the series
            }
            means.clear();
            let mut tbar = 0.0f32;
            for i in 0..q {
                let m = s_b[i * bg..(i + 1) * bg].iter().sum::<f32>()
                    / bg as f32;
                means.push(m);
                tbar += m;
            }
            tbar /= q as f32;
            let var = means
                .iter()
                .map(|m| (m - tbar) * (m - tbar))
                .sum::<f32>()
                / q as f32;
            out_b[g] = bg as f32 * var;
        }
    }
    out
}

/// `ssag_reduce`: weighted combine of `reduce_fan` variance-curve
/// partials — the Eaglet algebra over `ssag_points` lanes.
pub fn ssag_reduce(
    p: &ModelParams,
    parts: &[f32],
    weights: &[f32],
) -> (Vec<f32>, f32) {
    let g = p.ssag_points;
    let mut wsum = vec![0.0f32; g];
    for (ki, &w) in weights.iter().enumerate().take(p.reduce_fan) {
        if w == 0.0 {
            continue;
        }
        for gi in 0..g {
            wsum[gi] += parts[ki * g + gi] * w;
        }
    }
    (wsum, weights.iter().sum())
}

/// `seqaddr_reduce`: sum `reduce_fan` stat tensors — the Netflix
/// algebra over `sa_bins × stat_fields` lanes.
pub fn seqaddr_reduce(p: &ModelParams, parts: &[f32]) -> Vec<f32> {
    let f = p.sa_bins * p.stat_fields;
    let mut out = vec![0.0f32; f];
    for ki in 0..p.reduce_fan {
        for fi in 0..f {
            out[fi] += parts[ki * f + fi];
        }
    }
    out
}

/// An [`Exec`] backend that computes every manifest entry natively.
/// Always available — no artifacts, no XLA runtime, no filesystem.
pub struct NativeExec {
    manifest: Manifest,
}

impl NativeExec {
    pub fn new(params: ModelParams) -> NativeExec {
        NativeExec { manifest: Manifest::synthetic(params) }
    }

    fn check_idx(entry: &Entry, idx: &[i32], limit: usize) -> Result<()> {
        if idx.iter().any(|&v| v < 0 || v as usize >= limit) {
            return Err(Error::Data(format!(
                "{}: subsample index out of range (limit {limit})",
                entry.name
            )));
        }
        Ok(())
    }
}

impl Exec for NativeExec {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(
        &self,
        entry: &Entry,
        inputs: Vec<HostTensor>,
    ) -> Result<Vec<Vec<f32>>> {
        // Same boundary validation as the PJRT path (shape, dtype,
        // element count) — malformed tensors error cleanly instead of
        // panicking inside a kernel.
        Runtime::check_inputs(entry, &inputs)?;
        let p = &self.manifest.params;
        match entry.kind.as_str() {
            "eaglet_map" => {
                let geno = inputs[0].as_f32()?;
                let pos = inputs[1].as_f32()?;
                let idx = inputs[2].as_i32()?;
                let grid = inputs[3].as_f32()?;
                Self::check_idx(entry, idx, p.markers)?;
                Ok(vec![eaglet_map(p, entry.bucket, geno, pos, idx, grid)])
            }
            "netflix_map_hi" | "netflix_map_lo" => {
                let vals = inputs[0].as_f32()?;
                let months = inputs[1].as_f32()?;
                let mask = inputs[2].as_f32()?;
                let idx = inputs[3].as_i32()?;
                Self::check_idx(entry, idx, p.ratings_cap)?;
                Ok(vec![netflix_map(p, entry.bucket, vals, months, mask, idx)])
            }
            "seqaddr_map" => {
                let series = inputs[0].as_f32()?;
                let idx = inputs[1].as_i32()?;
                Self::check_idx(entry, idx, p.sa_len - p.sa_window + 1)?;
                Ok(vec![seqaddr_map(p, entry.bucket, series, idx)])
            }
            "ssag_map" => {
                let series = inputs[0].as_f32()?;
                Ok(vec![ssag_map(p, entry.bucket, series)])
            }
            "eaglet_reduce" => {
                let parts = inputs[0].as_f32()?;
                let weights = inputs[1].as_f32()?;
                let (wsum, wtot) = eaglet_reduce(p, parts, weights);
                Ok(vec![wsum, vec![wtot]])
            }
            "netflix_reduce" => {
                let parts = inputs[0].as_f32()?;
                Ok(vec![netflix_reduce(p, parts)])
            }
            "ssag_reduce" => {
                let parts = inputs[0].as_f32()?;
                let weights = inputs[1].as_f32()?;
                let (wsum, wtot) = ssag_reduce(p, parts, weights);
                Ok(vec![wsum, vec![wtot]])
            }
            "seqaddr_reduce" => {
                let parts = inputs[0].as_f32()?;
                Ok(vec![seqaddr_reduce(p, parts)])
            }
            other => Err(Error::Artifact(format!(
                "native backend: unknown entry kind {other}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> ModelParams {
        ModelParams::default()
    }

    #[test]
    fn netflix_map_matches_hand_computed_stats() {
        let p = params();
        let ne = NativeExec::new(p.clone());
        let n = p.ratings_cap;
        let mut vals = vec![0.0f32; n];
        let mut months = vec![0.0f32; n];
        let mut mask = vec![0.0f32; n];
        // three valid ratings: (4.0, month 2), (2.0, month 2), (5.0, month 7)
        for (slot, (v, mth)) in [(4.0, 2.0), (2.0, 2.0), (5.0, 7.0)]
            .iter()
            .enumerate()
        {
            vals[slot] = *v;
            months[slot] = *mth;
            mask[slot] = 1.0;
        }
        // draw slots 0, 1, 2, plus slot 1 again (bootstrap repeat) and a
        // padded slot (ignored); pad the idx vector with padded slots.
        let mut idx = vec![200i32; p.s_lo];
        idx[..4].copy_from_slice(&[0, 1, 2, 1]);
        let entry = ne.manifest().entry("netflix_map_lo", 1).unwrap().clone();
        let out = ne
            .run(
                &entry,
                vec![
                    HostTensor::F32(vals, vec![1, n]),
                    HostTensor::F32(months, vec![1, n]),
                    HostTensor::F32(mask, vec![1, n]),
                    HostTensor::I32(idx, vec![p.s_lo]),
                ],
            )
            .unwrap();
        let stats = &out[0];
        let f = p.stat_fields;
        // month 2: 4 + 2 + 2 (slot 1 drawn twice)
        assert!((stats[2 * f] - 8.0).abs() < 1e-6);
        assert!((stats[2 * f + 1] - (16.0 + 4.0 + 4.0)).abs() < 1e-6);
        assert!((stats[2 * f + 2] - 3.0).abs() < 1e-6);
        // month 7: one rating of 5
        assert!((stats[7 * f] - 5.0).abs() < 1e-6);
        assert!((stats[7 * f + 2] - 1.0).abs() < 1e-6);
        // all other months empty
        let total: f32 = (0..p.months).map(|m| stats[m * f + 2]).sum();
        assert!((total - 4.0).abs() < 1e-6);
    }

    #[test]
    fn eaglet_map_is_deterministic_and_finite() {
        let p = params();
        let ne = NativeExec::new(p.clone());
        let entry = ne.manifest().entry("eaglet_map", 4).unwrap().clone();
        let mut rng = crate::util::rng::Rng::new(9);
        let geno: Vec<f32> = (0..4 * p.markers * p.individuals)
            .map(|_| rng.f32() * 2.0 - 1.0)
            .collect();
        let pos: Vec<f32> =
            (0..4 * p.markers).map(|_| rng.f32()).collect();
        let idx: Vec<i32> = (0..p.rounds * p.subsample)
            .map(|_| rng.below(p.markers as u64) as i32)
            .collect();
        let grid: Vec<f32> =
            (0..p.grid).map(|g| g as f32 / p.grid as f32).collect();
        let mk_inputs = || {
            vec![
                HostTensor::F32(geno.clone(), vec![4, p.markers, p.individuals]),
                HostTensor::F32(pos.clone(), vec![4, p.markers]),
                HostTensor::I32(idx.clone(), vec![p.rounds, p.subsample]),
                HostTensor::F32(grid.clone(), vec![p.grid]),
            ]
        };
        let a = ne.run(&entry, mk_inputs()).unwrap();
        let b = ne.run(&entry, mk_inputs()).unwrap();
        assert_eq!(a, b, "native kernel must be bit-deterministic");
        assert_eq!(a[0].len(), 4 * p.grid);
        assert!(a[0].iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn zero_padding_rows_produce_zero_alod() {
        let p = params();
        let ne = NativeExec::new(p.clone());
        let entry = ne.manifest().entry("eaglet_map", 1).unwrap().clone();
        let out = ne
            .run(
                &entry,
                vec![
                    HostTensor::F32(
                        vec![0.0; p.markers * p.individuals],
                        vec![1, p.markers, p.individuals],
                    ),
                    HostTensor::F32(vec![0.0; p.markers], vec![1, p.markers]),
                    HostTensor::I32(
                        vec![0; p.rounds * p.subsample],
                        vec![p.rounds, p.subsample],
                    ),
                    HostTensor::F32(
                        (0..p.grid).map(|g| g as f32 / p.grid as f32).collect(),
                        vec![p.grid],
                    ),
                ],
            )
            .unwrap();
        assert!(out[0].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn reduce_kernels_match_f64_oracle() {
        let p = params();
        let ne = NativeExec::new(p.clone());
        let k = p.reduce_fan;
        let g = p.grid;
        let mut rng = crate::util::rng::Rng::new(3);
        let parts: Vec<f32> = (0..k * g).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let weights: Vec<f32> =
            (0..k).map(|_| 1.0 + rng.below(9) as f32).collect();
        let e = ne.manifest().entry("eaglet_reduce", k).unwrap().clone();
        let out = ne
            .run(
                &e,
                vec![
                    HostTensor::F32(parts.clone(), vec![k, g]),
                    HostTensor::F32(weights.clone(), vec![k]),
                ],
            )
            .unwrap();
        for gi in 0..g {
            let want: f64 = (0..k)
                .map(|ki| parts[ki * g + gi] as f64 * weights[ki] as f64)
                .sum();
            assert!((out[0][gi] as f64 - want).abs() < 1e-3);
        }
        let wtot: f64 = weights.iter().map(|&w| w as f64).sum();
        assert!((out[1][0] as f64 - wtot).abs() < 1e-3);

        let f = p.months * p.stat_fields;
        let nparts: Vec<f32> = (0..k * f).map(|_| rng.f32() * 10.0).collect();
        let e = ne.manifest().entry("netflix_reduce", k).unwrap().clone();
        let out = ne
            .run(&e, vec![HostTensor::F32(nparts.clone(), vec![k, p.months, p.stat_fields])])
            .unwrap();
        for fi in 0..f {
            let want: f64 =
                (0..k).map(|ki| nparts[ki * f + fi] as f64).sum();
            assert!((out[0][fi] as f64 - want).abs() < 1e-3);
        }
    }

    #[test]
    fn seqaddr_map_matches_hand_computed_stats() {
        let p = params();
        let ne = NativeExec::new(p.clone());
        let entry = ne.manifest().entry("seqaddr_map", 1).unwrap().clone();
        // a linear series: window mean at offset o is o + (w-1)/2
        let series: Vec<f32> = (0..p.sa_len).map(|t| t as f32).collect();
        // two draws at offset 0 and one at the last valid start
        let last = (p.sa_len - p.sa_window) as i32;
        let mut idx = vec![0i32; p.sa_rounds];
        idx[p.sa_rounds - 1] = last;
        let out = ne
            .run(
                &entry,
                vec![
                    HostTensor::F32(series, vec![1, p.sa_len]),
                    HostTensor::I32(idx, vec![p.sa_rounds]),
                ],
            )
            .unwrap();
        let f = p.stat_fields;
        let half = (p.sa_window - 1) as f32 / 2.0;
        // bin 0: sa_rounds-1 draws at offset 0, mean = half
        let n0 = (p.sa_rounds - 1) as f32;
        assert!((out[0][0] - n0 * half).abs() < 1e-2);
        assert!((out[0][2] - n0).abs() < 1e-6);
        // last bin: one draw, mean = last + half
        let lb = (p.sa_bins - 1) * f;
        assert!((out[0][lb] - (last as f32 + half)).abs() < 1e-2);
        assert!((out[0][lb + 2] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ssag_map_matches_hand_computed_variance() {
        let p = params();
        let ne = NativeExec::new(p.clone());
        let entry = ne.manifest().entry("ssag_map", 1).unwrap().clone();
        // alternating +1/-1 at block scale b: blocks of even size have
        // mean 0 → variance 0; constant series → variance 0 everywhere
        let constant = vec![2.5f32; p.ssag_len];
        let out = ne
            .run(
                &entry,
                vec![HostTensor::F32(constant, vec![1, p.ssag_len])],
            )
            .unwrap();
        assert!(out[0].iter().all(|&v| v.abs() < 1e-4));
        // first half 0, second half 2: the coarsest blocks straddle
        // means 0 and 2, giving a strictly positive estimate
        let step: Vec<f32> = (0..p.ssag_len)
            .map(|t| if t < p.ssag_len / 2 { 0.0 } else { 2.0 })
            .collect();
        let out = ne
            .run(&entry, vec![HostTensor::F32(step, vec![1, p.ssag_len])])
            .unwrap();
        // hand-check rung 0: q blocks of size b, half mean 0, half
        // mean 2 → Var = 1, estimate = b * 1
        let b0 = p.ssag_b as f32;
        assert!((out[0][0] - b0).abs() < 1e-3, "got {}", out[0][0]);
        assert!(out[0].iter().all(|&v| v.is_finite() && v >= 0.0));
    }

    #[test]
    fn series_reduce_kernels_match_f64_oracle() {
        let p = params();
        let ne = NativeExec::new(p.clone());
        let k = p.reduce_fan;
        let mut rng = crate::util::rng::Rng::new(11);
        let g = p.ssag_points;
        let parts: Vec<f32> =
            (0..k * g).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let weights: Vec<f32> =
            (0..k).map(|_| 1.0 + rng.below(9) as f32).collect();
        let e = ne.manifest().entry("ssag_reduce", k).unwrap().clone();
        let out = ne
            .run(
                &e,
                vec![
                    HostTensor::F32(parts.clone(), vec![k, g]),
                    HostTensor::F32(weights.clone(), vec![k]),
                ],
            )
            .unwrap();
        for gi in 0..g {
            let want: f64 = (0..k)
                .map(|ki| parts[ki * g + gi] as f64 * weights[ki] as f64)
                .sum();
            assert!((out[0][gi] as f64 - want).abs() < 1e-3);
        }
        let f = p.sa_bins * p.stat_fields;
        let sparts: Vec<f32> =
            (0..k * f).map(|_| rng.f32() * 10.0).collect();
        let e = ne.manifest().entry("seqaddr_reduce", k).unwrap().clone();
        let out = ne
            .run(
                &e,
                vec![HostTensor::F32(
                    sparts.clone(),
                    vec![k, p.sa_bins, p.stat_fields],
                )],
            )
            .unwrap();
        for fi in 0..f {
            let want: f64 =
                (0..k).map(|ki| sparts[ki * f + fi] as f64).sum();
            assert!((out[0][fi] as f64 - want).abs() < 1e-3);
        }
    }

    #[test]
    fn rejects_bad_shapes_and_out_of_range_indices() {
        let p = params();
        let ne = NativeExec::new(p.clone());
        let entry = ne.manifest().entry("netflix_reduce", p.reduce_fan).unwrap().clone();
        // wrong arity
        assert!(ne.run(&entry, vec![]).is_err());
        // wrong shape
        let bad = HostTensor::F32(vec![0.0; 6], vec![2, 3]);
        assert!(ne.run(&entry, vec![bad]).is_err());
        // out-of-range subsample index
        let e = ne.manifest().entry("eaglet_map", 1).unwrap().clone();
        let inputs = vec![
            HostTensor::F32(
                vec![0.0; p.markers * p.individuals],
                vec![1, p.markers, p.individuals],
            ),
            HostTensor::F32(vec![0.0; p.markers], vec![1, p.markers]),
            HostTensor::I32(
                vec![p.markers as i32; p.rounds * p.subsample],
                vec![p.rounds, p.subsample],
            ),
            HostTensor::F32(vec![0.0; p.grid], vec![p.grid]),
        ];
        assert!(ne.run(&e, inputs).is_err());
    }
}
