//! The execution spine: a cluster executor over the pluggable
//! transport layer, with pluggable kernel backends (DESIGN.md §4, §11).
//!
//! This is the path that *runs* — a leader plus N map slots (local
//! threads over channels, or remote `bts worker` processes over
//! framed TCP), driving a real job end to end:
//!
//! ```text
//! kneepoint::pack → TwoStepScheduler dispatch (leader, WorkerLinks) →
//!   worker: dfs fetch (+prefetch; DFS-proxied for remote slots) →
//!   MapTask assembly → Backend::run (map kernel) → shuffle (Up) →
//!   reduce tree on the leader → JobOutput + metrics
//! ```
//!
//! Layout:
//! - [`native`]  — pure-rust ports of the L1/L2 kernels (ref.py
//!   semantics) behind a synthetic manifest; always available.
//! - [`backend`] — [`Backend`]: native kernels or the PJRT pool, with
//!   probing auto-selection.
//! - [`cluster`] — the leader/worker machinery, shutdown ordering,
//!   failure injection, and the scheduler-overhead metrics
//!   ([`SchedOverhead`]) this platform is graded on.
//!
//! `coordinator::job` remains the scoped-thread PJRT engine; this
//! module is the backend-generic, message-passing executor the CLI
//! (`bts exec`), `examples/end_to_end.rs` and
//! `benches/exec_pipeline.rs` drive.

pub mod backend;
pub mod cluster;
pub mod native;

pub use backend::Backend;
pub use cluster::{
    run_cluster, run_cluster_with_recovery, ExecConfig, ExecResult,
    SchedOverhead, WorkerStats,
};
pub use native::NativeExec;
