//! Pluggable execution backend for the cluster executor.
//!
//! The cluster (leader + workers) is written against the [`Exec`]
//! trait; [`Backend`] is the concrete choice a process makes once at
//! startup:
//!
//! * [`Backend::Pjrt`] — compiled HLO artifacts through the shared
//!   [`ExecutorPool`] (requires `make artifacts` and the real `xla`
//!   crate; see vendor/xla).
//! * [`Backend::Native`] — the pure-rust kernels of
//!   [`super::native`], always available.
//!
//! [`Backend::auto`] picks PJRT when artifacts exist *and* a probe
//! execution succeeds (i.e. the real XLA runtime is linked), falling
//! back to native otherwise — so binaries and examples run end to end
//! on any host.

use std::sync::Arc;

use super::native::NativeExec;
use crate::data::ModelParams;
use crate::error::Result;
use crate::runtime::{Entry, Exec, ExecutorPool, HostTensor, Manifest};

/// A concrete executor: PJRT artifacts or native kernels.
pub enum Backend {
    /// Pure-rust kernels over a synthetic manifest.
    Native(NativeExec),
    /// Compiled artifacts through the process-wide PJRT pool.
    Pjrt(Arc<ExecutorPool>),
}

impl Backend {
    /// The native backend for `params` (no artifacts needed).
    pub fn native(params: ModelParams) -> Backend {
        Backend::Native(NativeExec::new(params))
    }

    /// The PJRT backend over `manifest` (shared process-wide pool).
    pub fn pjrt(manifest: &Arc<Manifest>) -> Result<Backend> {
        Ok(Backend::Pjrt(ExecutorPool::global(manifest)?))
    }

    /// Prefer PJRT when it can actually execute; otherwise native.
    ///
    /// "Can execute" is probed, not assumed: artifacts may exist while
    /// the binary links the vendored xla stub (whose runtime
    /// construction fails), and the probe keeps that configuration
    /// falling back cleanly instead of failing mid-job.
    pub fn auto() -> Backend {
        if let Ok(m) = Manifest::load_default() {
            let m = Arc::new(m);
            let params = m.params.clone();
            if let Ok(pool) = ExecutorPool::global(&m) {
                let p = &pool.manifest_ref().params;
                if let Some(e) = pool.manifest_ref().entry("netflix_reduce", p.reduce_fan)
                {
                    let probe = HostTensor::F32(
                        vec![0.0; p.reduce_fan * p.months * p.stat_fields],
                        vec![p.reduce_fan, p.months, p.stat_fields],
                    );
                    let e = e.clone();
                    if pool.execute(&e, vec![probe]).is_ok() {
                        return Backend::Pjrt(pool);
                    }
                }
            }
            return Backend::native(params);
        }
        Backend::native(ModelParams::default())
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native(_) => "native",
            Backend::Pjrt(_) => "pjrt",
        }
    }
}

impl Exec for Backend {
    fn manifest(&self) -> &Manifest {
        match self {
            Backend::Native(n) => n.manifest(),
            Backend::Pjrt(p) => p.manifest_ref(),
        }
    }

    fn run(
        &self,
        entry: &Entry,
        inputs: Vec<HostTensor>,
    ) -> Result<Vec<Vec<f32>>> {
        match self {
            Backend::Native(n) => n.run(entry, inputs),
            Backend::Pjrt(p) => p.execute(entry, inputs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_serves_manifest_lookups() {
        let b = Backend::native(ModelParams::default());
        assert_eq!(b.name(), "native");
        let m = b.manifest();
        assert!(m.entry("eaglet_map", 1).is_some());
        assert!(m.map_entry("netflix_map_lo", 5).unwrap().bucket >= 5);
    }

    #[test]
    fn auto_falls_back_to_native_without_working_pjrt() {
        // In offline builds (vendored xla stub, no artifacts) auto()
        // must yield the native backend rather than erroring.
        if Manifest::load_default().is_err() {
            assert_eq!(Backend::auto().name(), "native");
        }
    }
}
