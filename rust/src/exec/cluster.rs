//! The cluster executor: a leader and N map slots joined by the
//! pluggable transport layer, driving one job end to end.
//!
//! Roles (thesis Fig 7):
//!
//! * **Leader** (the calling thread): packs samples into kneepoint-
//!   sized tasks, stages their blocks into the replicated store, owns
//!   the [`TwoStepScheduler`], pushes [`TaskSpec`]s down per-worker
//!   links (keeping a small dispatch window in flight so worker
//!   prefetchers have lookahead), collects partials, drives the
//!   adaptive replication controller, and runs the reduce tree.
//! * **Workers**: every map slot runs [`crate::transport::worker_body`]
//!   over a [`crate::transport::WorkerLink`] — local threads over mpsc
//!   channels, and (with [`ExecConfig::remote`]) `bts worker
//!   --connect` processes over framed TCP, fetching blocks through
//!   the leader-proxied DFS path instead of receiving data inline.
//!   Above the links the leader cannot tell the transports apart.
//!
//! Shutdown ordering is explicit: the leader sends `Shutdown` to a
//! worker only when the scheduler has no work left for it and nothing
//! of its is in flight; workers acknowledge by reporting `Exited`, and
//! the leader joins every link before reducing. A worker failure —
//! reported ([`Up::TaskFailed`]) or transport-level
//! ([`Up::Lost`], e.g. a TCP worker dropping mid-job) — aborts the
//! attempt (all workers are told to stop, then joined) and surfaces
//! as `Err`; job-level recovery restarts the whole job via
//! [`run_cluster_with_recovery`], reproducing the statistic exactly
//! (per-task seeds, seq-ordered reduce — the transport-independent
//! determinism contract).
//!
//! Since the serve layer landed, the per-job half of the leader lives
//! in [`JobCtx`]: scheduler ownership, partial collection, per-task
//! timing, the replication feedback loop, and the seq-ordered reduce.
//! `run_cluster` drives exactly one `JobCtx` over links it spawns and
//! joins itself; `serve::JobService` drives *many* `JobCtx`s over a
//! persistent [`crate::serve::PoolConfig`]-sized pool, which is what
//! turns this executor into a long-lived multi-tenant service. Block
//! keys are namespace-prefixed ([`crate::dfs::job_ns`]) so concurrent
//! jobs sharing one store never collide; solo runs use the empty
//! namespace and keep their historical keys.
//!
//! Unlike `coordinator::job` (scoped threads pulling from a shared
//! scheduler, PJRT-only), this executor isolates every cross-thread
//! interaction in messages and is generic over the execution backend —
//! and it measures what the thesis says must stay small: per-task
//! latency and scheduler overhead (leader dispatch time + worker queue
//! wait).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use super::backend::Backend;
use crate::cache::{CacheLayer, CacheStats};
use crate::coordinator::assemble::TaskPartial;
use crate::coordinator::recovery::{retry, FailurePlan};
use crate::coordinator::reduce::{
    finalize_netflix, finalize_seqaddr, reduce_eaglet, reduce_netflix,
    reduce_seqaddr, reduce_ssag,
};
use crate::coordinator::JobOutput;
use crate::data::{Dataset, ModelParams, Workload};
use crate::dfs::{
    decide, initial_data_nodes, ControllerState, Dfs, LatencyModel,
    ReplicationPolicy,
};
use crate::error::{Error, Result};
use crate::kneepoint::TaskSizing;
use crate::membership::{Acceptor, Ledger, MemberEvent, TaskKind};
use crate::metrics::{JobReport, Timer};
use crate::net::protocol::{NetCounters, ACCEPT_TIMEOUT, PING_INTERVAL};
use crate::runtime::Exec;
use crate::scheduler::{
    inflight_target, placement_score, DoneKind, ResponseTimeTracker,
    SchedConfig, SchedSnapshot, SpeculationState, TaskSpec,
    TwoStepScheduler,
};
use crate::reduce::{PartitionPlan, Partitioner};
use crate::transport::{
    teardown, BodyCfg, Down, PumpCfg, ReduceDone, ReduceEnvelope,
    ReduceSpec, RemoteWorkers, TaskDone, TaskEnvelope, Up, WorkerLink,
};
use crate::util::json::{num, obj, Json};
use crate::util::stats::{summarize, Summary};
use crate::util::testutil::Turbulence;

/// Everything one cluster run needs beyond the dataset and backend.
#[derive(Debug, Clone)]
pub struct ExecConfig {
    pub sizing: TaskSizing,
    /// Local worker threads (in-proc map slots).
    pub workers: usize,
    /// Remote TCP map slots: a pre-bound listener plus how many
    /// `bts worker --connect` processes to accept on it. Remote
    /// workers take the slot indices after the local ones. The
    /// listener lives in the config (an `Arc`), so job-level recovery
    /// reuses it across attempts — reconnecting workers are adopted
    /// by the next attempt.
    pub remote: Option<RemoteWorkers>,
    /// Data nodes backing the replicated store.
    pub data_nodes: usize,
    pub latency: LatencyModel,
    pub replication: ReplicationPolicy,
    /// Drive the replication factor from the fetch/exec feedback loop.
    pub adaptive_rf: bool,
    pub sched: SchedConfig,
    /// Upper bound on the per-worker prefetch depth k.
    pub prefetch_k: usize,
    /// Tasks kept in flight per worker link (dispatch lookahead —
    /// what lets the prefetcher pump ahead of execution).
    pub inflight: usize,
    /// Shared read-through block cache budget in MiB (0 disables).
    pub cache_mb: usize,
    /// Cache-affinity dispatch: refill batches prefer the worker
    /// already holding a task's blocks.
    pub affinity: bool,
    /// Job seed: drives every task's subsample indices.
    pub seed: u64,
    /// Injected failure (shutdown-ordering and recovery tests).
    pub failure: Option<FailurePlan>,
    /// Deterministic latency/fault turbulence for the in-proc workers
    /// (scheduler tests and the straggler bench script slow slots
    /// through this; see [`crate::util::testutil::Turbulence`]).
    pub turbulence: Option<Arc<Turbulence>>,
    /// Attempt number, set by [`run_cluster_with_recovery`] (1-based).
    pub attempt: u32,
    /// Label for reports.
    pub platform: String,
    /// Executed reduce partitions (`1` keeps the historical leader-side
    /// seq-ordered reduce; `>1` shuffles map partials through the
    /// replicated store and runs reducers on the worker pool).
    pub reduce_tasks: usize,
    /// Key → reduce-partition assignment policy (only consulted when
    /// `reduce_tasks > 1`).
    pub partitioner: Partitioner,
    /// Elastic membership (DESIGN.md §14): admit late `bts worker
    /// --connect` joins mid-job, absorb `bts drain` departures, and
    /// turn worker loss into a ledger re-dispatch of the dead slot's
    /// in-flight window instead of a job-level restart. Off, the
    /// membership is frozen at startup and loss aborts the attempt
    /// (the historical recovery semantics).
    pub elastic: bool,
    /// Remote-link heartbeat interval in milliseconds: the worker's
    /// ping cadence, and (×6) the leader pump's silent-peer threshold.
    pub heartbeat_ms: u64,
    /// Coalesce each refill window's dispatches into one
    /// `Down::TaskBatch` frame (and let workers ack completions as
    /// `Up::DoneBatch`). The batch window is the scheduler-refill
    /// window — there is no separate size knob. Off reproduces the
    /// historical one-frame-per-task wire behavior (`--batch off`).
    pub batch_dispatch: bool,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig {
            sizing: TaskSizing::Kneepoint(256 * 1024),
            workers: 4,
            remote: None,
            data_nodes: 4,
            latency: LatencyModel::none(),
            replication: ReplicationPolicy::default(),
            adaptive_rf: true,
            sched: SchedConfig::default(),
            prefetch_k: 8,
            inflight: 4,
            cache_mb: 0,
            affinity: false,
            seed: 0xB75,
            failure: None,
            turbulence: None,
            attempt: 1,
            platform: "bts-exec".into(),
            reduce_tasks: 1,
            partitioner: Partitioner::Hash,
            elastic: false,
            heartbeat_ms: PING_INTERVAL.as_millis() as u64,
            batch_dispatch: true,
        }
    }
}

impl ExecConfig {
    /// Total map slots: local threads plus remote TCP workers.
    pub fn slots(&self) -> usize {
        self.workers + self.remote.as_ref().map_or(0, |r| r.count)
    }
}

/// Per-worker lifecycle accounting (shutdown-ordering tests key off
/// `clean_shutdown`).
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub worker: usize,
    pub executed: u64,
    /// The worker exited because the leader told it to (orderly
    /// drain), not because a link died under it.
    pub clean_shutdown: bool,
}

/// Scheduler-overhead metrics — the cost side of the tiny-task trade
/// the thesis quantifies (§1.1.2).
#[derive(Debug, Clone)]
pub struct SchedOverhead {
    /// Leader wall time spent inside scheduler claim/report calls and
    /// link dispatch.
    pub dispatch_s: f64,
    pub dispatch_calls: u64,
    /// Worker-side idle wait for the next task after finishing one.
    pub queue_wait: Summary,
}

impl SchedOverhead {
    pub fn dispatch_us_per_call(&self) -> f64 {
        if self.dispatch_calls == 0 {
            0.0
        } else {
            self.dispatch_s / self.dispatch_calls as f64 * 1e6
        }
    }
}

/// A finished cluster run.
#[derive(Debug, Clone)]
pub struct ExecResult {
    pub output: JobOutput,
    pub report: JobReport,
    pub sched: SchedSnapshot,
    pub overhead: SchedOverhead,
    /// Replication-factor trajectory (initial → final decisions).
    pub rf_trajectory: Vec<usize>,
    /// Data-plane volume: payload bytes served by the store across all
    /// data nodes (replica re-fetches included; remote workers'
    /// DFS-proxied fetches land here too).
    pub dfs_bytes_served: u64,
    /// Shared block-cache counters, when `cache_mb > 0`.
    pub cache: Option<CacheStats>,
    pub workers: Vec<WorkerStats>,
    /// Units re-dispatched after membership loss (drain or crash) —
    /// the task-level-checkpoint alternative to `report.restarts`.
    pub re_dispatched: u64,
}

impl ExecResult {
    /// Flat JSON record — the baseline format for BENCH_*.json
    /// trajectory entries (`results/exec_baseline.json`).
    pub fn metrics_json(&self) -> Json {
        obj(vec![
            ("report", self.report.to_json()),
            ("sched_dispatch_s", num(self.overhead.dispatch_s)),
            ("sched_dispatch_calls", num(self.overhead.dispatch_calls as f64)),
            (
                "sched_dispatch_us_per_call",
                num(self.overhead.dispatch_us_per_call()),
            ),
            ("queue_wait_p50_s", num(self.overhead.queue_wait.p50)),
            ("queue_wait_p95_s", num(self.overhead.queue_wait.p95)),
            ("sched_steals", num(self.sched.steals as f64)),
            ("sched_refills", num(self.sched.refills as f64)),
            ("sched_affinity_routed", num(self.sched.affinity_routed as f64)),
            ("sched_speculated", num(self.sched.speculated as f64)),
            ("sched_won_by_clone", num(self.sched.won_by_clone as f64)),
            ("membership_re_dispatched", num(self.re_dispatched as f64)),
            ("dfs_bytes_served", num(self.dfs_bytes_served as f64)),
            // disambiguates "cache off" from "cache on, zero hits" in
            // the cross-PR trajectory
            (
                "cache_enabled",
                num(if self.cache.is_some() { 1.0 } else { 0.0 }),
            ),
            ("cache_hit_rate", num(self.report.cache_hit_rate)),
            (
                "cache_dedup_hits",
                num(self
                    .cache
                    .as_ref()
                    .map_or(0.0, |c| c.dedup_hits as f64)),
            ),
            (
                "cache_evictions",
                num(self.cache.as_ref().map_or(0.0, |c| c.evicted as f64)),
            ),
        ])
    }
}

/// Store key for one sample's block under a job namespace (`""` for
/// solo runs; [`crate::dfs::job_ns`] prefixes for multiplexed jobs).
/// Shared with the scheduler's affinity scoring via
/// [`crate::data::block::block_key`].
pub(crate) fn block_key(ns: &str, workload: Workload, sample: u64) -> String {
    crate::data::block::block_key(ns, workload, sample)
}

/// Encode every sample of `dataset` into the store under `ns`. Returns
/// (samples, input bytes, staged keys) — the keys are what a
/// multi-tenant owner removes when the job leaves the system.
pub(crate) fn stage_dataset(
    dataset: &dyn Dataset,
    dfs: &Dfs,
    ns: &str,
) -> (usize, usize, Vec<String>) {
    let metas = dataset.metas();
    let workload = dataset.workload();
    let mut keys = Vec::with_capacity(metas.len());
    for meta in metas {
        let block = dataset.encode_block(meta.id);
        let key = block_key(ns, workload, meta.id);
        dfs.put(&key, Arc::new(block.encode()));
        keys.push(key);
    }
    (metas.len(), dataset.total_bytes(), keys)
}

/// Reduce seq-ordered task partials into the job statistic. Both the
/// solo executor and the serve layer finish jobs through this single
/// path — that shared, order-fixed reduce is the determinism argument
/// for "a multiplexed job equals its solo run, bit for bit" and for
/// "a TCP run equals its in-proc run, bit for bit".
fn reduce_partials(
    backend: &Backend,
    params: &ModelParams,
    workload: Workload,
    collected: Vec<TaskPartial>,
) -> Result<JobOutput> {
    Ok(match workload {
        Workload::Eaglet | Workload::Ssag => {
            let parts: Vec<(Vec<f32>, f32)> = collected
                .into_iter()
                .map(|p| match p {
                    TaskPartial::Eaglet { alod, weight } => (alod, weight),
                    _ => unreachable!("workload-homogeneous job"),
                })
                .collect();
            let (alod, weight) = match workload {
                Workload::Eaglet => reduce_eaglet(backend, params, parts)?,
                _ => reduce_ssag(backend, params, parts)?,
            };
            JobOutput::Eaglet { alod, weight }
        }
        Workload::NetflixHi | Workload::NetflixLo | Workload::SeqAddr => {
            let parts: Vec<Vec<f32>> = collected
                .into_iter()
                .map(|pt| match pt {
                    TaskPartial::Netflix { stats } => stats,
                    _ => unreachable!("workload-homogeneous job"),
                })
                .collect();
            let out = match workload {
                Workload::SeqAddr => {
                    let stats = reduce_seqaddr(backend, params, parts)?;
                    finalize_seqaddr(params, &stats)?
                }
                _ => {
                    let stats = reduce_netflix(backend, params, parts)?;
                    finalize_netflix(params, &stats)?
                }
            };
            JobOutput::Netflix(out)
        }
    })
}

/// Everything a finished [`JobCtx`] yields short of pool-owned state
/// (worker lifecycle, store volume), which the caller supplies.
pub(crate) struct FinishedJob {
    pub(crate) output: JobOutput,
    pub(crate) report: JobReport,
    pub(crate) sched: SchedSnapshot,
    pub(crate) overhead: SchedOverhead,
    pub(crate) rf_trajectory: Vec<usize>,
    pub(crate) re_dispatched: u64,
}

/// The per-job half of the leader: owns this job's scheduler and
/// partials, times every scheduler interaction, drives the adaptive
/// replication controller, and reduces in seq order when complete.
///
/// `run_cluster` drives one of these over links it spawns itself;
/// the serve dispatcher drives one per in-flight job over a shared
/// persistent pool — "one job among many" with no per-job spawn/join.
pub(crate) struct JobCtx {
    cfg: ExecConfig,
    workload: Workload,
    dfs: Arc<Dfs>,
    sched: TwoStepScheduler,
    partials: Vec<Option<TaskPartial>>,
    remaining: usize,
    n_tasks: usize,
    samples: usize,
    input_bytes: usize,
    startup_s: f64,
    map_t: Timer,
    fetch_times: Vec<f64>,
    exec_times: Vec<f64>,
    queue_waits: Vec<f64>,
    turnarounds: Vec<f64>,
    hits: u64,
    misses: u64,
    rf_trajectory: Vec<usize>,
    ctrl: ControllerState,
    dispatch_s: f64,
    dispatch_calls: u64,
    cache_hits: u64,
    cache_misses: u64,
    /// Leader-side speculation bookkeeping (also the source of the
    /// dispatch → first-completion turnaround times).
    spec: SpeculationState,
    /// Task-level checkpoint index (DESIGN.md §14): which `(kind, seq,
    /// attempt)` units are riding on which slots, so a membership loss
    /// re-dispatches exactly the dead slot's sole-carrier in-flight
    /// window — everything completed stays completed.
    ledger: Ledger,
    /// Response-time tracker (dynamic mode); shared pool-wide by the
    /// serve layer, private to the run for solo exec.
    tracker: Option<Arc<ResponseTimeTracker>>,
    /// The affinity view the scheduler also holds — kept here so
    /// speculative clone targets can be scored by placement.
    affinity: Option<crate::cache::AffinityHook>,
    /// This job's block-key namespace (`""` for solo runs) — shuffle
    /// fragments are staged under it so concurrent jobs never collide.
    ns: Arc<str>,
    /// Reduce phase (only populated when `cfg.reduce_tasks > 1`): the
    /// key → partition plan, built once every map partial is in.
    rplan: Option<PartitionPlan>,
    /// Reduce dispatches not yet claimed by a worker.
    rqueue: VecDeque<ReduceSpec>,
    /// Spec per partition, kept for speculative re-dispatch.
    rspecs: Vec<Option<ReduceSpec>>,
    /// Dispatch clock per partition (straggler detection).
    rdispatch: Vec<Option<Timer>>,
    /// First slot a partition was dispatched to (clones avoid it).
    rprimary: Vec<Option<usize>>,
    rcloned: Vec<bool>,
    /// Collected reduce partials, indexed by partition — first
    /// bit-identical result wins, duplicates are dropped.
    reduced: Vec<Option<TaskPartial>>,
    reduce_remaining: usize,
    reduce_speculated: u64,
    reduce_won_by_clone: u64,
    /// Intermediate bytes staged into the store by the shuffle.
    shuffle_bytes: u64,
    /// Imbalance factor of the chosen plan (1.0 = perfect balance).
    shuffle_imbalance: f64,
    /// Dispatch → first-completion turnaround per reduce partition.
    reduce_turnarounds: Vec<f64>,
}

impl JobCtx {
    /// Build the leader state for one job whose blocks are already
    /// staged in `dfs`. `pool_workers` sizes the scheduler's per-worker
    /// queues (the number of map slots that will call [`JobCtx::next`]);
    /// `affinity` (when cache-affinity dispatch is on) carries the
    /// shared registry plus this job's key namespace into the
    /// scheduler's refill step.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        specs: Vec<TaskSpec>,
        dfs: Arc<Dfs>,
        cfg: ExecConfig,
        pool_workers: usize,
        samples: usize,
        input_bytes: usize,
        startup_s: f64,
        affinity: Option<crate::cache::AffinityHook>,
        tracker: Option<Arc<ResponseTimeTracker>>,
        ns: Arc<str>,
    ) -> Result<JobCtx> {
        let Some(first) = specs.first() else {
            return Err(Error::Data("job packed zero tasks".into()));
        };
        let workload = first.workload;
        let n_tasks = specs.len();
        let mut sched =
            TwoStepScheduler::new(specs, pool_workers, cfg.sched.clone());
        if let Some(hook) = affinity.clone() {
            sched.set_affinity(hook);
        }
        if let Some(t) = tracker.clone() {
            sched.set_tracker(t);
        }
        let rf_trajectory = vec![dfs.replication_factor()];
        Ok(JobCtx {
            cfg,
            workload,
            dfs,
            sched,
            partials: vec![None; n_tasks],
            remaining: n_tasks,
            n_tasks,
            samples,
            input_bytes,
            startup_s,
            map_t: Timer::start(),
            fetch_times: Vec::with_capacity(n_tasks),
            exec_times: Vec::with_capacity(n_tasks),
            queue_waits: Vec::with_capacity(n_tasks),
            turnarounds: Vec::with_capacity(n_tasks),
            hits: 0,
            misses: 0,
            rf_trajectory,
            ctrl: ControllerState::default(),
            dispatch_s: 0.0,
            dispatch_calls: 0,
            cache_hits: 0,
            cache_misses: 0,
            spec: SpeculationState::new(),
            ledger: Ledger::new(ns.clone()),
            tracker,
            affinity,
            ns,
            rplan: None,
            rqueue: VecDeque::new(),
            rspecs: Vec::new(),
            rdispatch: Vec::new(),
            rprimary: Vec::new(),
            rcloned: Vec::new(),
            reduced: Vec::new(),
            reduce_remaining: 0,
            reduce_speculated: 0,
            reduce_won_by_clone: 0,
            shuffle_bytes: 0,
            shuffle_imbalance: 1.0,
            reduce_turnarounds: Vec::new(),
        })
    }

    /// Claim this job's next task for `worker`, timing the scheduler
    /// interaction (the dispatch half of [`SchedOverhead`]) and
    /// registering the dispatch with the speculation bookkeeping.
    pub(crate) fn next(&mut self, worker: usize) -> Option<TaskSpec> {
        let t = Timer::start();
        let next = self.sched.next(worker);
        self.dispatch_s += t.secs();
        self.dispatch_calls += 1;
        if let Some(spec) = &next {
            // Elastic runs retain specs too: a lost slot's in-flight
            // window re-dispatches from these instead of restarting.
            self.spec.on_dispatch(
                spec,
                worker,
                self.cfg.sched.speculate || self.cfg.elastic,
            );
            self.ledger.dispatched(
                TaskKind::Map,
                spec.task.seq,
                self.cfg.attempt,
                worker,
            );
        }
        next
    }

    /// Record one finished task: collect the partial, feed the
    /// scheduler's feedback loop and the response-time tracker, and
    /// (if enabled) let the replication controller react to the new
    /// fetch/exec balance. Returns `false` for a late duplicate (a
    /// dead speculative clone), which is dropped without touching the
    /// partials or the job-local feedback — keyed on task id, so
    /// arrival order never matters.
    pub(crate) fn on_done(&mut self, d: TaskDone) -> bool {
        self.ledger.completed(TaskKind::Map, d.seq);
        let info = self.spec.on_done(d.seq, d.worker);
        if info.kind == DoneKind::Duplicate || self.partials[d.seq].is_some()
        {
            // Dead-clone cleanup: the winner already landed. The
            // tracker still learns this copy's own dispatch-relative
            // latency — a slow slot's duplicates are exactly the
            // evidence against it (its self-reported timers are not).
            if let Some(t) = &self.tracker {
                t.observe_task(d.worker, info.slot_latency_s);
            }
            return false;
        }
        self.partials[d.seq] = Some(d.partial);
        self.remaining -= 1;
        self.fetch_times.push(d.fetch_s);
        self.exec_times.push(d.exec_s);
        self.queue_waits.push(d.queue_wait_s);
        self.turnarounds.push(info.turnaround_s);
        if let Some(t) = &self.tracker {
            // Charge the reporting slot only for its own copy's wait —
            // a winning clone must not inherit the straggler's delay.
            t.observe_task(d.worker, info.slot_latency_s);
            // Mirror the DFS client's per-node response estimates at a
            // sampled cadence — the diagnostics surface behind
            // `slowest_node` — without paying a store lock plus a Vec
            // per completion on the hot path. (Replica *selection*
            // already reacts to these estimates inside the DFS client
            // itself; slot placement reacts via the turnarounds above,
            // which include fetch time.)
            const NODE_MIRROR_EVERY: usize = 16;
            if self.turnarounds.len() % NODE_MIRROR_EVERY == 1 {
                t.ingest_node_responses(&self.dfs.per_node_response());
            }
        }
        self.hits += d.prefetch_hits;
        self.misses += d.prefetch_misses;
        self.cache_hits += d.cache_hits;
        self.cache_misses += d.cache_misses;
        let t = Timer::start();
        self.sched.report(d.worker, d.fetch_s, d.exec_s);
        self.dispatch_s += t.secs();
        self.dispatch_calls += 1;
        if self.cfg.adaptive_rf {
            if let (Some(fetch), Some(exec)) =
                (self.sched.observed_fetch_s(), self.sched.observed_exec_s())
            {
                let cur = self.dfs.replication_factor();
                let next = decide(
                    &self.cfg.replication,
                    &mut self.ctrl,
                    fetch,
                    exec,
                    cur,
                );
                if next != cur {
                    self.dfs.set_replication_factor(next);
                    self.rf_trajectory.push(next);
                }
            }
        }
        true
    }

    /// Fold link-send time into the dispatch half of
    /// [`SchedOverhead`] — the wire cost of getting a refill window
    /// onto a link is dispatch overhead exactly like the scheduler
    /// claim that produced it (one call per frame, so batching shows
    /// up as fewer, slightly larger calls).
    pub(crate) fn note_dispatch(&mut self, secs: f64) {
        self.dispatch_s += secs;
        self.dispatch_calls += 1;
    }

    /// Whether dispatches should coalesce into `TaskBatch` frames.
    pub(crate) fn batch_dispatch(&self) -> bool {
        self.cfg.batch_dispatch
    }

    /// Dispatch window for `slot` under this job's config: the base
    /// lookahead normally, collapsing to one task for slots the
    /// tracker has watched straggle.
    pub(crate) fn inflight_target(&self, slot: usize, base: usize) -> usize {
        inflight_target(self.tracker.as_deref(), slot, base)
    }

    /// Speculative re-execution step: among in-flight tasks older than
    /// the straggler threshold (and never cloned before), pick for
    /// each the best idle slot by [`placement_score`] — affinity
    /// credit minus predicted completion — and return the
    /// `(slot, spec)` clones to dispatch. Consumes each idle slot at
    /// most once per call; returns nothing until the tracker has
    /// enough samples for a threshold.
    pub(crate) fn clone_candidates(
        &mut self,
        idle: &[usize],
    ) -> Vec<(usize, TaskSpec)> {
        if !self.cfg.sched.speculate || idle.is_empty() {
            return Vec::new();
        }
        let Some(tracker) = self.tracker.clone() else {
            return Vec::new();
        };
        let Some(threshold) =
            tracker.straggler_threshold_s(self.cfg.sched.straggler_pct)
        else {
            return Vec::new();
        };
        let mut free: Vec<usize> = idle.to_vec();
        let mut clones = Vec::new();
        for seq in self.spec.overdue(threshold) {
            if free.is_empty() {
                break;
            }
            let Some(primary) = self.spec.primary_of(seq) else {
                continue;
            };
            let Some(spec) = self.spec.spec_of(seq).cloned() else {
                continue;
            };
            let target = free
                .iter()
                .copied()
                .filter(|&w| w != primary)
                .max_by(|&a, &b| {
                    let score = |w: usize| {
                        placement_score(
                            self.affine_blocks(&spec, w),
                            tracker.predicted_task_s(w),
                        )
                    };
                    score(a)
                        .partial_cmp(&score(b))
                        .expect("placement scores are finite")
                        // prefer the lower slot index on ties
                        .then(b.cmp(&a))
                });
            let Some(w) = target else { continue };
            if self.spec.mark_cloned(seq, w) {
                self.ledger.dispatched(
                    TaskKind::Map,
                    seq,
                    self.cfg.attempt,
                    w,
                );
                free.retain(|&x| x != w);
                clones.push((w, spec));
            }
        }
        clones
    }

    /// A clone dispatch failed before it left the leader: make the
    /// straggler cloneable again (see
    /// [`SpeculationState::cancel_clone`]).
    pub(crate) fn cancel_clone(&mut self, seq: usize) {
        self.spec.cancel_clone(seq);
    }

    /// Absorb a joining slot (elastic membership): grow the scheduler
    /// (fresh queue, probe step pending, feedback lane) and give the
    /// newcomer a pessimistic response-time prior so dynamic placement
    /// ramps it up instead of trusting it blindly. Returns the new
    /// slot index.
    pub(crate) fn add_worker(&mut self) -> usize {
        let slot = self.sched.add_worker();
        if let Some(t) = &self.tracker {
            t.seed_pessimistic(slot);
        }
        slot
    }

    /// A slot left the membership (drained or lost): reclaim its
    /// queued-but-unclaimed tasks into the pending pool, and re-dispatch
    /// exactly the ledger's sole-carrier in-flight units — map specs
    /// re-enter the scheduler, reduce partitions re-enter the reduce
    /// queue. Durable outputs (collected partials, staged shuffle
    /// fragments) are untouched, which is the task-level-checkpoint
    /// claim. Errs only when a stranded unit's spec cannot be
    /// recovered — the caller falls back to job-level recovery.
    /// Returns how many units were re-dispatched.
    pub(crate) fn on_member_lost(&mut self, worker: usize) -> Result<usize> {
        let t = Timer::start();
        self.sched.retire_worker(worker);
        let stranded = self.ledger.inflight_of(worker);
        let mut map_specs = Vec::new();
        let mut redispatched = 0u64;
        for (kind, seq) in stranded {
            match kind {
                TaskKind::Map => {
                    if self.partials[seq].is_some() {
                        continue;
                    }
                    let Some(spec) = self.spec.abandon(seq) else {
                        return Err(Error::Scheduler(format!(
                            "worker {worker} left with map task {seq} in \
                             flight and no retained spec; falling back to \
                             job-level recovery"
                        )));
                    };
                    map_specs.push(spec);
                    redispatched += 1;
                }
                TaskKind::Reduce => {
                    if self.reduced[seq].is_some() {
                        continue;
                    }
                    let Some(spec) = self.rspecs[seq].clone() else {
                        return Err(Error::Scheduler(format!(
                            "worker {worker} left with reduce partition \
                             {seq} in flight and no retained spec; falling \
                             back to job-level recovery"
                        )));
                    };
                    self.rqueue.push_back(spec);
                    self.rdispatch[seq] = None;
                    self.rprimary[seq] = None;
                    self.rcloned[seq] = false;
                    redispatched += 1;
                }
            }
        }
        self.sched.requeue(map_specs);
        self.ledger.forget_worker(worker);
        self.ledger.note_redispatch(redispatched);
        self.dispatch_s += t.secs();
        self.dispatch_calls += 1;
        Ok(redispatched as usize)
    }

    /// How many of `spec`'s blocks the affinity registry attributes to
    /// `slot` (0 without affinity dispatch).
    fn affine_blocks(&self, spec: &TaskSpec, slot: usize) -> usize {
        let Some(hook) = &self.affinity else { return 0 };
        hook.index.score(
            slot,
            spec.task
                .sample_ids
                .iter()
                .map(|&id| block_key(&hook.ns, spec.workload, id)),
        )
    }

    /// Everything collected — map partials and, for `reduce_tasks > 1`,
    /// every reduce partition — so the job can produce its output.
    pub(crate) fn is_complete(&self) -> bool {
        self.remaining == 0
            && (self.cfg.reduce_tasks <= 1
                || (self.rplan.is_some() && self.reduce_remaining == 0))
    }

    /// Whether the executed reduce phase still has (or will have) work
    /// for the pool — drivers keep idle workers alive while this holds
    /// instead of shutting them down at map-scheduler dryness.
    pub(crate) fn expects_reduce_work(&self) -> bool {
        self.cfg.reduce_tasks > 1 && !self.is_complete()
    }

    /// Once the last map partial lands (and `reduce_tasks > 1`): compute
    /// observed key weights from the complete seq-ordered partial set,
    /// build the partition plan, slice every partial into per-partition
    /// fragments, and register them in the replicated store — shuffle
    /// fetches then ride the exact same leader-proxied DFS path (and
    /// block cache) as map-input blocks. Returns `true` when the
    /// shuffle just started, so the driver can top every idle slot up
    /// with reduce work. Idempotent; a no-op for `reduce_tasks <= 1`.
    pub(crate) fn maybe_start_shuffle(
        &mut self,
        params: &ModelParams,
    ) -> Result<bool> {
        if self.cfg.reduce_tasks <= 1
            || self.rplan.is_some()
            || self.remaining != 0
        {
            return Ok(false);
        }
        let collected: Vec<TaskPartial> = self
            .partials
            .iter()
            .map(|p| p.clone().expect("map phase complete"))
            .collect();
        let weights =
            crate::reduce::key_weights(self.workload, params, &collected)?;
        let plan = crate::reduce::build_plan(
            self.cfg.partitioner,
            &weights,
            self.cfg.reduce_tasks,
        );
        self.shuffle_imbalance = plan.imbalance_factor(&weights);
        let (blocks, staged) = crate::reduce::stage_fragments(
            params,
            &self.ns,
            &plan,
            &collected,
        )?;
        // Re-staging on a recovered attempt overwrites with identical
        // bytes — the plan is a pure function of the seq-ordered
        // partials, never of arrival order.
        for (key, data) in blocks {
            self.dfs.put(&key, data);
        }
        self.shuffle_bytes = staged;
        let r = plan.partitions;
        for partition in 0..r {
            let spec = ReduceSpec {
                partition,
                partitions: r,
                n_tasks: self.n_tasks as u32,
                workload: self.workload,
                keys: plan.keys_of(partition),
            };
            self.rspecs.push(Some(spec.clone()));
            self.rqueue.push_back(spec);
        }
        self.rdispatch = vec![None; r as usize];
        self.rprimary = vec![None; r as usize];
        self.rcloned = vec![false; r as usize];
        self.reduced = vec![None; r as usize];
        self.reduce_remaining = r as usize;
        self.rplan = Some(plan);
        Ok(true)
    }

    /// Claim the next unclaimed reduce partition for `worker`, timing
    /// the interaction like [`JobCtx::next`].
    pub(crate) fn next_reduce(&mut self, worker: usize) -> Option<ReduceSpec> {
        let t = Timer::start();
        let next = self.rqueue.pop_front();
        self.dispatch_s += t.secs();
        self.dispatch_calls += 1;
        if let Some(spec) = &next {
            let p = spec.partition as usize;
            self.rdispatch[p] = Some(Timer::start());
            self.rprimary[p] = Some(worker);
            self.ledger.dispatched(
                TaskKind::Reduce,
                p,
                self.cfg.attempt,
                worker,
            );
        }
        next
    }

    /// Record one finished reduce partition. Returns `false` for a late
    /// duplicate (the losing copy of a speculative pair), which is
    /// dropped — results are keyed on partition id, never arrival
    /// order, so whichever bit-identical copy lands first wins.
    pub(crate) fn on_reduce_done(&mut self, d: ReduceDone) -> bool {
        let p = d.partition as usize;
        self.ledger.completed(TaskKind::Reduce, p);
        let latency = self.rdispatch[p].as_ref().map_or(0.0, |t| t.secs());
        if let Some(t) = &self.tracker {
            t.observe_task(d.worker, latency);
        }
        if p >= self.reduced.len() || self.reduced[p].is_some() {
            return false;
        }
        if self.rcloned[p] && self.rprimary[p] != Some(d.worker) {
            self.reduce_won_by_clone += 1;
        }
        self.reduced[p] = Some(d.partial);
        self.reduce_remaining -= 1;
        self.reduce_turnarounds.push(latency);
        self.queue_waits.push(d.queue_wait_s);
        true
    }

    /// Speculative re-execution for the reduce phase: overdue
    /// partitions (dispatched, unfinished, never cloned) are re-sent to
    /// the fastest-looking idle slot that is not the primary.
    pub(crate) fn reduce_clone_candidates(
        &mut self,
        idle: &[usize],
    ) -> Vec<(usize, ReduceSpec)> {
        if !self.cfg.sched.speculate
            || idle.is_empty()
            || self.rplan.is_none()
        {
            return Vec::new();
        }
        let Some(tracker) = self.tracker.clone() else {
            return Vec::new();
        };
        let Some(threshold) =
            tracker.straggler_threshold_s(self.cfg.sched.straggler_pct)
        else {
            return Vec::new();
        };
        let mut free: Vec<usize> = idle.to_vec();
        let mut clones = Vec::new();
        for p in 0..self.reduced.len() {
            if free.is_empty() {
                break;
            }
            let overdue = self.reduced[p].is_none()
                && !self.rcloned[p]
                && self.rdispatch[p]
                    .as_ref()
                    .is_some_and(|t| t.secs() > threshold);
            if !overdue {
                continue;
            }
            let primary = self.rprimary[p];
            let target = free
                .iter()
                .copied()
                .filter(|&w| Some(w) != primary)
                .min_by(|&a, &b| {
                    tracker
                        .predicted_task_s(a)
                        .partial_cmp(&tracker.predicted_task_s(b))
                        .expect("predictions are finite")
                        .then(a.cmp(&b))
                });
            let Some(w) = target else { continue };
            let Some(spec) = self.rspecs[p].clone() else {
                continue;
            };
            self.rcloned[p] = true;
            self.reduce_speculated += 1;
            self.ledger.dispatched(TaskKind::Reduce, p, self.cfg.attempt, w);
            free.retain(|&x| x != w);
            clones.push((w, spec));
        }
        clones
    }

    /// A reduce clone failed to leave the leader: make its partition
    /// cloneable again.
    pub(crate) fn cancel_reduce_clone(&mut self, partition: u32) {
        let p = partition as usize;
        if p < self.rcloned.len() {
            self.rcloned[p] = false;
            self.reduce_speculated = self.reduce_speculated.saturating_sub(1);
        }
    }

    /// Seq-ordered reduce plus the job report. Errors if any task
    /// produced no partial (an aborted or still-running job).
    pub(crate) fn finish(self, backend: &Backend) -> Result<FinishedJob> {
        let map_s = self.map_t.secs();
        let collected: Vec<TaskPartial> = self
            .partials
            .into_iter()
            .enumerate()
            .map(|(seq, p)| {
                p.ok_or_else(|| {
                    Error::Scheduler(format!("task {seq} produced no partial"))
                })
            })
            .collect::<Result<_>>()?;
        let params = backend.manifest().params.clone();
        let reduce_t = Timer::start();
        let output = match (&self.rplan, self.cfg.reduce_tasks) {
            // Executed reduce: assemble each output lane from the
            // partition that owns its key. Bit-identical to the r=1
            // leader-side path by the zero-padded full-shape argument
            // (DESIGN.md §13).
            (Some(plan), r) if r > 1 => {
                let reduced: Vec<TaskPartial> = self
                    .reduced
                    .into_iter()
                    .enumerate()
                    .map(|(p, out)| {
                        out.ok_or_else(|| {
                            Error::Scheduler(format!(
                                "reduce partition {p} produced no partial"
                            ))
                        })
                    })
                    .collect::<Result<_>>()?;
                crate::reduce::assemble_output(
                    &params,
                    self.workload,
                    plan,
                    &reduced,
                )?
            }
            _ => reduce_partials(backend, &params, self.workload, collected)?,
        };
        let reduce_s = reduce_t.secs();
        let (h, m) = (self.hits, self.misses);
        let report = JobReport {
            workload: self.workload.name().to_string(),
            platform: self.cfg.platform.clone(),
            tasks: self.n_tasks,
            samples: self.samples,
            input_bytes: self.input_bytes,
            startup_s: self.startup_s,
            map_s,
            reduce_s,
            total_s: self.startup_s + self.map_t.secs(),
            task_exec: summarize(if self.exec_times.is_empty() {
                &[0.0]
            } else {
                &self.exec_times
            }),
            task_fetch: summarize(if self.fetch_times.is_empty() {
                &[0.0]
            } else {
                &self.fetch_times
            }),
            task_turnaround: summarize(if self.turnarounds.is_empty() {
                &[0.0]
            } else {
                &self.turnarounds
            }),
            speculated: self.spec.speculated() + self.reduce_speculated,
            won_by_clone: self.spec.won_by_clone()
                + self.reduce_won_by_clone,
            reduce_tasks: self.cfg.reduce_tasks.max(1),
            shuffle_bytes: self.shuffle_bytes,
            shuffle_imbalance: self.shuffle_imbalance,
            reduce_turnaround: summarize(
                if self.reduce_turnarounds.is_empty() {
                    &[0.0]
                } else {
                    &self.reduce_turnarounds
                },
            ),
            prefetch_hit_rate: if h + m == 0 {
                0.0
            } else {
                h as f64 / (h + m) as f64
            },
            cache_hit_rate: {
                let (ch, cm) = (self.cache_hits, self.cache_misses);
                if ch + cm == 0 {
                    0.0
                } else {
                    ch as f64 / (ch + cm) as f64
                }
            },
            final_rf: self.dfs.replication_factor(),
            restarts: self.cfg.attempt - 1,
            // Wire counters are pool-owned; the driver fills them in
            // (run_cluster from its run-local counters, the serve
            // dispatcher from the pool's).
            frames_sent: 0,
            frames_batched: 0,
            wire_bytes: 0,
            blocks_zero_copy: 0,
        };
        let overhead = SchedOverhead {
            dispatch_s: self.dispatch_s,
            dispatch_calls: self.dispatch_calls,
            queue_wait: summarize(if self.queue_waits.is_empty() {
                &[0.0]
            } else {
                &self.queue_waits
            }),
        };
        let mut sched = self.sched.snapshot();
        sched.speculated = self.spec.speculated() + self.reduce_speculated;
        sched.won_by_clone =
            self.spec.won_by_clone() + self.reduce_won_by_clone;
        Ok(FinishedJob {
            output,
            report,
            sched,
            overhead,
            rf_trajectory: self.rf_trajectory,
            re_dispatched: self.ledger.re_dispatched(),
        })
    }
}

/// Keep `worker` topped up to its dispatch-window target (the base
/// lookahead, collapsed to 1 for tracker-flagged slow slots). Sends
/// `Shutdown` (and retires the link) once the scheduler is dry for
/// this worker and nothing is in flight — unless speculation is
/// armed, in which case idle slots stay alive until the job completes
/// so they can host straggler clones (the completion path shuts them
/// down).
#[allow(clippy::too_many_arguments)]
fn top_up(
    ctx: &mut JobCtx,
    links: &[WorkerLink],
    retired: &mut [bool],
    inflight: &mut [usize],
    w: usize,
    base_target: usize,
    attempt: u32,
    ns: &Arc<str>,
    speculate: bool,
) {
    let target = ctx.inflight_target(w, base_target);
    let batch = ctx.batch_dispatch();
    while !retired[w] && inflight[w] < target {
        // Collect this wakeup's refill window for the slot. Batched,
        // the whole window leaves as one `TaskBatch` frame — the
        // window size *is* the batch size, no separate knob; unbatched
        // reproduces the historical one-frame-per-task path.
        let mut burst: Vec<TaskEnvelope> = Vec::new();
        while inflight[w] + burst.len() < target {
            match ctx.next(w) {
                Some(spec) => {
                    burst.push(TaskEnvelope {
                        job: 0,
                        attempt,
                        ns: ns.clone(),
                        spec,
                        poison: false,
                    });
                    if !batch {
                        break;
                    }
                }
                None => break,
            }
        }
        if !burst.is_empty() {
            let n = burst.len();
            let t = Timer::start();
            let sent = if n == 1 {
                let env = burst.pop().expect("len checked");
                links[w].send(Down::Task(Box::new(env)))
            } else {
                links[w].send(Down::TaskBatch(burst))
            };
            ctx.note_dispatch(t.secs());
            if sent {
                inflight[w] += n;
                continue;
            }
            // Link gone; its Lost/Exited message explains.
            retired[w] = true;
            return;
        }
        // Map scheduler dry for this slot: the reduce phase (if any)
        // feeds it next — reducer slots refill through the same
        // dispatch window as map slots.
        if let Some(rspec) = ctx.next_reduce(w) {
            let env = ReduceEnvelope {
                job: 0,
                attempt,
                ns: ns.clone(),
                spec: rspec,
            };
            if links[w].send(Down::Reduce(Box::new(env))) {
                inflight[w] += 1;
                continue;
            }
            retired[w] = true;
            return;
        }
        // Keep idle slots alive while a reduce phase is still pending
        // (its dispatches only exist once the last map partial lands)
        // or speculation may still clone.
        if inflight[w] == 0 && !speculate && !ctx.expects_reduce_work() {
            let _ = links[w].send(Down::Shutdown);
            retired[w] = true;
        }
        return;
    }
}

/// Absorb a joining worker into a running attempt (elastic
/// membership): grow every per-slot vector, register the slot with the
/// scheduler and tracker via [`JobCtx::add_worker`], and immediately
/// top the newcomer up — the refill's busy-skip sweep and steal path
/// rebalance queued work onto it from there.
#[allow(clippy::too_many_arguments)]
fn admit(
    ctx: &mut JobCtx,
    links: &mut Vec<WorkerLink>,
    retired: &mut Vec<bool>,
    inflight: &mut Vec<usize>,
    worker_stats: &mut Vec<Option<WorkerStats>>,
    link: WorkerLink,
    base_target: usize,
    attempt: u32,
    ns: &Arc<str>,
    speculate: bool,
) {
    let slot = ctx.add_worker();
    debug_assert_eq!(slot, links.len(), "acceptor slots are sequential");
    links.push(link);
    retired.push(false);
    inflight.push(0);
    worker_stats.push(None);
    top_up(
        ctx, links, retired, inflight, slot, base_target, attempt, ns,
        speculate,
    );
}

/// Run one cluster attempt. A worker failure — injected, real, or a
/// dropped remote link — surfaces as `Err` after an orderly abort;
/// job-level recovery restarts the whole job, never a task. With
/// [`ExecConfig::elastic`] on, membership changes (joins, drains,
/// crashes) are absorbed live instead: the ledger re-dispatches only
/// the departed slot's in-flight window.
pub fn run_cluster(
    dataset: &dyn Dataset,
    backend: Arc<Backend>,
    cfg: &ExecConfig,
) -> Result<ExecResult> {
    let slots = cfg.slots();
    if slots == 0 {
        return Err(Error::Config(
            "cluster needs at least one worker (local or remote)".into(),
        ));
    }
    let params = backend.manifest().params.clone();
    let workload = dataset.workload();
    let total_t = Timer::start();

    // ---- startup: pack, stage, schedule --------------------------------
    let metas = dataset.metas();
    if metas.is_empty() {
        return Err(Error::Data("empty dataset".into()));
    }
    let tasks = crate::kneepoint::pack(metas, cfg.sizing);
    let n_tasks = tasks.len();
    let mean_task_bytes =
        tasks.iter().map(|t| t.bytes).sum::<usize>() / n_tasks.max(1);
    let rf0 = initial_data_nodes(
        slots,
        mean_task_bytes,
        0.05, // pre-probe guess; the controller corrects it online
        &cfg.replication,
    )
    .min(cfg.data_nodes);
    let dfs = Dfs::new(cfg.data_nodes, rf0, cfg.latency.clone());
    let layer = CacheLayer::build(&dfs, cfg.cache_mb, cfg.affinity);
    let (samples, input_bytes, _keys) = stage_dataset(dataset, &dfs, "");
    let specs: Vec<TaskSpec> = tasks
        .into_iter()
        .map(|t| TaskSpec::new(t, workload, cfg.seed))
        .collect();
    let startup_s = total_t.secs();
    // Dynamic mode: one response-time tracker for the run, shared by
    // the scheduler (refill sizing), the leader (dispatch windows and
    // straggler thresholds), and the remote link pumps (heartbeat-gap
    // overruns).
    let tracker = cfg
        .sched
        .wants_tracker()
        .then(|| Arc::new(ResponseTimeTracker::new()));
    let speculate = cfg.sched.speculate;
    let ns: Arc<str> = Arc::from("");
    let mut ctx = JobCtx::new(
        specs,
        dfs.clone(),
        cfg.clone(),
        slots,
        samples,
        input_bytes,
        startup_s,
        layer.hook("".into()),
        tracker.clone(),
        ns.clone(),
    )?;

    // ---- map phase: stand up the links, lead the job --------------------
    let (up_tx, up_rx) = mpsc::channel::<Up>();
    let mut links: Vec<WorkerLink> = Vec::with_capacity(slots);
    for w in 0..cfg.workers {
        let body = BodyCfg {
            worker: w,
            prefetch_k: cfg.prefetch_k,
            failure: cfg.failure,
            // Solo semantics: a task error is fatal to the attempt.
            survive_task_errors: false,
            affinity: layer.affinity.clone(),
            turbulence: cfg.turbulence.clone(),
        };
        links.push(WorkerLink::spawn_inproc(
            body,
            params.clone(),
            backend.clone(),
            dfs.clone(),
            up_tx.clone(),
            "bts-exec-worker",
        )?);
    }
    // The membership acceptor replaces the one-shot accept loop: it
    // keeps admitting for the whole attempt, so late `bts worker
    // --connect`s join mid-job (elastic) or get a versioned refusal
    // frame (frozen) instead of silently rotting in the backlog.
    let mut acceptor: Option<Acceptor> = None;
    let mut pending_drains: Vec<usize> = Vec::new();
    // One wire-counter instance per run (never a global static — a
    // process can lead several jobs at once through the serve layer,
    // and each must report its own traffic).
    let net = Arc::new(NetCounters::default());
    if let Some(remote) = &cfg.remote {
        let acc = match Acceptor::spawn(
            remote.listener.clone(),
            cfg.workers,
            remote.count,
            cfg.elastic,
            dfs.clone(),
            up_tx.clone(),
            tracker.clone(),
            PumpCfg::from_heartbeat_ms(cfg.heartbeat_ms),
            net.clone(),
        ) {
            Ok(a) => a,
            Err(e) => {
                // Orderly teardown of whatever already stood up.
                teardown(links);
                return Err(e);
            }
        };
        // Initial quota: the statically requested --workers-remote set,
        // with the same per-worker patience as before.
        while links.len() < cfg.workers + remote.count {
            match acc.wait_event(ACCEPT_TIMEOUT) {
                Some(MemberEvent::Joined(link)) => links.push(link),
                Some(MemberEvent::DrainRequested(w)) => {
                    pending_drains.push(w);
                }
                None => {
                    acc.stop();
                    teardown(links);
                    return Err(Error::Protocol(format!(
                        "timed out waiting for the initial {} remote \
                         worker(s)",
                        remote.count
                    )));
                }
            }
        }
        acceptor = Some(acc);
    }
    drop(up_tx);
    let elastic = cfg.elastic;

    let target = cfg.inflight.max(1);
    let mut inflight = vec![0usize; slots];
    let mut retired = vec![false; slots];
    for w in 0..slots {
        top_up(
            &mut ctx,
            &links,
            &mut retired,
            &mut inflight,
            w,
            target,
            cfg.attempt,
            &ns,
            speculate,
        );
    }

    let mut worker_stats: Vec<Option<WorkerStats>> = vec![None; slots];
    let mut first_err: Option<Error> = None;

    // Drain requests that raced the standup apply now that every
    // initial slot is live.
    for w in pending_drains {
        if w < links.len() && !retired[w] {
            let _ = links[w].send(Down::Drain);
        }
    }

    // Shut every live worker down (orderly): a worker mid-task finishes
    // it, then sees the Shutdown during its drain and abandons anything
    // still queued — which is what reclaims dead speculative clones.
    let shutdown_all = |links: &[WorkerLink], retired: &mut [bool]| {
        for (w, link) in links.iter().enumerate() {
            if !retired[w] {
                let _ = link.send(Down::Shutdown);
                retired[w] = true;
            }
        }
    };

    // Speculation and the membership plane both need the leader to
    // wake on a timer — the former to age in-flight tasks, the latter
    // to poll acceptor events; a purely static run blocks as before.
    let poll = speculate || elastic || acceptor.is_some();
    let poll_interval = cfg.sched.straggler_poll();
    while worker_stats.iter().any(|s| s.is_none())
        || (elastic
            && acceptor.is_some()
            && first_err.is_none()
            && !ctx.is_complete())
    {
        let msg = if poll {
            match up_rx.recv_timeout(poll_interval) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        } else {
            match up_rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break, // every up-channel sender gone
            }
        };
        // A `DoneBatch` frame is several completions in one message:
        // unpack it into the per-completion events the arms below
        // already handle — batching changes the wire, not the leader's
        // bookkeeping.
        let events: Vec<Up> = match msg {
            None => Vec::new(),
            Some(Up::DoneBatch(items)) => items
                .into_iter()
                .map(|it| Up::Done {
                    job: it.job,
                    attempt: it.attempt,
                    done: Box::new(it.done),
                })
                .collect(),
            Some(m) => vec![m],
        };
        // Completion refills are deferred past the event loop: a
        // DoneBatch freeing several of a worker's slots must refill
        // them as ONE TaskBatch burst, not per-completion singles.
        let mut refill: Vec<usize> = Vec::new();
        let mut refill_all = false;
        for ev in events {
            match ev {
            Up::Done { done, .. } => {
                let w = done.worker;
                inflight[w] = inflight[w].saturating_sub(1);
                ctx.on_done(*done);
                // The last map partial arms the shuffle: stage the
                // fragments and refill *every* slot — idle workers are
                // blocked waiting and must be handed reduce work.
                let shuffle_started = match ctx.maybe_start_shuffle(&params)
                {
                    Ok(started) => started,
                    Err(e) => {
                        first_err.get_or_insert(e);
                        shutdown_all(&links, &mut retired);
                        continue;
                    }
                };
                if ctx.is_complete() {
                    // The statistic is fully collected: release every
                    // worker now instead of waiting out stragglers
                    // that only dead clones still cover.
                    shutdown_all(&links, &mut retired);
                } else if shuffle_started {
                    // The last map partial armed the shuffle: idle
                    // workers are blocked waiting and must be handed
                    // reduce work.
                    refill_all = true;
                } else {
                    refill.push(w);
                }
            }
            Up::ReduceDone { done, .. } => {
                let w = done.worker;
                inflight[w] = inflight[w].saturating_sub(1);
                ctx.on_reduce_done(*done);
                if ctx.is_complete() {
                    shutdown_all(&links, &mut retired);
                } else {
                    refill.push(w);
                }
            }
            Up::Lost { worker, error: _ }
                if elastic && !ctx.is_complete() =>
            {
                // Elastic loss absorption: the dead slot's queued work
                // folds back into the pool and its sole-carrier
                // in-flight units re-dispatch; survivors keep going.
                retired[worker] = true;
                inflight[worker] = 0;
                match ctx.on_member_lost(worker) {
                    Ok(_) => {
                        for slot in 0..links.len() {
                            if !retired[slot] {
                                top_up(
                                    &mut ctx,
                                    &links,
                                    &mut retired,
                                    &mut inflight,
                                    slot,
                                    target,
                                    cfg.attempt,
                                    &ns,
                                    speculate,
                                );
                            }
                        }
                    }
                    Err(e) => {
                        first_err.get_or_insert(e);
                        shutdown_all(&links, &mut retired);
                    }
                }
            }
            Up::TaskFailed { error, .. } | Up::Lost { error, .. } => {
                // A failure arriving after the statistic is fully
                // collected can only come from a dead speculative copy
                // (or a link dropping during the drain): the job's
                // result is already in hand, so don't discard it.
                if !ctx.is_complete() {
                    first_err.get_or_insert(error);
                }
                // Orderly abort: every live worker drains its channel
                // and stops at the Shutdown marker.
                shutdown_all(&links, &mut retired);
            }
            Up::Drained { worker, returned: _ } => {
                // Graceful departure (`bts drain` or a SIGTERMed
                // worker): its returned queue and sole-carrier
                // in-flight units redistribute over the survivors. The
                // worker follows up with a clean Exited.
                retired[worker] = true;
                inflight[worker] = 0;
                match ctx.on_member_lost(worker) {
                    Ok(_) => {
                        for slot in 0..links.len() {
                            if !retired[slot] {
                                top_up(
                                    &mut ctx,
                                    &links,
                                    &mut retired,
                                    &mut inflight,
                                    slot,
                                    target,
                                    cfg.attempt,
                                    &ns,
                                    speculate,
                                );
                            }
                        }
                    }
                    Err(e) => {
                        // No retained spec to re-dispatch from: fall
                        // back to job-level recovery.
                        if !ctx.is_complete() {
                            first_err.get_or_insert(e);
                        }
                        shutdown_all(&links, &mut retired);
                    }
                }
            }
            // Solo runs never send Abort, so acks cannot arrive.
            Up::Aborted { .. } => {}
            // Batches were unpacked into the events vector above.
            Up::DoneBatch(_) => unreachable!("batches unpack above"),
            Up::Exited { worker, executed, clean } => {
                let lost_mid_job = !clean
                    && worker_stats[worker].is_none()
                    && !ctx.is_complete();
                worker_stats[worker] = Some(WorkerStats {
                    worker,
                    executed,
                    clean_shutdown: clean,
                });
                if lost_mid_job {
                    // A crash with no goodbye (in-proc kill, or the
                    // pump's synthesized exit after a Lost).
                    retired[worker] = true;
                    inflight[worker] = 0;
                    if elastic {
                        match ctx.on_member_lost(worker) {
                            Ok(_) => {
                                for slot in 0..links.len() {
                                    if !retired[slot] {
                                        top_up(
                                            &mut ctx,
                                            &links,
                                            &mut retired,
                                            &mut inflight,
                                            slot,
                                            target,
                                            cfg.attempt,
                                            &ns,
                                            speculate,
                                        );
                                    }
                                }
                            }
                            Err(e) => {
                                first_err.get_or_insert(e);
                                shutdown_all(&links, &mut retired);
                            }
                        }
                    } else {
                        first_err.get_or_insert(Error::Scheduler(format!(
                            "worker {worker} exited uncleanly mid-job"
                        )));
                        shutdown_all(&links, &mut retired);
                    }
                }
            }
            }
        }
        // Deferred refill pass: one top_up per worker that freed
        // slots this wakeup (top_up skips retired/complete slots).
        if refill_all {
            for slot in 0..links.len() {
                top_up(
                    &mut ctx,
                    &links,
                    &mut retired,
                    &mut inflight,
                    slot,
                    target,
                    cfg.attempt,
                    &ns,
                    speculate,
                );
            }
        } else if !refill.is_empty() {
            refill.sort_unstable();
            refill.dedup();
            for w in refill {
                top_up(
                    &mut ctx,
                    &links,
                    &mut retired,
                    &mut inflight,
                    w,
                    target,
                    cfg.attempt,
                    &ns,
                    speculate,
                );
            }
        }
        // Membership plane: absorb joins, route drain requests. A
        // joiner arriving after the outcome is settled is dismissed
        // politely instead of being grown into a finished job.
        if let Some(acc) = &acceptor {
            while let Some(ev) = acc.try_event() {
                match ev {
                    MemberEvent::Joined(link) => {
                        if first_err.is_some() || ctx.is_complete() {
                            let _ = link.send(Down::Shutdown);
                            link.join();
                        } else {
                            admit(
                                &mut ctx,
                                &mut links,
                                &mut retired,
                                &mut inflight,
                                &mut worker_stats,
                                link,
                                target,
                                cfg.attempt,
                                &ns,
                                speculate,
                            );
                        }
                    }
                    MemberEvent::DrainRequested(w) => {
                        if w < links.len() && !retired[w] {
                            let _ = links[w].send(Down::Drain);
                        }
                    }
                }
            }
        }
        // Membership stall: every slot has left with the job
        // incomplete. An elastic leader waits (bounded by the accept
        // patience) for a rescuing joiner; anyone else hands the
        // attempt to job-level recovery.
        if first_err.is_none()
            && !ctx.is_complete()
            && (0..links.len()).all(|w| retired[w])
        {
            let mut rescued = false;
            if elastic {
                if let Some(acc) = &acceptor {
                    let deadline = Instant::now() + ACCEPT_TIMEOUT;
                    loop {
                        let left =
                            deadline.saturating_duration_since(Instant::now());
                        if left.is_zero() {
                            break;
                        }
                        match acc.wait_event(left) {
                            Some(MemberEvent::Joined(link)) => {
                                admit(
                                    &mut ctx,
                                    &mut links,
                                    &mut retired,
                                    &mut inflight,
                                    &mut worker_stats,
                                    link,
                                    target,
                                    cfg.attempt,
                                    &ns,
                                    speculate,
                                );
                                rescued = true;
                                break;
                            }
                            Some(MemberEvent::DrainRequested(_)) => {}
                            None => break,
                        }
                    }
                }
            }
            if !rescued {
                first_err.get_or_insert(Error::Scheduler(
                    "every worker left the membership mid-job and no \
                     replacement joined"
                        .into(),
                ));
            }
        }
        // Speculative re-execution: clone overdue in-flight tasks to
        // the best idle slots (first bit-identical result wins).
        if speculate && first_err.is_none() && !ctx.is_complete() {
            let idle: Vec<usize> = (0..links.len())
                .filter(|&w| !retired[w] && inflight[w] == 0)
                .collect();
            for (w, spec) in ctx.clone_candidates(&idle) {
                let seq = spec.task.seq;
                let env = TaskEnvelope {
                    job: 0,
                    attempt: cfg.attempt,
                    ns: ns.clone(),
                    spec,
                    poison: false,
                };
                if links[w].send(Down::Task(Box::new(env))) {
                    inflight[w] += 1;
                } else {
                    // The clone never left the leader: retire the dead
                    // link and give the straggler its attempt back.
                    retired[w] = true;
                    ctx.cancel_clone(seq);
                }
            }
            // Overdue reduce partitions get the same treatment: first
            // bit-identical copy wins, the loser is dropped on arrival.
            let idle: Vec<usize> = (0..links.len())
                .filter(|&w| !retired[w] && inflight[w] == 0)
                .collect();
            for (w, rspec) in ctx.reduce_clone_candidates(&idle) {
                let partition = rspec.partition;
                let env = ReduceEnvelope {
                    job: 0,
                    attempt: cfg.attempt,
                    ns: ns.clone(),
                    spec: rspec,
                };
                if links[w].send(Down::Reduce(Box::new(env))) {
                    inflight[w] += 1;
                } else {
                    retired[w] = true;
                    ctx.cancel_reduce_clone(partition);
                }
            }
        }
    }

    // The membership plane closes before the links do: queued joiners
    // are dismissed with a clean Shutdown, late connects get a closed
    // port instead of a wedged backlog.
    if let Some(acc) = acceptor.take() {
        acc.stop();
    }

    // Leader joins every link before touching the partials — the
    // shutdown-ordering contract.
    for l in links {
        if !l.join() {
            first_err
                .get_or_insert(Error::Scheduler("worker panicked".into()));
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }

    // ---- shuffle sanity + reduce (on the leader, via the backend) -------
    let mut fin = ctx.finish(backend.as_ref())?;
    // The wire counters live with the run, not the job context — the
    // pumps kept writing (acks, pings) while the context was blind to
    // the transport. Zero for purely in-proc runs (mpsc is not a wire).
    let wire = net.totals();
    fin.report.frames_sent = wire.frames_sent;
    fin.report.frames_batched = wire.frames_batched;
    fin.report.wire_bytes = wire.wire_bytes;
    fin.report.blocks_zero_copy = wire.blocks_zero_copy;
    Ok(ExecResult {
        output: fin.output,
        report: fin.report,
        sched: fin.sched,
        overhead: fin.overhead,
        rf_trajectory: fin.rf_trajectory,
        re_dispatched: fin.re_dispatched,
        dfs_bytes_served: dfs.bytes_served(),
        cache: dfs.cache_stats(),
        workers: worker_stats
            .into_iter()
            .enumerate()
            .map(|(w, s)| {
                s.unwrap_or(WorkerStats {
                    worker: w,
                    executed: 0,
                    clean_shutdown: false,
                })
            })
            .collect(),
    })
}

/// Run with job-level recovery: on any worker failure the *entire job*
/// restarts (same seed ⇒ identical final statistic), up to
/// `max_attempts`. With remote workers, the listener in
/// [`ExecConfig::remote`] is reused across attempts, so replacement
/// workers connect to the same address.
pub fn run_cluster_with_recovery(
    dataset: &dyn Dataset,
    backend: Arc<Backend>,
    cfg: &ExecConfig,
    max_attempts: u32,
) -> Result<ExecResult> {
    let (mut r, restarts) = retry(max_attempts, |attempt| {
        let mut attempt_cfg = cfg.clone();
        attempt_cfg.attempt = attempt;
        run_cluster(dataset, backend.clone(), &attempt_cfg)
    })?;
    r.report.restarts = restarts;
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::Prefetcher;
    use crate::transport::run_task;

    #[test]
    fn default_config_is_sane() {
        let c = ExecConfig::default();
        assert!(c.workers > 0);
        assert!(c.remote.is_none());
        assert_eq!(c.slots(), c.workers);
        assert!(c.data_nodes > 0);
        assert!(c.inflight >= 1);
        assert_eq!(c.attempt, 1);
        assert!(c.failure.is_none());
    }

    #[test]
    fn zero_workers_is_a_config_error() {
        let backend = Arc::new(Backend::native(ModelParams::default()));
        let ds = crate::workloads::build_small(
            Workload::Eaglet,
            &ModelParams::default(),
            4,
        );
        let cfg = ExecConfig { workers: 0, ..Default::default() };
        assert!(run_cluster(ds.as_ref(), backend, &cfg).is_err());
    }

    #[test]
    fn overhead_math() {
        let o = SchedOverhead {
            dispatch_s: 0.002,
            dispatch_calls: 1000,
            queue_wait: summarize(&[0.0]),
        };
        assert!((o.dispatch_us_per_call() - 2.0).abs() < 1e-9);
        let zero = SchedOverhead {
            dispatch_s: 0.0,
            dispatch_calls: 0,
            queue_wait: summarize(&[0.0]),
        };
        assert_eq!(zero.dispatch_us_per_call(), 0.0);
    }

    #[test]
    fn block_keys_are_namespace_disjoint() {
        let a = block_key("", Workload::Eaglet, 7);
        let b = block_key(&crate::dfs::job_ns(1), Workload::Eaglet, 7);
        let c = block_key(&crate::dfs::job_ns(2), Workload::Eaglet, 7);
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert!(b.ends_with(&a), "namespacing must only prefix: {b} vs {a}");
    }

    #[test]
    fn job_ctx_collects_and_finishes() {
        // Drive a JobCtx by hand — the same motions the serve
        // dispatcher makes — and check the reduce gate.
        let backend = Backend::native(ModelParams::default());
        let params = ModelParams::default();
        let ds = crate::workloads::build_small(Workload::Eaglet, &params, 6);
        let dfs = Dfs::new(2, 1, LatencyModel::none());
        let (samples, bytes, keys) = stage_dataset(ds.as_ref(), &dfs, "t/");
        assert_eq!(samples, 6);
        assert!(keys.iter().all(|k| k.starts_with("t/")));
        let specs: Vec<TaskSpec> =
            crate::kneepoint::pack(ds.metas(), TaskSizing::Tiniest)
                .into_iter()
                .map(|t| TaskSpec::new(t, Workload::Eaglet, 1))
                .collect();
        let mut ctx = JobCtx::new(
            specs,
            dfs.clone(),
            ExecConfig { adaptive_rf: false, ..Default::default() },
            1,
            samples,
            bytes,
            0.0,
            None,
            None,
            "t/".into(),
        )
        .unwrap();
        let mut pf = Prefetcher::new(dfs, 4);
        while let Some(spec) = ctx.next(0) {
            let (partial, fetch_s, exec_s) =
                run_task(&params, &backend, &mut pf, &spec, "t/").unwrap();
            assert!(!ctx.is_complete());
            ctx.on_done(TaskDone {
                worker: 0,
                seq: spec.task.seq,
                partial,
                fetch_s,
                exec_s,
                queue_wait_s: 0.0,
                prefetch_hits: 0,
                prefetch_misses: 0,
                cache_hits: 0,
                cache_misses: 0,
            });
        }
        assert!(ctx.is_complete());
        let fin = ctx.finish(&backend).unwrap();
        assert_eq!(fin.report.tasks, 6);
        assert!(matches!(fin.output, JobOutput::Eaglet { .. }));
    }

    #[test]
    fn job_ctx_two_phase_reduce_matches_leader_side_path() {
        // Drive the same job through the historical r=1 leader-side
        // reduce and the executed r=3 shuffle + reduce; the outputs
        // must be bit-identical (the JobCtx half of the determinism
        // contract — transports add nothing on top of this).
        let backend = Backend::native(ModelParams::default());
        let params = ModelParams::default();
        let run = |reduce_tasks: usize| -> JobOutput {
            let ds =
                crate::workloads::build_small(Workload::NetflixLo, &params, 8);
            let dfs = Dfs::new(2, 1, LatencyModel::none());
            let (samples, bytes, _) = stage_dataset(ds.as_ref(), &dfs, "");
            let specs: Vec<TaskSpec> =
                crate::kneepoint::pack(ds.metas(), TaskSizing::Tiniest)
                    .into_iter()
                    .map(|t| TaskSpec::new(t, Workload::NetflixLo, 5))
                    .collect();
            let mut ctx = JobCtx::new(
                specs,
                dfs.clone(),
                ExecConfig {
                    adaptive_rf: false,
                    reduce_tasks,
                    partitioner: Partitioner::Skew,
                    ..Default::default()
                },
                1,
                samples,
                bytes,
                0.0,
                None,
                None,
                "".into(),
            )
            .unwrap();
            let mut pf = Prefetcher::new(dfs, 4);
            while let Some(spec) = ctx.next(0) {
                let (partial, fetch_s, exec_s) =
                    run_task(&params, &backend, &mut pf, &spec, "").unwrap();
                ctx.on_done(TaskDone {
                    worker: 0,
                    seq: spec.task.seq,
                    partial,
                    fetch_s,
                    exec_s,
                    queue_wait_s: 0.0,
                    prefetch_hits: 0,
                    prefetch_misses: 0,
                    cache_hits: 0,
                    cache_misses: 0,
                });
            }
            let started = ctx.maybe_start_shuffle(&params).unwrap();
            assert_eq!(started, reduce_tasks > 1);
            while let Some(rspec) = ctx.next_reduce(0) {
                assert!(!ctx.is_complete());
                let (partial, fetch_s, exec_s, shuffle_bytes) =
                    crate::transport::run_reduce_task(
                        &params, &backend, &mut pf, &rspec, "",
                    )
                    .unwrap();
                ctx.on_reduce_done(ReduceDone {
                    worker: 0,
                    partition: rspec.partition,
                    partial,
                    fetch_s,
                    exec_s,
                    queue_wait_s: 0.0,
                    shuffle_bytes,
                });
            }
            assert!(ctx.is_complete());
            let fin = ctx.finish(&backend).unwrap();
            assert_eq!(fin.report.reduce_tasks, reduce_tasks.max(1));
            if reduce_tasks > 1 {
                assert!(fin.report.shuffle_bytes > 0);
                assert!(fin.report.shuffle_imbalance >= 1.0);
            } else {
                assert_eq!(fin.report.shuffle_bytes, 0);
            }
            fin.output
        };
        let solo = run(1);
        let sharded = run(3);
        assert_eq!(solo, sharded, "r=3 must equal r=1 bit for bit");
    }

    #[test]
    fn unfinished_job_refuses_to_reduce() {
        let params = ModelParams::default();
        let ds = crate::workloads::build_small(Workload::Eaglet, &params, 3);
        let dfs = Dfs::new(1, 1, LatencyModel::none());
        let (samples, bytes, _) = stage_dataset(ds.as_ref(), &dfs, "");
        let specs: Vec<TaskSpec> =
            crate::kneepoint::pack(ds.metas(), TaskSizing::Tiniest)
                .into_iter()
                .map(|t| TaskSpec::new(t, Workload::Eaglet, 1))
                .collect();
        let ctx = JobCtx::new(
            specs,
            dfs,
            ExecConfig::default(),
            1,
            samples,
            bytes,
            0.0,
            None,
            None,
            "".into(),
        )
        .unwrap();
        let backend = Backend::native(params);
        assert!(ctx.finish(&backend).is_err());
    }

    // End-to-end cluster runs (both workloads, oracle agreement,
    // shutdown ordering, recovery) live in
    // rust/tests/integration_exec.rs, and the in-proc ≡ TCP
    // equivalence contract in rust/tests/integration_transport.rs —
    // they need no artifacts.
}
