//! PJRT execution: load HLO-text artifacts, compile once, run many.
//!
//! Follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! (jax ≥0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects).
//!
//! `Runtime` is intentionally **not Send** (the xla crate wraps the
//! client in an `Rc`): each worker thread constructs its own via
//! `Runtime::new`, compiles lazily, and caches executables for the
//! duration of the process — compilation never sits on the per-task
//! path after first touch.
//!
//! Offline builds link the vendored `xla` stub (vendor/xla), where
//! `PjRtClient::cpu()` fails with a clear message; jobs then run
//! through the native kernel backend instead (`exec::NativeExec`, see
//! DESIGN.md §4).

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

use super::manifest::{Dtype, Entry, Manifest};
use crate::error::{Error, Result};

/// A host-side tensor handed to/returned from an artifact.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32(..) => Dtype::F32,
            HostTensor::I32(..) => Dtype::I32,
        }
    }

    pub fn elements(&self) -> usize {
        match self {
            HostTensor::F32(v, _) => v.len(),
            HostTensor::I32(v, _) => v.len(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Ok(v),
            _ => Err(Error::Artifact("expected f32 tensor".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v, _) => Ok(v),
            _ => Err(Error::Artifact("expected i32 tensor".into())),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> =
            self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v, _) => xla::Literal::vec1(v),
            HostTensor::I32(v, _) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }
}

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Arc<Manifest>,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    pub executions: std::cell::Cell<u64>,
    pub compile_s: std::cell::Cell<f64>,
}

impl Runtime {
    pub fn new(manifest: Arc<Manifest>) -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            manifest,
            cache: RefCell::new(HashMap::new()),
            executions: std::cell::Cell::new(0),
            compile_s: std::cell::Cell::new(0.0),
        })
    }

    /// Pre-compile a set of entries (pull compile time off the first
    /// task's critical path).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            let e = self
                .manifest
                .entry_named(n)
                .ok_or_else(|| Error::Artifact(format!("no entry {n}")))?
                .clone();
            self.ensure_compiled(&e)?;
        }
        Ok(())
    }

    fn ensure_compiled(&self, entry: &Entry) -> Result<()> {
        if self.cache.borrow().contains_key(&entry.name) {
            return Ok(());
        }
        let t = std::time::Instant::now();
        let path = self.manifest.hlo_path(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| {
                Error::Artifact("non-utf8 artifact path".into())
            })?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compile_s
            .set(self.compile_s.get() + t.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(entry.name.clone(), exe);
        Ok(())
    }

    /// Validate inputs against the entry spec (shape + dtype + element
    /// count) — catches marshaling bugs at the boundary instead of
    /// inside XLA. Shared with the native backend (`exec::native`), so
    /// both execution paths reject malformed tensors identically.
    pub(crate) fn check_inputs(
        entry: &Entry,
        inputs: &[HostTensor],
    ) -> Result<()> {
        if inputs.len() != entry.inputs.len() {
            return Err(Error::Artifact(format!(
                "{}: got {} inputs, want {}",
                entry.name,
                inputs.len(),
                entry.inputs.len()
            )));
        }
        for (i, (t, spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
            if t.shape() != spec.shape.as_slice() || t.dtype() != spec.dtype
            {
                return Err(Error::Artifact(format!(
                    "{} input #{i} ({}): got {:?} {:?}, want {:?} {:?}",
                    entry.name,
                    spec.name,
                    t.dtype(),
                    t.shape(),
                    spec.dtype,
                    spec.shape,
                )));
            }
            if t.elements() != spec.elements() {
                return Err(Error::Artifact(format!(
                    "{} input #{i}: element count mismatch",
                    entry.name
                )));
            }
        }
        Ok(())
    }

    /// Execute an entry; returns the output tensors as flat f32 vectors
    /// (all our artifact outputs are f32).
    pub fn execute(
        &self,
        entry: &Entry,
        inputs: &[HostTensor],
    ) -> Result<Vec<Vec<f32>>> {
        Self::check_inputs(entry, inputs)?;
        self.ensure_compiled(entry)?;
        let cache = self.cache.borrow();
        let exe = cache.get(&entry.name).expect("just compiled");
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()?;
        self.executions.set(self.executions.get() + 1);
        // aot.py lowers with return_tuple=True: output is an n-tuple.
        let parts = result.to_tuple()?;
        if parts.len() != entry.outputs.len() {
            return Err(Error::Artifact(format!(
                "{}: got {} outputs, want {}",
                entry.name,
                parts.len(),
                entry.outputs.len()
            )));
        }
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(Into::into))
            .collect()
    }

    /// Convenience: execute the map entry for `kind` at the bucket
    /// fitting `units` samples.
    pub fn execute_map(
        &self,
        kind: &str,
        units: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<Vec<f32>>> {
        let entry = self.manifest.map_entry(kind, units)?.clone();
        self.execute(&entry, inputs)
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Execution round-trip tests live in rust/tests/integration_runtime.rs
    // (they need built artifacts); here we cover the host-tensor plumbing.

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.elements(), 4);
        assert_eq!(t.dtype(), Dtype::F32);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        let i = HostTensor::I32(vec![1, 2], vec![2]);
        assert_eq!(i.dtype(), Dtype::I32);
        assert!(i.as_f32().is_err());
        assert_eq!(i.as_i32().unwrap(), &[1, 2]);
    }

    #[test]
    fn input_check_catches_shape_mismatch() {
        let entry = Entry {
            name: "t".into(),
            kind: "t".into(),
            bucket: 1,
            file: "t.hlo.txt".into(),
            inputs: vec![super::super::manifest::TensorSpec {
                name: "x".into(),
                shape: vec![2, 2],
                dtype: Dtype::F32,
            }],
            outputs: vec![],
        };
        let bad_shape = HostTensor::F32(vec![0.0; 6], vec![2, 3]);
        assert!(Runtime::check_inputs(&entry, &[bad_shape]).is_err());
        let bad_dtype = HostTensor::I32(vec![0; 4], vec![2, 2]);
        assert!(Runtime::check_inputs(&entry, &[bad_dtype]).is_err());
        let bad_arity = HostTensor::F32(vec![0.0; 4], vec![2, 2]);
        assert!(
            Runtime::check_inputs(&entry, &[bad_arity.clone(), bad_arity])
                .is_err()
        );
        let good = HostTensor::F32(vec![0.0; 4], vec![2, 2]);
        assert!(Runtime::check_inputs(&entry, &[good]).is_ok());
    }
}
