//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime.
//!
//! `artifacts/manifest.json` lists every compiled HLO entry point with
//! its input/output tensor specs and the model parameters
//! (`data::ModelParams`); `Manifest::load` parses and validates it so a
//! drift between shapes.py and the rust defaults fails loudly at startup
//! rather than corrupting results.

use std::path::{Path, PathBuf};

use crate::data::ModelParams;
use crate::error::{Error, Result};
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => Err(Error::Artifact(format!("unsupported dtype {other}"))),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    /// Entry family: eaglet_map, netflix_map_hi, netflix_map_lo,
    /// seqaddr_map, ssag_map, eaglet_reduce, netflix_reduce,
    /// seqaddr_reduce, ssag_reduce.
    pub kind: String,
    /// Samples-per-task bucket (map) or fan-in K (reduce).
    pub bucket: usize,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub params: ModelParams,
    pub entries: Vec<Entry>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .ok_or_else(|| Error::Artifact("specs not an array".into()))?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t.req_str("name")?.to_string(),
                shape: t
                    .req_arr("shape")?
                    .iter()
                    .map(|v| v.as_usize().unwrap_or(0))
                    .collect(),
                dtype: Dtype::parse(t.req_str("dtype")?)?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| {
                Error::Artifact(format!(
                    "cannot read {}/manifest.json (run `make artifacts`): {e}",
                    dir.display()
                ))
            })?;
        let j = Json::parse(&text)?;
        let params = ModelParams::from_json(j.req("params")?)?;
        let entries = j
            .req_arr("entries")?
            .iter()
            .map(|e| {
                Ok(Entry {
                    name: e.req_str("name")?.to_string(),
                    kind: e.req_str("kind")?.to_string(),
                    bucket: e.req_usize("bucket")?,
                    file: e.req_str("file")?.to_string(),
                    inputs: tensor_specs(e.req("inputs")?)?,
                    outputs: tensor_specs(e.req("outputs")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let m = Manifest { dir, params, entries };
        m.validate()?;
        Ok(m)
    }

    /// Default artifact location: $BTS_ARTIFACTS or ./artifacts.
    pub fn load_default() -> Result<Manifest> {
        let dir = std::env::var("BTS_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    /// Build the manifest aot.py would emit for `params`, without
    /// touching the filesystem. Entry names, kinds, buckets and tensor
    /// specs mirror `python/compile/aot.py::entry_points` exactly; this
    /// is the contract backends that compute the kernels natively
    /// (`exec::NativeExec`) serve lookups from. The `file` fields name
    /// artifacts that do not exist, so `validate()` is intentionally
    /// not called (and would fail) — only `load` validates.
    pub fn synthetic(params: ModelParams) -> Manifest {
        use super::manifest::Dtype::{F32, I32};
        let spec = |name: &str, shape: Vec<usize>, dtype| TensorSpec {
            name: name.to_string(),
            shape,
            dtype,
        };
        let mut entries = Vec::new();
        for &b in &params.buckets {
            entries.push(Entry {
                name: format!("eaglet_map_b{b}"),
                kind: "eaglet_map".to_string(),
                bucket: b,
                file: format!("eaglet_map_b{b}.hlo.txt"),
                inputs: vec![
                    spec("geno", vec![b, params.markers, params.individuals], F32),
                    spec("pos", vec![b, params.markers], F32),
                    spec("idx", vec![params.rounds, params.subsample], I32),
                    spec("grid", vec![params.grid], F32),
                ],
                outputs: vec![spec("alod", vec![b, params.grid], F32)],
            });
            for (conf, s) in [("hi", params.s_hi), ("lo", params.s_lo)] {
                entries.push(Entry {
                    name: format!("netflix_map_{conf}_b{b}"),
                    kind: format!("netflix_map_{conf}"),
                    bucket: b,
                    file: format!("netflix_map_{conf}_b{b}.hlo.txt"),
                    inputs: vec![
                        spec("vals", vec![b, params.ratings_cap], F32),
                        spec("months", vec![b, params.ratings_cap], F32),
                        spec("mask", vec![b, params.ratings_cap], F32),
                        spec("idx", vec![s], I32),
                    ],
                    outputs: vec![spec(
                        "stats",
                        vec![b, params.months, params.stat_fields],
                        F32,
                    )],
                });
            }
            entries.push(Entry {
                name: format!("seqaddr_map_b{b}"),
                kind: "seqaddr_map".to_string(),
                bucket: b,
                file: format!("seqaddr_map_b{b}.hlo.txt"),
                inputs: vec![
                    spec("series", vec![b, params.sa_len], F32),
                    spec("idx", vec![params.sa_rounds], I32),
                ],
                outputs: vec![spec(
                    "stats",
                    vec![b, params.sa_bins, params.stat_fields],
                    F32,
                )],
            });
            entries.push(Entry {
                name: format!("ssag_map_b{b}"),
                kind: "ssag_map".to_string(),
                bucket: b,
                file: format!("ssag_map_b{b}.hlo.txt"),
                inputs: vec![spec("series", vec![b, params.ssag_len], F32)],
                outputs: vec![spec(
                    "var",
                    vec![b, params.ssag_points],
                    F32,
                )],
            });
        }
        entries.push(Entry {
            name: "eaglet_reduce".to_string(),
            kind: "eaglet_reduce".to_string(),
            bucket: params.reduce_fan,
            file: "eaglet_reduce.hlo.txt".to_string(),
            inputs: vec![
                spec("parts", vec![params.reduce_fan, params.grid], F32),
                spec("weights", vec![params.reduce_fan], F32),
            ],
            outputs: vec![
                spec("wsum", vec![params.grid], F32),
                spec("wtot", vec![1], F32),
            ],
        });
        entries.push(Entry {
            name: "netflix_reduce".to_string(),
            kind: "netflix_reduce".to_string(),
            bucket: params.reduce_fan,
            file: "netflix_reduce.hlo.txt".to_string(),
            inputs: vec![spec(
                "parts",
                vec![params.reduce_fan, params.months, params.stat_fields],
                F32,
            )],
            outputs: vec![spec(
                "stats",
                vec![params.months, params.stat_fields],
                F32,
            )],
        });
        entries.push(Entry {
            name: "ssag_reduce".to_string(),
            kind: "ssag_reduce".to_string(),
            bucket: params.reduce_fan,
            file: "ssag_reduce.hlo.txt".to_string(),
            inputs: vec![
                spec(
                    "parts",
                    vec![params.reduce_fan, params.ssag_points],
                    F32,
                ),
                spec("weights", vec![params.reduce_fan], F32),
            ],
            outputs: vec![
                spec("wsum", vec![params.ssag_points], F32),
                spec("wtot", vec![1], F32),
            ],
        });
        entries.push(Entry {
            name: "seqaddr_reduce".to_string(),
            kind: "seqaddr_reduce".to_string(),
            bucket: params.reduce_fan,
            file: "seqaddr_reduce.hlo.txt".to_string(),
            inputs: vec![spec(
                "parts",
                vec![params.reduce_fan, params.sa_bins, params.stat_fields],
                F32,
            )],
            outputs: vec![spec(
                "stats",
                vec![params.sa_bins, params.stat_fields],
                F32,
            )],
        });
        Manifest { dir: PathBuf::from("<native>"), params, entries }
    }

    pub fn validate(&self) -> Result<()> {
        if self.entries.is_empty() {
            return Err(Error::Artifact("manifest has no entries".into()));
        }
        for e in &self.entries {
            if !self.dir.join(&e.file).exists() {
                return Err(Error::Artifact(format!(
                    "artifact file missing: {}",
                    e.file
                )));
            }
            if e.inputs.is_empty() || e.outputs.is_empty() {
                return Err(Error::Artifact(format!(
                    "entry {} missing tensor specs",
                    e.name
                )));
            }
        }
        // every bucket advertised by params must have all map kinds
        for &b in &self.params.buckets {
            for kind in ["eaglet_map", "netflix_map_hi", "netflix_map_lo"] {
                if self.entry(kind, b).is_none() {
                    return Err(Error::Artifact(format!(
                        "missing {kind} bucket {b}"
                    )));
                }
            }
        }
        for kind in ["eaglet_reduce", "netflix_reduce"] {
            if !self.entries.iter().any(|e| e.kind == kind) {
                return Err(Error::Artifact(format!("missing {kind}")));
            }
        }
        Ok(())
    }

    pub fn entry(&self, kind: &str, bucket: usize) -> Option<&Entry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.bucket == bucket)
    }

    pub fn entry_named(&self, name: &str) -> Option<&Entry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Map-entry lookup for a task of `units` samples: smallest compiled
    /// bucket that fits.
    pub fn map_entry(&self, kind: &str, units: usize) -> Result<&Entry> {
        let bucket = self.params.bucket_for(units).ok_or_else(|| {
            Error::Artifact(format!(
                "task of {units} units exceeds max bucket {}",
                self.params.max_bucket()
            ))
        })?;
        self.entry(kind, bucket).ok_or_else(|| {
            Error::Artifact(format!("no entry {kind} bucket {bucket}"))
        })
    }

    pub fn hlo_path(&self, e: &Entry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert_eq!(m.params, ModelParams::default(), "shapes.py drifted");
        assert_eq!(m.entries.len(), 3 * m.params.buckets.len() + 2);
        let e = m.map_entry("eaglet_map", 3).unwrap();
        assert_eq!(e.bucket, 4);
        assert_eq!(e.inputs[0].shape, vec![4, 64, 8]);
        assert_eq!(e.outputs[0].shape, vec![4, 32]);
    }

    #[test]
    fn map_entry_rejects_oversize() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert!(m.map_entry("eaglet_map", 65).is_err());
    }

    #[test]
    fn missing_dir_is_a_clear_error() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn synthetic_mirrors_aot_entry_points() {
        let p = ModelParams::default();
        let m = Manifest::synthetic(p.clone());
        // 5 map kinds × buckets + 4 reduce kinds.
        assert_eq!(m.entries.len(), 5 * p.buckets.len() + 4);
        let e = m.map_entry("eaglet_map", 3).unwrap();
        assert_eq!(e.bucket, 4);
        assert_eq!(e.name, "eaglet_map_b4");
        assert_eq!(e.inputs[0].shape, vec![4, p.markers, p.individuals]);
        assert_eq!(e.outputs[0].shape, vec![4, p.grid]);
        for kind in ["netflix_map_hi", "netflix_map_lo"] {
            for &b in &p.buckets {
                assert!(m.entry(kind, b).is_some(), "missing {kind} b{b}");
            }
        }
        for kind in ["seqaddr_map", "ssag_map"] {
            for &b in &p.buckets {
                assert!(m.entry(kind, b).is_some(), "missing {kind} b{b}");
            }
        }
        let sa = m.entry("seqaddr_map", 1).unwrap();
        assert_eq!(sa.inputs[0].shape, vec![1, p.sa_len]);
        assert_eq!(sa.inputs[1].shape, vec![p.sa_rounds]);
        assert_eq!(
            sa.outputs[0].shape,
            vec![1, p.sa_bins, p.stat_fields]
        );
        let sg = m.entry("ssag_map", 1).unwrap();
        assert_eq!(sg.inputs.len(), 1);
        assert_eq!(sg.outputs[0].shape, vec![1, p.ssag_points]);
        let r = m.entry("eaglet_reduce", p.reduce_fan).unwrap();
        assert_eq!(r.outputs.len(), 2);
        assert!(m.entry("netflix_reduce", p.reduce_fan).is_some());
        assert!(m.entry("ssag_reduce", p.reduce_fan).is_some());
        assert!(m.entry("seqaddr_reduce", p.reduce_fan).is_some());
        // hi entries subsample more than lo
        let hi = m.entry("netflix_map_hi", 1).unwrap();
        let lo = m.entry("netflix_map_lo", 1).unwrap();
        assert_eq!(hi.inputs[3].shape, vec![p.s_hi]);
        assert_eq!(lo.inputs[3].shape, vec![p.s_lo]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("float32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("int32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("float64").is_err());
    }
}
