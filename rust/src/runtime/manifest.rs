//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime.
//!
//! `artifacts/manifest.json` lists every compiled HLO entry point with
//! its input/output tensor specs and the model parameters
//! (`data::ModelParams`); `Manifest::load` parses and validates it so a
//! drift between shapes.py and the rust defaults fails loudly at startup
//! rather than corrupting results.

use std::path::{Path, PathBuf};

use crate::data::ModelParams;
use crate::error::{Error, Result};
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => Err(Error::Artifact(format!("unsupported dtype {other}"))),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    /// Entry family: eaglet_map, netflix_map_hi, netflix_map_lo,
    /// eaglet_reduce, netflix_reduce.
    pub kind: String,
    /// Samples-per-task bucket (map) or fan-in K (reduce).
    pub bucket: usize,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub params: ModelParams,
    pub entries: Vec<Entry>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .ok_or_else(|| Error::Artifact("specs not an array".into()))?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t.req_str("name")?.to_string(),
                shape: t
                    .req_arr("shape")?
                    .iter()
                    .map(|v| v.as_usize().unwrap_or(0))
                    .collect(),
                dtype: Dtype::parse(t.req_str("dtype")?)?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| {
                Error::Artifact(format!(
                    "cannot read {}/manifest.json (run `make artifacts`): {e}",
                    dir.display()
                ))
            })?;
        let j = Json::parse(&text)?;
        let params = ModelParams::from_json(j.req("params")?)?;
        let entries = j
            .req_arr("entries")?
            .iter()
            .map(|e| {
                Ok(Entry {
                    name: e.req_str("name")?.to_string(),
                    kind: e.req_str("kind")?.to_string(),
                    bucket: e.req_usize("bucket")?,
                    file: e.req_str("file")?.to_string(),
                    inputs: tensor_specs(e.req("inputs")?)?,
                    outputs: tensor_specs(e.req("outputs")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let m = Manifest { dir, params, entries };
        m.validate()?;
        Ok(m)
    }

    /// Default artifact location: $BTS_ARTIFACTS or ./artifacts.
    pub fn load_default() -> Result<Manifest> {
        let dir = std::env::var("BTS_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    pub fn validate(&self) -> Result<()> {
        if self.entries.is_empty() {
            return Err(Error::Artifact("manifest has no entries".into()));
        }
        for e in &self.entries {
            if !self.dir.join(&e.file).exists() {
                return Err(Error::Artifact(format!(
                    "artifact file missing: {}",
                    e.file
                )));
            }
            if e.inputs.is_empty() || e.outputs.is_empty() {
                return Err(Error::Artifact(format!(
                    "entry {} missing tensor specs",
                    e.name
                )));
            }
        }
        // every bucket advertised by params must have all map kinds
        for &b in &self.params.buckets {
            for kind in ["eaglet_map", "netflix_map_hi", "netflix_map_lo"] {
                if self.entry(kind, b).is_none() {
                    return Err(Error::Artifact(format!(
                        "missing {kind} bucket {b}"
                    )));
                }
            }
        }
        for kind in ["eaglet_reduce", "netflix_reduce"] {
            if !self.entries.iter().any(|e| e.kind == kind) {
                return Err(Error::Artifact(format!("missing {kind}")));
            }
        }
        Ok(())
    }

    pub fn entry(&self, kind: &str, bucket: usize) -> Option<&Entry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.bucket == bucket)
    }

    pub fn entry_named(&self, name: &str) -> Option<&Entry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Map-entry lookup for a task of `units` samples: smallest compiled
    /// bucket that fits.
    pub fn map_entry(&self, kind: &str, units: usize) -> Result<&Entry> {
        let bucket = self.params.bucket_for(units).ok_or_else(|| {
            Error::Artifact(format!(
                "task of {units} units exceeds max bucket {}",
                self.params.max_bucket()
            ))
        })?;
        self.entry(kind, bucket).ok_or_else(|| {
            Error::Artifact(format!("no entry {kind} bucket {bucket}"))
        })
    }

    pub fn hlo_path(&self, e: &Entry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert_eq!(m.params, ModelParams::default(), "shapes.py drifted");
        assert_eq!(m.entries.len(), 3 * m.params.buckets.len() + 2);
        let e = m.map_entry("eaglet_map", 3).unwrap();
        assert_eq!(e.bucket, 4);
        assert_eq!(e.inputs[0].shape, vec![4, 64, 8]);
        assert_eq!(e.outputs[0].shape, vec![4, 32]);
    }

    #[test]
    fn map_entry_rejects_oversize() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert!(m.map_entry("eaglet_map", 65).is_err());
    }

    #[test]
    fn missing_dir_is_a_clear_error() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("float32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("int32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("float64").is_err());
    }
}
