//! Process-wide executor pool: persistent PJRT runtimes behind channels.
//!
//! §Perf (EXPERIMENTS.md): profiling showed a 400-task tiny-task job
//! spending ~2 s of its 2.1 s wall in *per-worker* `PjRtClient::cpu()`
//! creation and executable compilation — the map work itself was ~85 ms.
//! The xla crate's client is `Rc`-based (not `Send`), so runtimes cannot
//! be shared across worker threads directly; instead a fixed pool of
//! executor threads each owns one `Runtime` for the life of the process
//! and serves execute requests over channels. Compilation happens at
//! most once per (executor, entry) — first job in a process pays it,
//! every later job (and every later task) runs hot. Workers stay
//! lightweight: fetch, assemble, submit, report.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};

use super::client::{HostTensor, Runtime};
use super::manifest::{Entry, Manifest};
use crate::error::{Error, Result};

struct Request {
    entry_name: String,
    inputs: Vec<HostTensor>,
    resp: mpsc::Sender<Result<Vec<Vec<f32>>>>,
}

/// Fixed-size pool of executor threads, each owning a persistent
/// `Runtime` (PJRT client + compiled-executable cache).
pub struct ExecutorPool {
    manifest: Arc<Manifest>,
    senders: Vec<Mutex<mpsc::Sender<Request>>>,
    rr: AtomicUsize,
}

impl ExecutorPool {
    /// Build a pool of `n` executors. Prefer [`ExecutorPool::global`].
    pub fn new(manifest: Arc<Manifest>, n: usize) -> Arc<ExecutorPool> {
        let n = n.max(1);
        let mut senders = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = mpsc::channel::<Request>();
            let m = manifest.clone();
            std::thread::Builder::new()
                .name(format!("bts-exec-{i}"))
                .spawn(move || executor_loop(m, rx))
                .expect("spawn executor");
            senders.push(Mutex::new(tx));
        }
        Arc::new(ExecutorPool {
            manifest,
            senders,
            rr: AtomicUsize::new(0),
        })
    }

    /// The process-wide pool, created on first use against the default
    /// manifest location. Sized to the host's parallelism (capped — each
    /// executor holds a full PJRT client).
    pub fn global(manifest: &Arc<Manifest>) -> Result<Arc<ExecutorPool>> {
        static POOL: OnceLock<Arc<ExecutorPool>> = OnceLock::new();
        let pool = POOL.get_or_init(|| {
            let n = std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
                .min(8);
            ExecutorPool::new(manifest.clone(), n)
        });
        // A process talks to one artifact set; catch accidental mixes.
        if pool.manifest.dir != manifest.dir {
            return Err(Error::Artifact(format!(
                "executor pool bound to {}, asked for {}",
                pool.manifest.dir.display(),
                manifest.dir.display()
            )));
        }
        Ok(pool.clone())
    }

    pub fn size(&self) -> usize {
        self.senders.len()
    }

    pub fn manifest_ref(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute `entry` on the least-recently-used executor (round
    /// robin). Blocks until the result is back.
    pub fn execute(
        &self,
        entry: &Entry,
        inputs: Vec<HostTensor>,
    ) -> Result<Vec<Vec<f32>>> {
        let i = self.rr.fetch_add(1, Ordering::Relaxed) % self.senders.len();
        let (resp_tx, resp_rx) = mpsc::channel();
        let req = Request {
            entry_name: entry.name.clone(),
            inputs,
            resp: resp_tx,
        };
        self.senders[i]
            .lock()
            .unwrap()
            .send(req)
            .map_err(|_| Error::Xla("executor thread gone".into()))?;
        resp_rx
            .recv()
            .map_err(|_| Error::Xla("executor dropped request".into()))?
    }

    /// Pre-compile entries on every executor (pull compile cost off the
    /// first tasks). Best-effort; errors surface on first real use.
    pub fn warm(&self, names: &[&str]) {
        for name in names {
            let Some(entry) = self.manifest.entry_named(name) else {
                continue;
            };
            let probe: Vec<HostTensor> = entry
                .inputs
                .iter()
                .map(|spec| match spec.dtype {
                    super::manifest::Dtype::F32 => HostTensor::F32(
                        vec![0.0; spec.elements()],
                        spec.shape.clone(),
                    ),
                    super::manifest::Dtype::I32 => HostTensor::I32(
                        vec![0; spec.elements()],
                        spec.shape.clone(),
                    ),
                })
                .collect();
            for _ in 0..self.senders.len() {
                let _ = self.execute(entry, probe.clone());
            }
        }
    }
}

fn executor_loop(manifest: Arc<Manifest>, rx: mpsc::Receiver<Request>) {
    let rt = match Runtime::new(manifest.clone()) {
        Ok(rt) => rt,
        Err(e) => {
            // Fail every request with the construction error.
            while let Ok(req) = rx.recv() {
                let _ = req
                    .resp
                    .send(Err(Error::Xla(format!("runtime init failed: {e}"))));
            }
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        let result = match manifest.entry_named(&req.entry_name) {
            Some(entry) => rt.execute(entry, &req.inputs),
            None => Err(Error::Artifact(format!(
                "unknown entry {}",
                req.entry_name
            ))),
        };
        // Receiver may have given up (job aborted) — fine.
        let _ = req.resp.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Arc<Manifest>> {
        Manifest::load("artifacts").ok().map(Arc::new)
    }

    #[test]
    fn pool_executes_and_round_robins() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let pool = ExecutorPool::new(m.clone(), 2);
        let e = m.entry("netflix_reduce", m.params.reduce_fan).unwrap();
        let parts = HostTensor::F32(
            vec![1.0; m.params.reduce_fan * m.params.months * m.params.stat_fields],
            vec![m.params.reduce_fan, m.params.months, m.params.stat_fields],
        );
        for _ in 0..4 {
            let out = pool.execute(e, vec![parts.clone()]).unwrap();
            assert_eq!(
                out[0].len(),
                m.params.months * m.params.stat_fields
            );
            assert!(out[0].iter().all(|&v| v == m.params.reduce_fan as f32));
        }
    }

    #[test]
    fn pool_reports_unknown_entry() {
        let Some(m) = manifest() else { return };
        let pool = ExecutorPool::new(m.clone(), 1);
        let mut bogus = m.entries[0].clone();
        bogus.name = "nope".into();
        assert!(pool.execute(&bogus, vec![]).is_err());
    }

    #[test]
    fn global_pool_is_shared_and_guards_manifest_dir() {
        let Some(m) = manifest() else { return };
        let a = ExecutorPool::global(&m).unwrap();
        let b = ExecutorPool::global(&m).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let mut other = (*m).clone();
        other.dir = "/tmp/elsewhere".into();
        assert!(ExecutorPool::global(&Arc::new(other)).is_err());
    }
}
