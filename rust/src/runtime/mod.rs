//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (python/compile/aot.py → HLO text + manifest.json) and executes them
//! on the request path — Python never runs here.
//!
//! See /opt/xla-example/load_hlo and DESIGN.md §3 for the interchange
//! contract (HLO *text*, not serialized StableHLO).

pub mod client;
pub mod manifest;
pub mod pool;

pub use client::{HostTensor, Runtime};
pub use pool::ExecutorPool;
pub use manifest::{Dtype, Entry, Manifest, TensorSpec};

use crate::error::Result;

/// Anything that can execute a compiled artifact — the per-thread
/// [`Runtime`] or the process-wide [`ExecutorPool`]. Reduce trees and
/// calibration are generic over this.
pub trait Exec {
    fn manifest(&self) -> &Manifest;
    fn run(&self, entry: &Entry, inputs: Vec<HostTensor>) -> Result<Vec<Vec<f32>>>;
}

impl Exec for Runtime {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&self, entry: &Entry, inputs: Vec<HostTensor>) -> Result<Vec<Vec<f32>>> {
        self.execute(entry, &inputs)
    }
}

impl Exec for ExecutorPool {
    fn manifest(&self) -> &Manifest {
        self.manifest_ref()
    }

    fn run(&self, entry: &Entry, inputs: Vec<HostTensor>) -> Result<Vec<Vec<f32>>> {
        self.execute(entry, inputs)
    }
}
