//! The front-door's TCP face: `bts frontdoor` serving, and the client
//! calls behind `bts submit --frontdoor` / `bts fedctl`.
//!
//! One connection carries one request. A `SubmitJob` frame answers
//! with `JobRouted` + `JobDone` (or a versioned `Shed` / `Error`
//! refusal); `StatsReq` and `KillLeader` answer with the shard map;
//! a transport `Down::Shutdown` frame is echoed as the ack, then the
//! server drains and returns its [`FederationReport`]. Submissions are
//! handled on their own threads so slow jobs never block the accept
//! loop — concurrent tenants are what the fair queue exists for.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::front::Federation;
use crate::coordinator::JobOutput;
use crate::error::{Error, Result};
use crate::metrics::FederationReport;
use crate::net::protocol::{self, LeaderStat, Message};
use crate::serve::JobRequest;
use crate::transport::Down;
use crate::util::testutil::SERVE_JOB_DEADLINE;

fn split(
    stream: TcpStream,
) -> Result<(BufReader<TcpStream>, BufWriter<TcpStream>)> {
    protocol::configure_stream(&stream)?;
    let rd = BufReader::new(stream.try_clone()?);
    let wr = BufWriter::new(stream);
    Ok((rd, wr))
}

fn connect(
    addr: &str,
) -> Result<(BufReader<TcpStream>, BufWriter<TcpStream>)> {
    let stream = TcpStream::connect(addr).map_err(|e| {
        Error::Protocol(format!("connect to front-door {addr}: {e}"))
    })?;
    split(stream)
}

/// The front-door stringifies refusals for the wire; re-structure the
/// admission case so callers (and `bts submit --frontdoor`) get the
/// same [`Error::Admission`] a direct submission would.
fn decode_error(message: String) -> Error {
    match message.strip_prefix("admission rejected: ") {
        Some(rest) => Error::Admission(rest.to_string()),
        None => Error::Protocol(message),
    }
}

/// Wire text for a poisoned federation mutex — some connection thread
/// panicked mid-mutation, so the shared state can no longer be
/// trusted; clients get a structured refusal instead of a hung or
/// panicking server.
const POISONED: &str = "front-door federation state poisoned";

/// Serve one [`Federation`] on `listener` until a `Down::Shutdown`
/// frame arrives; drains queued work and returns the final report.
pub fn serve_frontdoor(
    listener: TcpListener,
    fed: Federation,
) -> Result<FederationReport> {
    let fed = Arc::new(Mutex::new(fed));
    let stop = Arc::new(AtomicBool::new(false));
    let pump = {
        let fed = fed.clone();
        let stop = stop.clone();
        thread::Builder::new()
            .name("bts-frontdoor-pump".into())
            .spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    // A poisoned federation means a connection thread
                    // panicked mid-mutation; stop pumping instead of
                    // cascading the panic through this thread too.
                    let Ok(mut guard) = fed.lock() else { return };
                    guard.pump();
                    drop(guard);
                    thread::sleep(Duration::from_millis(2));
                }
            })
            .map_err(|e| Error::Scheduler(format!("spawn pump: {e}")))?
    };
    let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let Ok((mut rd, mut wr)) = split(stream) else { continue };
        let Ok(first) = Message::read_deadline(
            &mut rd,
            Some(protocol::HANDSHAKE_TIMEOUT),
        ) else {
            continue;
        };
        match first {
            Message::Down(Down::Shutdown) => {
                let _ = Message::Down(Down::Shutdown).write_to(&mut wr);
                break;
            }
            Message::StatsReq => match fed.lock() {
                Ok(guard) => {
                    let stats = guard.leader_stats();
                    drop(guard);
                    let _ =
                        Message::LeaderStats { stats }.write_to(&mut wr);
                }
                Err(_) => {
                    let _ = Message::Error {
                        message: POISONED.into(),
                    }
                    .write_to(&mut wr);
                }
            },
            Message::KillLeader { leader } => {
                let Ok(mut guard) = fed.lock() else {
                    let _ = Message::Error {
                        message: POISONED.into(),
                    }
                    .write_to(&mut wr);
                    continue;
                };
                match guard.kill_leader(leader as usize) {
                    Ok(()) => {
                        let stats = guard.leader_stats();
                        drop(guard);
                        let _ = Message::LeaderStats { stats }
                            .write_to(&mut wr);
                    }
                    Err(e) => {
                        drop(guard);
                        let _ = Message::Error {
                            message: e.to_string(),
                        }
                        .write_to(&mut wr);
                    }
                }
            }
            Message::SubmitJob {
                tenant,
                workload,
                samples,
                seed,
                deadline_s,
                reduce_tasks,
                partitioner,
            } => {
                let fed = fed.clone();
                conns.push(thread::spawn(move || {
                    let mut req =
                        JobRequest::new(workload, samples as usize)
                            .with_seed(seed)
                            .with_reduce(
                                reduce_tasks as usize,
                                partitioner,
                            );
                    if let Some(d) = deadline_s {
                        req = req.with_deadline(d);
                    }
                    handle_submit(&fed, &tenant, req, &mut wr);
                }));
            }
            other => {
                let _ = Message::Error {
                    message: format!(
                        "front-door cannot handle {other:?}"
                    ),
                }
                .write_to(&mut wr);
            }
        }
    }
    for c in conns {
        let _ = c.join();
    }
    stop.store(true, Ordering::Relaxed);
    pump.join()
        .map_err(|_| Error::Scheduler("pump thread panicked".into()))?;
    let mut fed = Arc::try_unwrap(fed)
        .map_err(|_| {
            Error::Scheduler(
                "a connection still holds the federation".into(),
            )
        })?
        .into_inner()
        .map_err(|_| {
            Error::Scheduler("federation mutex poisoned".into())
        })?;
    fed.pump_until_idle(SERVE_JOB_DEADLINE)?;
    fed.shutdown()
}

/// One submission, end to end, on its own thread: admit (refusals go
/// straight back on the wire), then wait for the pump to finish the
/// job and send the routed/terminal frames.
fn handle_submit(
    fed: &Mutex<Federation>,
    tenant: &str,
    req: JobRequest,
    wr: &mut BufWriter<TcpStream>,
) {
    let submitted = match fed.lock() {
        Ok(mut guard) => guard.submit(tenant, req),
        Err(_) => Err(Error::Scheduler(POISONED.into())),
    };
    let id = match submitted {
        Ok(id) => id,
        Err(Error::Shed { retry_after_s, reason }) => {
            let _ = Message::Shed { retry_after_s, reason }.write_to(wr);
            return;
        }
        Err(e) => {
            let _ = Message::Error { message: e.to_string() }.write_to(wr);
            return;
        }
    };
    let deadline = Instant::now() + SERVE_JOB_DEADLINE;
    let done = loop {
        let polled = match fed.lock() {
            Ok(mut guard) => guard.take_result(id),
            Err(_) => {
                let _ = Message::Error { message: POISONED.into() }
                    .write_to(wr);
                return;
            }
        };
        if let Some(done) = polled {
            break done;
        }
        if Instant::now() >= deadline {
            let _ = Message::Error {
                message: format!(
                    "job {id} still unfinished after {SERVE_JOB_DEADLINE:?}"
                ),
            }
            .write_to(wr);
            return;
        }
        thread::sleep(Duration::from_millis(2));
    };
    match done.result {
        Ok(res) => {
            let _ = Message::JobRouted {
                job: id,
                leader: done.leader as u32,
                spilled: done.spilled,
            }
            .write_to(wr);
            let _ = Message::JobDone { job: id, output: res.output }
                .write_to(wr);
        }
        Err(e) => {
            let _ = Message::Error { message: e.to_string() }.write_to(wr);
        }
    }
}

/// What the front-door reports back for one routed job.
#[derive(Debug, Clone)]
pub struct FrontDoorOutcome {
    pub job: u64,
    pub leader: u32,
    pub spilled: bool,
    pub output: JobOutput,
}

/// Submit one job through the front-door at `addr` and block for its
/// output. Shed refusals come back as [`Error::Shed`] (with the
/// Retry-After hint), admission refusals as [`Error::Admission`].
pub fn submit_via_frontdoor(
    addr: &str,
    tenant: &str,
    req: &JobRequest,
) -> Result<FrontDoorOutcome> {
    let (mut rd, mut wr) = connect(addr)?;
    Message::SubmitJob {
        tenant: tenant.to_string(),
        workload: req.workload,
        samples: req.samples as u64,
        seed: req.seed,
        deadline_s: req.deadline_s,
        reduce_tasks: req.reduce_tasks as u32,
        partitioner: req.partitioner,
    }
    .write_to(&mut wr)?;
    let (job, leader, spilled) =
        match Message::read_deadline(&mut rd, Some(SERVE_JOB_DEADLINE))? {
            Message::JobRouted { job, leader, spilled } => {
                (job, leader, spilled)
            }
            Message::Shed { retry_after_s, reason } => {
                return Err(Error::Shed { retry_after_s, reason })
            }
            Message::Error { message } => {
                return Err(decode_error(message))
            }
            other => {
                return Err(Error::Protocol(format!(
                    "unexpected reply to submit: {other:?}"
                )))
            }
        };
    match Message::read_deadline(&mut rd, Some(SERVE_JOB_DEADLINE))? {
        Message::JobDone { job: j, output } if j == job => {
            Ok(FrontDoorOutcome { job, leader, spilled, output })
        }
        Message::Error { message } => Err(decode_error(message)),
        other => Err(Error::Protocol(format!(
            "unexpected terminal frame: {other:?}"
        ))),
    }
}

/// Fetch the shard map (per-leader liveness and load digests).
pub fn frontdoor_stats(addr: &str) -> Result<Vec<LeaderStat>> {
    let (mut rd, mut wr) = connect(addr)?;
    Message::StatsReq.write_to(&mut wr)?;
    match Message::read_deadline(
        &mut rd,
        Some(protocol::HANDSHAKE_TIMEOUT),
    )? {
        Message::LeaderStats { stats } => Ok(stats),
        Message::Error { message } => Err(decode_error(message)),
        other => Err(Error::Protocol(format!(
            "unexpected stats reply: {other:?}"
        ))),
    }
}

/// Kill leader `leader` (fault injection / ops drill); returns the
/// post-kill shard map. The reply waits out the victim's drain.
pub fn frontdoor_kill(addr: &str, leader: u32) -> Result<Vec<LeaderStat>> {
    let (mut rd, mut wr) = connect(addr)?;
    Message::KillLeader { leader }.write_to(&mut wr)?;
    match Message::read_deadline(&mut rd, Some(SERVE_JOB_DEADLINE))? {
        Message::LeaderStats { stats } => Ok(stats),
        Message::Error { message } => Err(decode_error(message)),
        other => Err(Error::Protocol(format!(
            "unexpected kill reply: {other:?}"
        ))),
    }
}

/// Ask the front-door to drain and exit; the echoed frame is the ack.
pub fn frontdoor_shutdown(addr: &str) -> Result<()> {
    let (mut rd, mut wr) = connect(addr)?;
    Message::Down(Down::Shutdown).write_to(&mut wr)?;
    match Message::read_deadline(
        &mut rd,
        Some(protocol::HANDSHAKE_TIMEOUT),
    )? {
        Message::Down(Down::Shutdown) => Ok(()),
        Message::Error { message } => Err(Error::Protocol(message)),
        other => Err(Error::Protocol(format!(
            "unexpected shutdown ack: {other:?}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{ModelParams, Workload};
    use crate::exec::Backend;
    use crate::federation::front::FederationConfig;

    fn spawn_frontdoor(
        cfg: FederationConfig,
    ) -> (String, thread::JoinHandle<Result<FederationReport>>) {
        let backend = Arc::new(Backend::native(ModelParams::default()));
        let fed = Federation::start(backend, cfg).unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = thread::spawn(move || serve_frontdoor(listener, fed));
        (addr, h)
    }

    #[test]
    fn frontdoor_serves_stats_submit_and_shutdown() {
        let (addr, h) = spawn_frontdoor(FederationConfig {
            leaders: 2,
            workers_per_leader: 2,
            ..FederationConfig::default()
        });
        let stats = frontdoor_stats(&addr).unwrap();
        assert_eq!(stats.len(), 2);
        assert!(stats.iter().all(|s| s.alive));
        let req = JobRequest::new(Workload::NetflixLo, 6).with_seed(0xFED);
        let out = submit_via_frontdoor(&addr, "tenant-a", &req).unwrap();
        assert!(out.leader < 2);
        assert!(!out.spilled);
        let stats = frontdoor_stats(&addr).unwrap();
        assert_eq!(
            stats.iter().map(|s| s.completed).sum::<u64>(),
            1,
            "the completion shows up in the shard map"
        );
        frontdoor_shutdown(&addr).unwrap();
        let report = h.join().unwrap().unwrap();
        assert_eq!(report.jobs_completed, 1);
        assert_eq!(report.jobs_failed, 0);
        assert_eq!(report.tenants, 1);
    }

    #[test]
    fn frontdoor_rejects_infeasible_deadline_with_structure() {
        let (addr, h) = spawn_frontdoor(FederationConfig {
            leaders: 1,
            workers_per_leader: 2,
            ..FederationConfig::default()
        });
        let req = JobRequest::new(Workload::Eaglet, 64)
            .with_seed(1)
            .with_deadline(1e-9);
        let err = submit_via_frontdoor(&addr, "t", &req).unwrap_err();
        assert!(
            matches!(err, Error::Admission(_)),
            "wire round trip keeps the admission structure: {err}"
        );
        frontdoor_shutdown(&addr).unwrap();
        let report = h.join().unwrap().unwrap();
        assert_eq!(report.admission_rejected, 1);
    }

    #[test]
    fn frontdoor_kill_rehomes_over_tcp() {
        let (addr, h) = spawn_frontdoor(FederationConfig {
            leaders: 2,
            workers_per_leader: 2,
            ..FederationConfig::default()
        });
        let stats = frontdoor_kill(&addr, 0).unwrap();
        assert!(!stats[0].alive && stats[1].alive);
        assert!(
            frontdoor_kill(&addr, 0).is_err(),
            "double kill is refused"
        );
        // any tenant now lands on the survivor
        let req = JobRequest::new(Workload::NetflixLo, 6).with_seed(9);
        let out = submit_via_frontdoor(&addr, "whoever", &req).unwrap();
        assert_eq!(out.leader, 1);
        frontdoor_shutdown(&addr).unwrap();
        let report = h.join().unwrap().unwrap();
        assert_eq!(report.jobs_completed, 1);
    }
}
