//! Dominant-resource fairness (DRF) over the federation's two shared
//! resources: worker slots and cache bytes.
//!
//! Tenants at the front-door compete for map slots across every live
//! leader and for the shared block-cache budget. A tenant's *dominant
//! share* is the larger of its two resource fractions; DRF's
//! progressive-filling rule repeatedly grants one job to the tenant
//! with the smallest dominant share that still fits. The classic
//! guarantees carry over at job granularity:
//!
//! * **work conservation** — allocation only stops when no remaining
//!   demand fits in the leftover capacity;
//! * **envy-freeness within one job's rounding** — a tenant with unmet
//!   demand never trails another tenant by more than that tenant's
//!   single-job dominant increment (for demand shapes it could have
//!   taken itself);
//! * **arrival-order independence** — ties break on the tenant name,
//!   never on input position, so shuffling the submission order cannot
//!   change anyone's grant.
//!
//! `prop_invariants.rs` checks all three properties over random tenant
//! mixes; the live front-door uses the same [`Capacity::dominant_share`]
//! comparator to pick which tenant's queue dispatches next.

/// Resources one job (or one tenant's dispatched set) holds: map slots
/// plus nominal cache bytes. A job always occupies at least one slot —
/// [`allocate`] normalizes zero-slot demands up to 1 so progressive
/// filling terminates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Demand {
    pub slots: u64,
    pub cache_bytes: u64,
}

impl Demand {
    pub fn plus(self, other: Demand) -> Demand {
        Demand {
            slots: self.slots + other.slots,
            cache_bytes: self.cache_bytes + other.cache_bytes,
        }
    }

    /// Release `other` (saturating: a release can never go negative).
    pub fn minus(self, other: Demand) -> Demand {
        Demand {
            slots: self.slots.saturating_sub(other.slots),
            cache_bytes: self.cache_bytes.saturating_sub(other.cache_bytes),
        }
    }
}

/// Total divisible capacity of the federation (live leaders × workers,
/// live leaders × cache budget). `cache_bytes == 0` means the cache
/// dimension is unconfigured: it neither constrains fitting nor
/// contributes to dominant shares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Capacity {
    pub slots: u64,
    pub cache_bytes: u64,
}

impl Capacity {
    /// Would granting `extra` on top of `used` still fit?
    pub fn fits(&self, used: Demand, extra: Demand) -> bool {
        used.slots + extra.slots <= self.slots
            && (self.cache_bytes == 0
                || used.cache_bytes + extra.cache_bytes <= self.cache_bytes)
    }

    /// max(slot fraction, cache fraction) — the DRF comparator. An
    /// unconfigured dimension (capacity 0) contributes 0.
    pub fn dominant_share(&self, used: Demand) -> f64 {
        let s = if self.slots == 0 {
            0.0
        } else {
            used.slots as f64 / self.slots as f64
        };
        let c = if self.cache_bytes == 0 {
            0.0
        } else {
            used.cache_bytes as f64 / self.cache_bytes as f64
        };
        s.max(c)
    }
}

/// One tenant's queue as the allocator sees it: a per-job demand
/// vector and how many jobs it wants. Tenant names must be distinct —
/// the name is the deterministic tie-breaker.
#[derive(Debug, Clone)]
pub struct TenantDemand {
    pub tenant: String,
    pub per_job: Demand,
    pub jobs: u64,
}

/// Progressive-filling DRF: repeatedly grant one job to the tenant
/// with the smallest dominant share whose next job still fits, ties
/// broken by tenant name. Returns jobs granted per tenant, aligned
/// with the input order (the *answer* is input-order aligned; the
/// *decision* never depends on input order).
pub fn allocate(cap: Capacity, tenants: &[TenantDemand]) -> Vec<u64> {
    let n = tenants.len();
    // Normalized per-job demands: every job holds ≥ 1 slot.
    let per_job: Vec<Demand> = tenants
        .iter()
        .map(|t| Demand {
            slots: t.per_job.slots.max(1),
            cache_bytes: t.per_job.cache_bytes,
        })
        .collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| tenants[a].tenant.cmp(&tenants[b].tenant));
    let mut granted = vec![0u64; n];
    let mut used = vec![Demand::default(); n];
    let mut total = Demand::default();
    loop {
        // Strict `<` while scanning in name order keeps ties on the
        // lexicographically-smallest tenant — the permutation-
        // invariance anchor.
        let mut best: Option<(f64, usize)> = None;
        for &i in &order {
            if granted[i] >= tenants[i].jobs {
                continue;
            }
            if !cap.fits(total, per_job[i]) {
                continue;
            }
            let share = cap.dominant_share(used[i]);
            let better = match best {
                None => true,
                Some((bs, _)) => share < bs,
            };
            if better {
                best = Some((share, i));
            }
        }
        let Some((_, i)) = best else { break };
        granted[i] += 1;
        used[i] = used[i].plus(per_job[i]);
        total = total.plus(per_job[i]);
    }
    granted
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(name: &str, slots: u64, cache: u64, jobs: u64) -> TenantDemand {
        TenantDemand {
            tenant: name.into(),
            per_job: Demand { slots, cache_bytes: cache },
            jobs,
        }
    }

    #[test]
    fn splits_identical_tenants_evenly() {
        let cap = Capacity { slots: 8, cache_bytes: 0 };
        let g = allocate(cap, &[t("a", 1, 0, 100), t("b", 1, 0, 100)]);
        assert_eq!(g, vec![4, 4]);
    }

    #[test]
    fn classic_drf_example_balances_dominant_shares() {
        // The DRF paper's shape: tenant A dominant in CPU (slots),
        // tenant B dominant in memory (cache). Equalizing dominant
        // shares gives A 3 jobs (3/9 slots) and B 2 jobs (2/6 cache
        // units ≈ 0.33 each).
        let cap = Capacity { slots: 9, cache_bytes: 18 };
        let g = allocate(
            cap,
            &[t("a", 1, 4, 100), t("b", 3, 1, 100)],
        );
        let share_a = cap.dominant_share(Demand {
            slots: g[0],
            cache_bytes: g[0] * 4,
        });
        let share_b = cap.dominant_share(Demand {
            slots: g[1] * 3,
            cache_bytes: g[1],
        });
        assert!(g[0] >= 1 && g[1] >= 1, "both make progress: {g:?}");
        assert!(
            (share_a - share_b).abs() <= 4.0 / 18.0 + 1e-12,
            "dominant shares within one increment: {share_a} vs {share_b}"
        );
    }

    #[test]
    fn stops_exactly_at_capacity() {
        let cap = Capacity { slots: 5, cache_bytes: 0 };
        let g = allocate(cap, &[t("a", 2, 0, 10), t("b", 2, 0, 10)]);
        // 2+2 slots granted; the fifth slot fits nobody's 2-slot job.
        assert_eq!(g.iter().sum::<u64>(), 2);
    }

    #[test]
    fn grants_everything_under_light_load() {
        let cap = Capacity { slots: 100, cache_bytes: 1 << 30 };
        let demands = [t("a", 1, 1024, 3), t("b", 2, 2048, 5)];
        let g = allocate(cap, &demands);
        assert_eq!(g, vec![3, 5], "no contention ⇒ full grants");
    }

    #[test]
    fn zero_slot_demand_still_terminates() {
        let cap = Capacity { slots: 4, cache_bytes: 0 };
        let g = allocate(cap, &[t("a", 0, 0, 1_000_000)]);
        assert_eq!(g, vec![4], "normalized to 1 slot per job");
    }

    #[test]
    fn ignores_cache_dimension_when_unconfigured() {
        let cap = Capacity { slots: 2, cache_bytes: 0 };
        let g = allocate(cap, &[t("a", 1, u64::MAX / 2, 2)]);
        assert_eq!(g, vec![2]);
        assert_eq!(
            cap.dominant_share(Demand { slots: 1, cache_bytes: 99 }),
            0.5
        );
    }

    #[test]
    fn empty_inputs() {
        let cap = Capacity { slots: 4, cache_bytes: 0 };
        assert!(allocate(cap, &[]).is_empty());
        assert_eq!(allocate(cap, &[t("a", 1, 0, 0)]), vec![0]);
    }

    #[test]
    fn demand_arithmetic_saturates() {
        let a = Demand { slots: 1, cache_bytes: 10 };
        let b = Demand { slots: 2, cache_bytes: 3 };
        assert_eq!(b.plus(a), Demand { slots: 3, cache_bytes: 13 });
        assert_eq!(a.minus(b), Demand { slots: 0, cache_bytes: 7 });
    }
}
