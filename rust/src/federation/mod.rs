//! Front-door federation: sharded multi-leader serving with fair
//! queueing and load shedding (DESIGN.md §15).
//!
//! One leader's dispatcher thread is the serve layer's scaling
//! ceiling: every tenant's tasks, partials, and reduce steps funnel
//! through it. The federation stands N *independent* leaders — each a
//! full [`crate::serve::JobService`] with its own pool and store —
//! behind one `bts frontdoor` admission point, and leans on the
//! determinism contract (same seed ⇒ same statistic, wherever the job
//! runs) to make placement a pure performance decision:
//!
//! * [`drf`] — dominant-resource fair allocation over worker slots +
//!   cache bytes (progressive filling; permutation-invariant,
//!   work-conserving, envy-free within one job's rounding);
//! * [`front`] — the [`Federation`] core: ring-sharded tenant → home
//!   leader placement, SLO admission before any leader is touched,
//!   per-tenant DRF fair queueing, deterministic spillover to the
//!   least-loaded sibling, Retry-After load shedding, and kill /
//!   re-home;
//! * [`server`] — the framed-TCP face (`SubmitJob` → `JobRouted` +
//!   `JobDone`, `StatsReq`/`KillLeader` → `LeaderStats`) plus the
//!   client calls behind `bts submit --frontdoor` and `bts fedctl`.

pub mod drf;
pub mod front;
pub mod server;

pub use drf::{allocate, Capacity, Demand, TenantDemand};
pub use front::{CompletedJob, Federation, FederationConfig};
pub use server::{
    frontdoor_kill, frontdoor_shutdown, frontdoor_stats, serve_frontdoor,
    submit_via_frontdoor, FrontDoorOutcome,
};
