//! The front-door core: N independent leaders behind one sharding,
//! fair-queueing, load-shedding admission point (DESIGN.md §15).
//!
//! A [`Federation`] owns its leaders in-process — each one a full
//! [`JobService`] with its own dispatcher, worker pool, and replicated
//! store — and routes tenants onto them with the same consistent-hash
//! ring the data layer uses for blocks. The routing pipeline per
//! submission:
//!
//! 1. **admission** — the SLO planner estimate (memoized in an
//!    [`EstimateCache`]) gates infeasible deadlines *before* the job
//!    reaches any leader;
//! 2. **shed** — past the front-door backlog cap the job is refused
//!    with [`Error::Shed`] carrying a deterministic Retry-After hint,
//!    so overload degrades into fast, honest refusals instead of
//!    unbounded queueing;
//! 3. **fair queue** — admitted jobs wait in per-tenant FIFOs; the
//!    dispatch sweep releases them in DRF order (smallest dominant
//!    share over slots + cache bytes first), so a tenant spraying
//!    hundreds of jobs cannot starve a light one;
//! 4. **route** — the tenant's home shard is its first *live* ring
//!    replica; a saturated home spills the whole job to the
//!    least-loaded live sibling (counted, deterministic: the spill
//!    decision reads only the front-door's own outstanding ledger);
//! 5. **re-home** — when a leader is killed its pending and in-flight
//!    tenants re-route to the surviving ring order. The determinism
//!    contract (same seed ⇒ same statistic on any leader) makes
//!    re-homing invisible in the outputs.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::drf::{Capacity, Demand};
use crate::dfs::Ring;
use crate::error::{Error, Result};
use crate::exec::Backend;
use crate::metrics::{jain_index, FederationReport};
use crate::net::protocol::LeaderStat;
use crate::serve::{
    feasible, JobHandle, JobRequest, JobResult, JobService, PoolConfig,
    ServeConfig,
};
use crate::slo::EstimateCache;
use crate::workloads::default_compute_s_per_mib;

/// Shape of a federation: how many leaders, how big each one is, and
/// where the overload thresholds sit.
#[derive(Debug, Clone)]
pub struct FederationConfig {
    /// Independent leader instances (shards).
    pub leaders: usize,
    /// Map slots per leader's pool.
    pub workers_per_leader: usize,
    /// Jobs each leader multiplexes at once.
    pub max_active_per_leader: usize,
    /// Per-leader shared block-cache budget in MiB (0 disables; also
    /// turns off the DRF cache dimension).
    pub cache_mb_per_leader: usize,
    /// Outstanding (dispatched, unfinished) jobs the front-door lets
    /// one leader carry before routing around it. This is front-door
    /// ledger accounting — not a racy gauge read — so spill decisions
    /// are deterministic given the dispatch/completion sequence.
    pub leader_outstanding_cap: usize,
    /// Admitted-but-undispatched jobs the front-door holds across all
    /// tenants before shedding new submissions.
    pub backlog_cap: usize,
    /// Virtual nodes per leader on the tenant ring.
    pub vnodes: usize,
}

impl Default for FederationConfig {
    fn default() -> Self {
        FederationConfig {
            leaders: 2,
            workers_per_leader: 2,
            max_active_per_leader: 2,
            cache_mb_per_leader: 0,
            leader_outstanding_cap: 4,
            backlog_cap: 64,
            vnodes: 32,
        }
    }
}

impl FederationConfig {
    /// The [`ServeConfig`] each leader starts with.
    pub fn serve_config(&self) -> ServeConfig {
        ServeConfig {
            pool: PoolConfig {
                workers: self.workers_per_leader.max(1),
                cache_mb: self.cache_mb_per_leader,
                ..PoolConfig::default()
            },
            max_active: self.max_active_per_leader.max(1),
            ..ServeConfig::default()
        }
    }
}

/// One admitted job waiting in its tenant's FIFO.
struct PendingJob {
    id: u64,
    req: JobRequest,
}

/// One dispatched, unfinished job.
struct Inflight {
    id: u64,
    tenant: String,
    leader: usize,
    spilled: bool,
    req: JobRequest,
    handle: JobHandle,
}

/// A finished federation job: where it ran and what came back.
#[derive(Debug)]
pub struct CompletedJob {
    pub id: u64,
    pub tenant: String,
    pub leader: usize,
    pub spilled: bool,
    pub result: Result<JobResult>,
}

/// The federation front-door (see module docs for the pipeline).
/// Single-threaded by design: `submit` enqueues, [`Federation::pump`]
/// sweeps completions and dispatches in DRF order. The TCP server
/// wraps this in a mutex with a pump thread.
pub struct Federation {
    cfg: FederationConfig,
    /// `None` marks a killed leader; indices are stable shard ids.
    leaders: Vec<Option<JobService>>,
    ring: Ring,
    est: EstimateCache,
    next_id: u64,
    /// Per-tenant FIFOs of admitted jobs (BTreeMap: deterministic
    /// name-order iteration is the DRF tie-breaker).
    pending: BTreeMap<String, VecDeque<PendingJob>>,
    pending_total: usize,
    /// Resources each tenant's dispatched jobs currently hold.
    held: HashMap<String, Demand>,
    inflight: Vec<Inflight>,
    /// Dispatched-minus-completed per leader (the spill ledger).
    outstanding: Vec<usize>,
    completed: Vec<CompletedJob>,
    // session accounting
    submitted: u64,
    admission_rejected: u64,
    shed: u64,
    spilled: u64,
    rehomed: u64,
    completed_ok: u64,
    failed: u64,
    tenant_jobs: HashMap<String, u64>,
    tenant_completed: HashMap<String, u64>,
    leader_completed: Vec<u64>,
    busy_polls: Vec<u64>,
    total_polls: u64,
    started: Instant,
}

impl Federation {
    /// Start `cfg.leaders` independent leader services over one shared
    /// backend.
    pub fn start(
        backend: Arc<Backend>,
        cfg: FederationConfig,
    ) -> Result<Federation> {
        if cfg.leaders == 0 {
            return Err(Error::Config(
                "federation needs at least one leader".into(),
            ));
        }
        let mut leaders = Vec::with_capacity(cfg.leaders);
        for _ in 0..cfg.leaders {
            leaders.push(Some(JobService::start(
                backend.clone(),
                cfg.serve_config(),
            )?));
        }
        let n = cfg.leaders;
        Ok(Federation {
            ring: Ring::new(n, cfg.vnodes.max(1)),
            leaders,
            est: EstimateCache::new(),
            next_id: 1,
            pending: BTreeMap::new(),
            pending_total: 0,
            held: HashMap::new(),
            inflight: Vec::new(),
            outstanding: vec![0; n],
            completed: Vec::new(),
            submitted: 0,
            admission_rejected: 0,
            shed: 0,
            spilled: 0,
            rehomed: 0,
            completed_ok: 0,
            failed: 0,
            tenant_jobs: HashMap::new(),
            tenant_completed: HashMap::new(),
            leader_completed: vec![0; n],
            busy_polls: vec![0; n],
            total_polls: 0,
            started: Instant::now(),
            cfg,
        })
    }

    /// The tenant's home shard with every leader alive (its ring
    /// primary).
    pub fn home_leader(&self, tenant: &str) -> usize {
        self.ring.primary(tenant)
    }

    fn live_leaders(&self) -> usize {
        self.leaders.iter().filter(|l| l.is_some()).count()
    }

    /// Total divisible capacity over live leaders (the DRF
    /// denominator).
    fn capacity(&self) -> Capacity {
        let live = self.live_leaders() as u64;
        Capacity {
            slots: live * self.cfg.workers_per_leader.max(1) as u64,
            cache_bytes: live
                * self.cfg.cache_mb_per_leader as u64
                * 1024
                * 1024,
        }
    }

    /// Resources one dispatched job of `req` holds against the DRF
    /// capacity.
    fn demand_of(&self, req: &JobRequest) -> Demand {
        Demand {
            slots: 1,
            cache_bytes: if self.cfg.cache_mb_per_leader > 0 {
                req.nominal_bytes() as u64
            } else {
                0
            },
        }
    }

    /// Planner estimate for `req` on one leader's pool (memoized).
    pub fn estimate_s(&self, req: &JobRequest) -> f64 {
        self.est.estimate_s(
            req.workload,
            req.nominal_bytes(),
            self.cfg.workers_per_leader.max(1),
            default_compute_s_per_mib(req.workload),
        )
    }

    /// Admit one job for `tenant`, or refuse it: `Error::Admission`
    /// when its deadline is infeasible under the planner estimate
    /// (checked here, before any leader sees the job), `Error::Shed`
    /// with a Retry-After hint when the front-door backlog is at cap.
    pub fn submit(&mut self, tenant: &str, req: JobRequest) -> Result<u64> {
        self.submitted += 1;
        if self.live_leaders() == 0 {
            return Err(Error::Scheduler(
                "every leader in the federation is dead".into(),
            ));
        }
        if req.samples == 0 {
            return Err(Error::Config("job needs at least one sample".into()));
        }
        if let Some(d) = req.deadline_s {
            if !d.is_finite() || d < 0.0 {
                return Err(Error::Config(format!(
                    "deadline must be a finite non-negative number of \
                     seconds, got {d}"
                )));
            }
            let est = self.estimate_s(&req);
            if !feasible(est, req.deadline_s) {
                self.admission_rejected += 1;
                return Err(Error::Admission(format!(
                    "planner estimates {est:.1}s for {} samples of {}, \
                     beyond the {:.3}s deadline",
                    req.samples,
                    req.workload.name(),
                    d,
                )));
            }
        }
        if self.pending_total >= self.cfg.backlog_cap.max(1) {
            self.shed += 1;
            let est = self.estimate_s(&req);
            let slots = self.capacity().slots.max(1) as f64;
            // One backlog's worth of estimated work per available slot:
            // the earliest a retry could plausibly be dispatched.
            let retry_after_s =
                est * (1.0 + self.pending_total as f64 / slots);
            return Err(Error::Shed {
                retry_after_s,
                reason: format!(
                    "front-door backlog {} at cap {} for tenant {tenant}",
                    self.pending_total, self.cfg.backlog_cap
                ),
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        *self.tenant_jobs.entry(tenant.to_string()).or_insert(0) += 1;
        self.pending
            .entry(tenant.to_string())
            .or_default()
            .push_back(PendingJob { id, req });
        self.pending_total += 1;
        Ok(id)
    }

    /// Pick where `tenant`'s next job should run, reading only the
    /// front-door ledger: the live home shard if it has headroom, else
    /// the least-loaded live sibling (a *spill*), else nowhere.
    /// Returns `(leader, spilled, rehomed)`.
    fn route(&self, tenant: &str) -> Option<(usize, bool, bool)> {
        let reps = self.ring.replicas(tenant, self.leaders.len());
        let primary = reps[0];
        let home = *reps.iter().find(|&&l| self.leaders[l].is_some())?;
        let cap = self.cfg.leader_outstanding_cap.max(1);
        if self.outstanding[home] < cap {
            return Some((home, false, home != primary));
        }
        let sibling = (0..self.leaders.len())
            .filter(|&l| {
                l != home
                    && self.leaders[l].is_some()
                    && self.outstanding[l] < cap
            })
            .min_by_key(|&l| (self.outstanding[l], l))?;
        Some((sibling, true, false))
    }

    /// One sweep: collect finished jobs (re-homing any stranded by a
    /// killed leader), then dispatch pending jobs in DRF order while
    /// leaders have headroom. Returns completions collected this sweep.
    pub fn pump(&mut self) -> usize {
        let mut collected = 0;
        // 1. completions
        let inflight = std::mem::take(&mut self.inflight);
        for inf in inflight {
            let Some(result) = inf.handle.try_wait() else {
                self.inflight.push(inf);
                continue;
            };
            collected += 1;
            self.outstanding[inf.leader] =
                self.outstanding[inf.leader].saturating_sub(1);
            let d = self.demand_of(&inf.req);
            if let Some(h) = self.held.get_mut(&inf.tenant) {
                *h = h.minus(d);
            }
            match result {
                Ok(res) => {
                    self.completed_ok += 1;
                    self.leader_completed[inf.leader] += 1;
                    *self
                        .tenant_completed
                        .entry(inf.tenant.clone())
                        .or_insert(0) += 1;
                    self.completed.push(CompletedJob {
                        id: inf.id,
                        tenant: inf.tenant,
                        leader: inf.leader,
                        spilled: inf.spilled,
                        result: Ok(res),
                    });
                }
                Err(_) if self.leaders[inf.leader].is_none() => {
                    // The leader died under this job: re-home it. Same
                    // request, same seed ⇒ same statistic on the
                    // surviving shard.
                    self.rehomed += 1;
                    self.pending
                        .entry(inf.tenant.clone())
                        .or_default()
                        .push_back(PendingJob { id: inf.id, req: inf.req });
                    self.pending_total += 1;
                }
                Err(e) => {
                    self.failed += 1;
                    self.completed.push(CompletedJob {
                        id: inf.id,
                        tenant: inf.tenant,
                        leader: inf.leader,
                        spilled: inf.spilled,
                        result: Err(e),
                    });
                }
            }
        }
        // 2. DRF dispatch
        loop {
            if self.live_leaders() == 0 {
                // Nothing can run anywhere: fail the backlog loudly
                // rather than hold it forever.
                let pending = std::mem::take(&mut self.pending);
                for (tenant, q) in pending {
                    for pj in q {
                        self.failed += 1;
                        self.completed.push(CompletedJob {
                            id: pj.id,
                            tenant: tenant.clone(),
                            leader: 0,
                            spilled: false,
                            result: Err(Error::Scheduler(
                                "every leader in the federation is dead"
                                    .into(),
                            )),
                        });
                    }
                }
                self.pending_total = 0;
                break;
            }
            let cap = self.capacity();
            let mut order: Vec<(f64, String)> = self
                .pending
                .iter()
                .filter(|(_, q)| !q.is_empty())
                .map(|(t, _)| {
                    let held =
                        self.held.get(t).copied().unwrap_or_default();
                    (cap.dominant_share(held), t.clone())
                })
                .collect();
            // total_cmp, not partial_cmp().unwrap(): dominant shares
            // derive from remotely-submitted job demands, and a NaN
            // there must order deterministically, not panic the pump.
            order.sort_by(|a, b| {
                a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1))
            });
            let mut dispatched = false;
            for (_, tenant) in order {
                let Some((leader, spill, rehome)) = self.route(&tenant)
                else {
                    continue;
                };
                // `order` was built from non-empty queues, but stay
                // panic-free if that invariant ever slips: an empty or
                // missing queue just yields no dispatch this round.
                let Some(queue) = self.pending.get_mut(&tenant) else {
                    continue;
                };
                let Some(pj) = queue.pop_front() else {
                    self.pending.remove(&tenant);
                    continue;
                };
                if queue.is_empty() {
                    self.pending.remove(&tenant);
                }
                self.pending_total -= 1;
                let Some(svc) = self.leaders[leader].as_ref() else {
                    // Routed to a leader that died under us: requeue at
                    // the front and let the next round re-route.
                    self.pending_total += 1;
                    self.pending
                        .entry(tenant)
                        .or_default()
                        .push_front(pj);
                    continue;
                };
                match svc.submit(pj.req.clone()) {
                    Ok(handle) => {
                        self.outstanding[leader] += 1;
                        let d = self.demand_of(&pj.req);
                        let h = self
                            .held
                            .entry(tenant.clone())
                            .or_default();
                        *h = h.plus(d);
                        if spill {
                            self.spilled += 1;
                        }
                        if rehome {
                            self.rehomed += 1;
                        }
                        self.inflight.push(Inflight {
                            id: pj.id,
                            tenant,
                            leader,
                            spilled: spill,
                            req: pj.req,
                            handle,
                        });
                    }
                    Err(e) => {
                        self.failed += 1;
                        self.completed.push(CompletedJob {
                            id: pj.id,
                            tenant,
                            leader,
                            spilled: spill,
                            result: Err(e),
                        });
                    }
                }
                dispatched = true;
                break;
            }
            if !dispatched {
                break;
            }
        }
        // 3. utilization sampling
        self.total_polls += 1;
        for (i, &o) in self.outstanding.iter().enumerate() {
            if self.leaders[i].is_some() && o > 0 {
                self.busy_polls[i] += 1;
            }
        }
        collected
    }

    /// No admitted job is waiting or running.
    pub fn idle(&self) -> bool {
        self.pending_total == 0 && self.inflight.is_empty()
    }

    /// Pump until idle or `timeout`, sleeping briefly between sweeps.
    pub fn pump_until_idle(&mut self, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        while !self.idle() {
            self.pump();
            if self.idle() {
                break;
            }
            if Instant::now() >= deadline {
                return Err(Error::Scheduler(format!(
                    "federation still busy after {timeout:?}: {} pending, \
                     {} in flight",
                    self.pending_total,
                    self.inflight.len()
                )));
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        Ok(())
    }

    /// Take every completion collected so far.
    pub fn drain_completions(&mut self) -> Vec<CompletedJob> {
        std::mem::take(&mut self.completed)
    }

    /// Take the completion for one job id, if it finished.
    pub fn take_result(&mut self, id: u64) -> Option<CompletedJob> {
        let idx = self.completed.iter().position(|c| c.id == id)?;
        Some(self.completed.remove(idx))
    }

    /// Kill leader `i`: drain its service and mark the shard dead.
    /// In-flight jobs finish during the drain; jobs still queued at
    /// the front-door re-route to the surviving ring order on the next
    /// pump.
    pub fn kill_leader(&mut self, i: usize) -> Result<()> {
        if i >= self.leaders.len() {
            return Err(Error::Config(format!(
                "no leader {i} in a {}-leader federation",
                self.leaders.len()
            )));
        }
        let svc = self.leaders[i].take().ok_or_else(|| {
            Error::Config(format!("leader {i} is already dead"))
        })?;
        svc.shutdown()?;
        Ok(())
    }

    /// Per-shard wire stats (alive flag, live gauge, completions).
    pub fn leader_stats(&self) -> Vec<LeaderStat> {
        self.leaders
            .iter()
            .enumerate()
            .map(|(i, svc)| match svc {
                Some(svc) => {
                    let d = svc.load();
                    LeaderStat {
                        leader: i as u32,
                        alive: true,
                        active: d.active as u32,
                        queued: d.queued as u32,
                        completed: self.leader_completed[i],
                    }
                }
                None => LeaderStat {
                    leader: i as u32,
                    alive: false,
                    active: 0,
                    queued: 0,
                    completed: self.leader_completed[i],
                },
            })
            .collect()
    }

    /// Session report so far (final when taken at shutdown).
    pub fn report(&self) -> FederationReport {
        let polls = self.total_polls.max(1) as f64;
        let completions: Vec<f64> = self
            .tenant_jobs
            .keys()
            .map(|t| {
                self.tenant_completed.get(t).copied().unwrap_or(0) as f64
            })
            .collect();
        FederationReport {
            leaders: self.cfg.leaders,
            jobs_submitted: self.submitted,
            jobs_completed: self.completed_ok,
            jobs_failed: self.failed,
            admission_rejected: self.admission_rejected,
            shed: self.shed,
            spilled: self.spilled,
            rehomed: self.rehomed,
            wall_s: self.started.elapsed().as_secs_f64(),
            leader_completed: self.leader_completed.clone(),
            leader_utilization: self
                .busy_polls
                .iter()
                .map(|&b| b as f64 / polls)
                .collect(),
            tenants: self.tenant_jobs.len(),
            fairness: jain_index(&completions),
        }
    }

    /// Shut down every surviving leader and return the final report.
    /// Call [`Federation::pump_until_idle`] first if queued work should
    /// finish.
    pub fn shutdown(mut self) -> Result<FederationReport> {
        let report = self.report();
        for slot in self.leaders.iter_mut() {
            if let Some(svc) = slot.take() {
                svc.shutdown()?;
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{ModelParams, Workload};
    use crate::util::testutil::SERVE_JOB_DEADLINE;

    fn native_fed(cfg: FederationConfig) -> Federation {
        let backend = Arc::new(Backend::native(ModelParams::default()));
        Federation::start(backend, cfg).unwrap()
    }

    fn small_cfg() -> FederationConfig {
        FederationConfig {
            leaders: 2,
            workers_per_leader: 2,
            max_active_per_leader: 2,
            leader_outstanding_cap: 2,
            ..FederationConfig::default()
        }
    }

    fn req(samples: usize, seed: u64) -> JobRequest {
        JobRequest::new(Workload::NetflixLo, samples).with_seed(seed)
    }

    #[test]
    fn drains_multi_tenant_load_and_reports() {
        let mut fed = native_fed(small_cfg());
        for (i, tenant) in ["alpha", "beta", "gamma"].iter().enumerate() {
            for j in 0..2 {
                fed.submit(tenant, req(6, 100 + (i * 10 + j) as u64))
                    .unwrap();
            }
        }
        fed.pump_until_idle(SERVE_JOB_DEADLINE).unwrap();
        let done = fed.drain_completions();
        assert_eq!(done.len(), 6);
        assert!(done.iter().all(|c| c.result.is_ok()));
        let report = fed.shutdown().unwrap();
        assert_eq!(report.jobs_submitted, 6);
        assert_eq!(report.jobs_completed, 6);
        assert_eq!(report.jobs_failed, 0);
        assert_eq!(report.tenants, 3);
        assert_eq!(
            report.leader_completed.iter().sum::<u64>(),
            6,
            "every completion lands on some shard"
        );
        // equal per-tenant loads drained fully ⇒ perfectly fair
        assert!(
            report.fairness > 0.999,
            "fairness {} for equal loads",
            report.fairness
        );
    }

    #[test]
    fn sheds_past_backlog_cap_with_retry_hint() {
        let cfg = FederationConfig {
            backlog_cap: 2,
            ..small_cfg()
        };
        let mut fed = native_fed(cfg);
        fed.submit("t", req(4, 1)).unwrap();
        fed.submit("t", req(4, 2)).unwrap();
        let err = fed.submit("t", req(4, 3)).unwrap_err();
        match err {
            Error::Shed { retry_after_s, reason } => {
                assert!(retry_after_s > 0.0);
                assert!(reason.contains("backlog 2 at cap 2"), "{reason}");
            }
            other => panic!("expected Shed, got {other}"),
        }
        assert_eq!(fed.report().shed, 1);
        fed.pump_until_idle(SERVE_JOB_DEADLINE).unwrap();
        fed.shutdown().unwrap();
    }

    #[test]
    fn admission_gate_rejects_before_any_leader() {
        let mut fed = native_fed(small_cfg());
        let err = fed
            .submit("t", req(64, 1).with_deadline(1e-9))
            .unwrap_err();
        assert!(matches!(err, Error::Admission(_)), "got {err}");
        let report = fed.report();
        assert_eq!(report.admission_rejected, 1);
        // the job never reached a leader
        assert!(fed.idle());
        fed.shutdown().unwrap();
    }

    #[test]
    fn saturated_home_spills_to_sibling() {
        let cfg = FederationConfig {
            leader_outstanding_cap: 1,
            ..small_cfg()
        };
        let mut fed = native_fed(cfg);
        let home = fed.home_leader("tenant-x");
        for seed in 0..3 {
            fed.submit("tenant-x", req(8, seed)).unwrap();
        }
        // One dispatch sweep before anything completes: job 1 goes
        // home, job 2 spills to the sibling, job 3 waits its turn.
        fed.pump();
        assert_eq!(fed.outstanding[home], 1);
        assert_eq!(fed.outstanding[1 - home], 1);
        assert_eq!(fed.pending_total, 1);
        assert_eq!(fed.report().spilled, 1);
        fed.pump_until_idle(SERVE_JOB_DEADLINE).unwrap();
        let done = fed.drain_completions();
        assert_eq!(done.len(), 3);
        assert!(done.iter().all(|c| c.result.is_ok()));
        assert_eq!(done.iter().filter(|c| c.spilled).count(), 1);
        fed.shutdown().unwrap();
    }

    #[test]
    fn killed_leader_rehomes_tenants_to_survivor() {
        let mut fed = native_fed(small_cfg());
        let home = fed.home_leader("victim");
        fed.kill_leader(home).unwrap();
        assert!(fed.kill_leader(home).is_err(), "double kill refused");
        fed.submit("victim", req(6, 7)).unwrap();
        fed.pump_until_idle(SERVE_JOB_DEADLINE).unwrap();
        let done = fed.drain_completions();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].leader, 1 - home, "ran on the survivor");
        assert!(done[0].result.is_ok());
        let report = fed.report();
        assert_eq!(report.rehomed, 1);
        let stats = fed.leader_stats();
        assert!(!stats[home].alive && stats[1 - home].alive);
        assert_eq!(stats[1 - home].completed, 1);
        fed.shutdown().unwrap();
    }

    #[test]
    fn all_leaders_dead_fails_fast() {
        let mut fed = native_fed(small_cfg());
        fed.submit("t", req(4, 1)).unwrap();
        fed.kill_leader(0).unwrap();
        fed.kill_leader(1).unwrap();
        fed.pump();
        let done = fed.drain_completions();
        assert_eq!(done.len(), 1);
        assert!(done[0].result.is_err());
        assert!(matches!(
            fed.submit("t", req(4, 2)),
            Err(Error::Scheduler(_))
        ));
        fed.shutdown().unwrap();
    }
}
