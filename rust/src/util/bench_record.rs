//! `BENCH_*.json` trajectory writer.
//!
//! Every benchmark-bearing surface (`bts exec`, `bts serve`,
//! `cargo bench --bench cache_affinity`, and whatever future PRs add)
//! funnels its flat metrics records through this one writer, so
//! `results/` accumulates a comparable perf trail: one
//! `BENCH_<name>.json` per surface, each a JSON array of flat records
//! in the baseline format `examples/end_to_end.rs` first wrote to
//! `results/exec_baseline.json` (see `ExecResult::metrics_json`).
//!
//! Each record is stamped with a schema version and run metadata
//! (host threads, cargo profile) before it lands on disk, so records
//! from different PRs — and from hosts of different sizes or debug
//! builds — stay comparable across the whole trajectory. Stamping
//! never overwrites a key a record already carries.
//!
//! Schema v3 unifies the row shape across every writer on the
//! `BENCH_suite.json` model: each record carries `surface` (which
//! writer produced it — stamped here from the file name) and `label`
//! (the writer's own discriminator for the row: the suite cell label,
//! a bench's mode/config name, …) alongside its flat counters. Before
//! v3 the discriminator key drifted per writer (`bench`, `mode`,
//! `config`, `segment`); trajectory readers can branch on
//! `schema_version` to handle old rows.

use super::json::{arr, num, s, Json};
use crate::error::Result;

/// Version stamped into every record; bump on incompatible changes to
/// the record shape so trajectory readers can branch on it.
pub const SCHEMA_VERSION: u64 = 3;

/// The run-metadata pairs added to every record.
fn run_meta() -> Vec<(&'static str, Json)> {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let profile = if cfg!(debug_assertions) { "debug" } else { "release" };
    vec![
        ("schema_version", num(SCHEMA_VERSION as f64)),
        ("host_threads", num(threads as f64)),
        ("cargo_profile", s(profile)),
    ]
}

/// Stamp one record with the schema version and run metadata. Only
/// object records are stamped; existing keys always win.
pub fn stamp(record: Json) -> Json {
    match record {
        Json::Obj(mut m) => {
            for (k, v) in run_meta() {
                m.entry(k.to_string()).or_insert(v);
            }
            Json::Obj(m)
        }
        other => other,
    }
}

/// Write `records` to `results/BENCH_<name>.json`; returns the path.
pub fn write(name: &str, records: Vec<Json>) -> Result<String> {
    write_in("results", name, records)
}

/// Same, into an explicit directory (tests point this at a temp dir).
pub fn write_in(
    dir: &str,
    name: &str,
    records: Vec<Json>,
) -> Result<String> {
    std::fs::create_dir_all(dir)?;
    let path = format!("{dir}/BENCH_{name}.json");
    // `surface` is the v3 cross-writer discriminator; like the rest of
    // the stamp, a caller-provided value wins.
    let stamped: Vec<Json> = records
        .into_iter()
        .map(|r| match stamp(r) {
            Json::Obj(mut m) => {
                m.entry("surface".to_string()).or_insert_with(|| s(name));
                Json::Obj(m)
            }
            other => other,
        })
        .collect();
    std::fs::write(&path, arr(stamped).to_string_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;

    #[test]
    fn writes_parseable_record_arrays() {
        let dir = std::env::temp_dir()
            .join("bts_bench_record_test")
            .to_string_lossy()
            .into_owned();
        let path = write_in(
            &dir,
            "selftest",
            vec![
                obj(vec![("total_s", num(1.5))]),
                obj(vec![("total_s", num(2.5))]),
            ],
        )
        .unwrap();
        assert!(path.ends_with("BENCH_selftest.json"));
        let back =
            Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        match back {
            Json::Arr(v) => {
                assert_eq!(v.len(), 2);
                assert!((v[1].req_f64("total_s").unwrap() - 2.5).abs()
                    < 1e-12);
            }
            other => panic!("expected array, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn every_record_is_stamped_with_schema_and_run_meta() {
        let dir = std::env::temp_dir()
            .join("bts_bench_record_stamp_test")
            .to_string_lossy()
            .into_owned();
        let path = write_in(
            &dir,
            "stamped",
            vec![obj(vec![("total_s", num(1.0))])],
        )
        .unwrap();
        let back =
            Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let Json::Arr(v) = back else { panic!("expected array") };
        let r = &v[0];
        assert_eq!(
            r.req_usize("schema_version").unwrap(),
            SCHEMA_VERSION as usize
        );
        assert!(r.req_usize("host_threads").unwrap() >= 1);
        assert_eq!(r.req_str("surface").unwrap(), "stamped");
        let profile = r.req_str("cargo_profile").unwrap();
        assert!(
            profile == "debug" || profile == "release",
            "odd profile {profile}"
        );
        // the original fields survive
        assert!((r.req_f64("total_s").unwrap() - 1.0).abs() < 1e-12);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn stamping_never_overwrites_caller_keys() {
        let r = stamp(obj(vec![("host_threads", num(99.0))]));
        assert_eq!(r.req_usize("host_threads").unwrap(), 99);
        assert_eq!(
            r.req_usize("schema_version").unwrap(),
            SCHEMA_VERSION as usize
        );
        // non-object records pass through untouched
        assert_eq!(stamp(num(7.0)), num(7.0));
    }

    #[test]
    fn caller_surface_beats_the_file_name_stamp() {
        let dir = std::env::temp_dir()
            .join("bts_bench_record_surface_test")
            .to_string_lossy()
            .into_owned();
        let path = write_in(
            &dir,
            "outer",
            vec![obj(vec![("surface", s("inner"))])],
        )
        .unwrap();
        let back =
            Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let Json::Arr(v) = back else { panic!("expected array") };
        assert_eq!(v[0].req_str("surface").unwrap(), "inner");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }
}
