//! `BENCH_*.json` trajectory writer.
//!
//! Every benchmark-bearing surface (`bts exec`, `bts serve`, and
//! whatever future PRs add) funnels its flat metrics records through
//! this one writer, so `results/` accumulates a comparable perf trail:
//! one `BENCH_<name>.json` per surface, each a JSON array of flat
//! records in the baseline format `examples/end_to_end.rs` first wrote
//! to `results/exec_baseline.json` (see `ExecResult::metrics_json`).

use super::json::{arr, Json};
use crate::error::Result;

/// Write `records` to `results/BENCH_<name>.json`; returns the path.
pub fn write(name: &str, records: Vec<Json>) -> Result<String> {
    write_in("results", name, records)
}

/// Same, into an explicit directory (tests point this at a temp dir).
pub fn write_in(
    dir: &str,
    name: &str,
    records: Vec<Json>,
) -> Result<String> {
    std::fs::create_dir_all(dir)?;
    let path = format!("{dir}/BENCH_{name}.json");
    std::fs::write(&path, arr(records).to_string_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj};

    #[test]
    fn writes_parseable_record_arrays() {
        let dir = std::env::temp_dir()
            .join("bts_bench_record_test")
            .to_string_lossy()
            .into_owned();
        let path = write_in(
            &dir,
            "selftest",
            vec![
                obj(vec![("total_s", num(1.5))]),
                obj(vec![("total_s", num(2.5))]),
            ],
        )
        .unwrap();
        assert!(path.ends_with("BENCH_selftest.json"));
        let back =
            Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        match back {
            Json::Arr(v) => {
                assert_eq!(v.len(), 2);
                assert!((v[1].req_f64("total_s").unwrap() - 2.5).abs()
                    < 1e-12);
            }
            other => panic!("expected array, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }
}
