//! Micro/macro bench harness (the offline vendor set has no criterion).
//!
//! `Bench::new("group")` collects named measurements — each timed over
//! warmup + N iterations — and prints a criterion-style table plus an
//! optional CSV (results/<group>.csv). All `cargo bench` targets
//! (rust/benches/*.rs, harness = false) are built on this.

use std::fmt::Write as _;
use std::time::Instant;

use super::stats::{summarize, Summary};

pub struct Bench {
    group: String,
    rows: Vec<(String, Summary, Option<String>)>,
    pub warmup: usize,
    pub iters: usize,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        Bench {
            group: group.to_string(),
            rows: Vec::new(),
            warmup: 2,
            iters: 10,
        }
    }

    pub fn with_iters(mut self, warmup: usize, iters: usize) -> Self {
        self.warmup = warmup;
        self.iters = iters;
        self
    }

    /// Time `f` (seconds per call) over warmup + iters calls.
    pub fn measure<F: FnMut()>(&mut self, name: &str, mut f: F) {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        self.rows.push((name.to_string(), summarize(&samples), None));
    }

    /// Record a precomputed scalar (e.g. a simulated runtime or a model
    /// output) so figure benches can report series, not only wallclock.
    pub fn record(&mut self, name: &str, value: f64, unit: &str) {
        self.rows.push((
            name.to_string(),
            Summary {
                n: 1,
                mean: value,
                std: 0.0,
                min: value,
                p50: value,
                p95: value,
                p99: value,
                max: value,
            },
            Some(unit.to_string()),
        ));
    }

    /// Render the table; also writes results/<group>.csv when possible.
    pub fn finish(self) {
        let mut out = String::new();
        let _ = writeln!(out, "\n== bench group: {} ==", self.group);
        let width = self
            .rows
            .iter()
            .map(|(n, _, _)| n.len())
            .max()
            .unwrap_or(8)
            .max(8);
        let _ = writeln!(
            out,
            "{:width$}  {:>12} {:>12} {:>12} {:>12}  unit",
            "name", "mean", "p50", "p95", "std",
        );
        let mut csv = String::from("name,mean,p50,p95,std,min,max,n,unit\n");
        for (name, s, unit) in &self.rows {
            let unit = unit.as_deref().unwrap_or("s");
            let fmt = |v: f64| {
                if unit == "s" {
                    format_secs(v)
                } else {
                    format!("{v:.4}")
                }
            };
            let _ = writeln!(
                out,
                "{:width$}  {:>12} {:>12} {:>12} {:>12}  {}",
                name,
                fmt(s.mean),
                fmt(s.p50),
                fmt(s.p95),
                fmt(s.std),
                unit,
            );
            let _ = writeln!(
                csv,
                "{name},{},{},{},{},{},{},{},{unit}",
                s.mean, s.p50, s.p95, s.std, s.min, s.max, s.n
            );
        }
        println!("{out}");
        let path = format!("results/{}.csv", self.group.replace(' ', "_"));
        if std::fs::create_dir_all("results").is_ok() {
            let _ = std::fs::write(&path, csv);
        }
    }
}

pub fn format_secs(v: f64) -> String {
    if v >= 1.0 {
        format!("{v:.3} s")
    } else if v >= 1e-3 {
        format!("{:.3} ms", v * 1e3)
    } else if v >= 1e-6 {
        format!("{:.3} µs", v * 1e6)
    } else {
        format!("{:.1} ns", v * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_formats() {
        let mut b = Bench::new("selftest").with_iters(1, 3);
        b.measure("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        b.record("model_output", 42.0, "MB/s");
        assert_eq!(b.rows.len(), 2);
        assert!(b.rows[0].1.mean >= 0.0);
        assert_eq!(b.rows[1].1.mean, 42.0);
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(format_secs(2.5), "2.500 s");
        assert_eq!(format_secs(0.0025), "2.500 ms");
        assert_eq!(format_secs(2.5e-6), "2.500 µs");
        assert!(format_secs(3e-9).ends_with("ns"));
    }
}
