//! Small statistics helpers shared by the feedback loop, metrics, and the
//! bench harness: EWMA, online mean/variance, and fixed-sample summaries.

/// Exponentially-weighted moving average (the scheduler feedback signal).
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    pub fn observe(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }
}

/// Welford online mean/variance.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn observe(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Summary of a fixed sample (used by the bench harness and reports).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    let mean = v.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        v.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let q = |p: f64| -> f64 {
        let idx = (p * (n as f64 - 1.0)).round() as usize;
        v[idx.min(n - 1)]
    };
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: v[0],
        p50: q(0.50),
        p95: q(0.95),
        p99: q(0.99),
        max: v[n - 1],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert!(e.get().is_none());
        for _ in 0..64 {
            e.observe(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_tracks_change() {
        let mut e = Ewma::new(0.5);
        e.observe(0.0);
        e.observe(10.0);
        assert!((e.get().unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn online_matches_closed_form() {
        let mut o = Online::new();
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        for &x in &xs {
            o.observe(x);
        }
        assert_eq!(o.count(), 5);
        assert!((o.mean() - 3.0).abs() < 1e-12);
        assert!((o.var() - 2.5).abs() < 1e-12);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 5.0);
    }

    #[test]
    fn summary_quantiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p95 - 95.0).abs() <= 1.0);
        assert!((s.p99 - 99.0).abs() <= 1.0);
        assert!(s.p99 >= s.p95);
    }
}
