//! Minimal JSON parser/writer (the offline vendor set has no serde_json).
//!
//! Supports the full JSON value grammar; used to read
//! `artifacts/manifest.json`, to read/write config files, and by the
//! net/ protocol. Not performance-critical — everything on the request
//! path marshals raw tensors, not JSON.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    /// Byte offset of a parse error; [`JsonError::NO_POS`] for schema
    /// (required-field) errors that have no source position.
    pub pos: usize,
}

impl JsonError {
    pub const NO_POS: usize = usize::MAX;

    /// A positionless schema error (missing/ill-typed field).
    pub fn schema(msg: String) -> JsonError {
        JsonError { msg, pos: Self::NO_POS }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.pos == Self::NO_POS {
            write!(f, "json error: {}", self.msg)
        } else {
            write!(f, "json error at byte {}: {}", self.pos, self.msg)
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers that produce readable errors. They return
    /// positionless [`JsonError`]s (`pos` is [`JsonError::NO_POS`] —
    /// schema violations have no byte offset), which convert into
    /// `bts::Error::Json` at `?` sites.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError::schema(format!("missing json field `{key}`")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| JsonError::schema(format!("field `{key}` is not a string")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?
            .as_f64()
            .map(|n| n as usize)
            .ok_or_else(|| JsonError::schema(format!("field `{key}` is not a number")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| JsonError::schema(format!("field `{key}` is not a number")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| JsonError::schema(format!("field `{key}` is not an array")))
    }

    // -- writer -----------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    x.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 code point
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len()
                        && (self.b[self.i] & 0xC0) == 0x80
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

/// Convenience constructors for writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"c"}],"d":null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "c"
        );
    }

    #[test]
    fn round_trips() {
        let src = r#"{"name":"eaglet_map_b4","shape":[4,64,8],"ok":true,"f":0.15}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""A\téß""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "A\té\u{df}");
    }

    #[test]
    fn pretty_print_parses_back() {
        let j = obj(vec![
            ("x", num(1.0)),
            ("y", arr(vec![s("a"), Json::Bool(false)])),
        ]);
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
    }
}
