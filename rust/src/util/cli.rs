//! Strict command-line flag parsing, shared by the `bts` binary and
//! the examples so every surface honours one contract: flags accept
//! both `--name value` and `--name=value`, unknown flags and stray
//! positional arguments are errors (never silence), and repeated
//! flags keep every occurrence.

use crate::error::{Error, Result};

/// Parsed flags. `get` returns the last occurrence (override
/// semantics); `get_all` yields every one (repeatable flags like
/// `--set`).
pub struct Flags {
    vals: Vec<(String, String)>,
}

impl Flags {
    pub fn parse(args: &[String], allowed: &[&str]) -> Result<Flags> {
        let expected = || {
            if allowed.is_empty() {
                "this command takes no flags".to_string()
            } else {
                format!("expected one of {}", allowed.join(", "))
            }
        };
        let mut vals = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if !a.starts_with("--") {
                return Err(Error::Config(format!(
                    "unexpected argument {a}; {}",
                    expected()
                )));
            }
            let (name, inline) = match a.split_once('=') {
                Some((n, v)) => (n.to_string(), Some(v.to_string())),
                None => (a.clone(), None),
            };
            if !allowed.contains(&name.as_str()) {
                return Err(Error::Config(format!(
                    "unknown flag {name}; {}",
                    expected()
                )));
            }
            let value = match inline {
                Some(v) => v,
                None => {
                    i += 1;
                    let v = args.get(i).cloned().ok_or_else(|| {
                        Error::Config(format!("flag {name} needs a value"))
                    })?;
                    // `--workers --workload x` is a dropped value, not
                    // a value that happens to start with `--`; demand
                    // the inline form for flag-like values.
                    if v.starts_with("--") {
                        return Err(Error::Config(format!(
                            "flag {name} needs a value, got {v}; use \
                             {name}=VALUE if the value starts with --"
                        )));
                    }
                    v
                }
            };
            vals.push((name, value));
            i += 1;
        }
        Ok(Flags { vals })
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.vals
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn get_all<'a>(
        &'a self,
        name: &'a str,
    ) -> impl Iterator<Item = &'a str> {
        self.vals
            .iter()
            .filter(move |(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    /// Parse `name` as `T`, falling back to `default` when absent.
    pub fn num<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("bad {name} value {v}"))),
        }
    }

    /// Every occurrence of `name`, each further split on commas — the
    /// grid-spec form (`--only fig4,fig7 --only tab1` →
    /// `[fig4, fig7, tab1]`). An empty item (empty value, leading /
    /// trailing / doubled comma) is an error, never a silent skip.
    pub fn list(&self, name: &str) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for occ in self.get_all(name) {
            for item in occ.split(',') {
                let item = item.trim();
                if item.is_empty() {
                    return Err(Error::Config(format!(
                        "flag {name} has an empty item in {occ:?}; want \
                         comma-separated non-empty values"
                    )));
                }
                out.push(item.to_string());
            }
        }
        Ok(out)
    }

    /// Like [`Flags::num`], with an inclusive lower bound: the shared
    /// validator for count-like knobs where zero or negative values
    /// are configuration mistakes, not requests.
    pub fn num_at_least<T>(&self, name: &str, default: T, min: T) -> Result<T>
    where
        T: std::str::FromStr + PartialOrd + std::fmt::Display,
    {
        let v = self.num(name, default)?;
        if v < min {
            return Err(Error::Config(format!(
                "bad {name} value {v}; want at least {min}"
            )));
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn flags_accept_both_spellings() {
        let f = Flags::parse(
            &argv(&["--workers", "8", "--workload=netflix_hi"]),
            &["--workers", "--workload"],
        )
        .unwrap();
        assert_eq!(f.get("--workers"), Some("8"));
        assert_eq!(f.get("--workload"), Some("netflix_hi"));
        assert_eq!(f.num::<usize>("--workers", 1).unwrap(), 8);
        assert_eq!(f.num::<usize>("--missing", 7).unwrap(), 7);
    }

    #[test]
    fn unknown_flags_are_errors_not_silence() {
        let err =
            Flags::parse(&argv(&["--wrokers", "8"]), &["--workers"])
                .unwrap_err();
        assert!(err.to_string().contains("--wrokers"));
        let err =
            Flags::parse(&argv(&["stray"]), &["--workers"]).unwrap_err();
        assert!(err.to_string().contains("stray"));
        let err = Flags::parse(&argv(&["--any"]), &[]).unwrap_err();
        assert!(err.to_string().contains("takes no flags"));
    }

    #[test]
    fn missing_and_malformed_values_are_errors() {
        let err = Flags::parse(&argv(&["--workers"]), &["--workers"])
            .unwrap_err();
        assert!(err.to_string().contains("needs a value"));
        let f = Flags::parse(&argv(&["--workers", "many"]), &["--workers"])
            .unwrap();
        assert!(f.num::<usize>("--workers", 1).is_err());
    }

    #[test]
    fn space_form_never_swallows_a_following_flag() {
        // `--workers --workload x` is a user who dropped a value, not
        // a value of "--workload"
        let err = Flags::parse(
            &argv(&["--workers", "--workload", "eaglet"]),
            &["--workers", "--workload"],
        )
        .unwrap_err();
        assert!(err.to_string().contains("--workers needs a value"));
        // the inline form still accepts flag-like values
        let f = Flags::parse(&argv(&["--set=--weird"]), &["--set"]).unwrap();
        assert_eq!(f.get("--set"), Some("--weird"));
        // negative numbers are plain values in either form
        let f = Flags::parse(&argv(&["--delta", "-3"]), &["--delta"])
            .unwrap();
        assert_eq!(f.num::<i64>("--delta", 0).unwrap(), -3);
    }

    #[test]
    fn both_spellings_mix_and_last_occurrence_wins() {
        let f = Flags::parse(
            &argv(&["--workers", "2", "--workers=8"]),
            &["--workers"],
        )
        .unwrap();
        assert_eq!(f.num::<usize>("--workers", 0).unwrap(), 8);
        // an unknown flag errors in the space-separated form too
        let err = Flags::parse(
            &argv(&["--wrokers", "8"]),
            &["--workers", "--delta"],
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown flag --wrokers"));
    }

    #[test]
    fn repeated_flags_keep_every_occurrence() {
        let f = Flags::parse(
            &argv(&["--set", "a=1", "--set=b=2"]),
            &["--set"],
        )
        .unwrap();
        let all: Vec<&str> = f.get_all("--set").collect();
        assert_eq!(all, vec!["a=1", "b=2"]);
        // get() returns the last occurrence (override semantics)
        assert_eq!(f.get("--set"), Some("b=2"));
    }
}
