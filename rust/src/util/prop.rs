//! Tiny property-testing harness (the offline vendor set has no proptest).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` independent
//! seeded RNGs; on failure it retries the failing seed with a verbose
//! message so the case reproduces exactly. Coordinator invariants
//! (packing conservation, ring balance, scheduler no-double-assign, ...)
//! are tested through this in module tests and rust/tests/prop_invariants.rs.

use super::rng::Rng;

/// Run `f` for `cases` generated cases. `f` returns Err(msg) to fail.
/// Panics with the seed on the first failure.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Base seed is fixed for reproducibility; PROP_SEED overrides to
    // re-run one failing case (PROP_SEED=<n>).
    let (lo, hi) = match std::env::var("PROP_SEED") {
        Ok(s) => {
            let n: u64 = s.parse().expect("PROP_SEED must be u64");
            (n, n + 1)
        }
        Err(_) => (0, cases),
    };
    for case in lo..hi {
        let seed = 0x5eed_0000_0000_0000u64 ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property `{name}` failed on case {case} \
                 (re-run with PROP_SEED={case}): {msg}"
            );
        }
    }
}

/// Assert helper producing Result<(), String> for use inside `check`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 below is bounded", 100, |rng| {
            let n = rng.range(1, 1000);
            let x = rng.below(n);
            if x < n {
                Ok(())
            } else {
                Err(format!("{x} >= {n}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn reports_failures() {
        check("always fails", 3, |_| Err("nope".into()));
    }
}
