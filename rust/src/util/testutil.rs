//! Deterministic test turbulence and shared test-timing policy.
//!
//! Two things live here, both consumed by integration tests and
//! benches (and therefore compiled into the library rather than
//! `rust/tests/common`, so `cargo bench` targets can reach them too):
//!
//! * [`Turbulence`] — a seedable, deterministic latency/fault injector
//!   pluggable into in-proc worker links via
//!   [`crate::transport::BodyCfg::turbulence`]. Scheduler tests script
//!   scenarios like "worker 2 is 10× slow from its 40th task" with
//!   millisecond-scale absolute delays, so straggler behaviour is
//!   real wall-clock without real sleeps dominating CI time. The
//!   injected delay happens *outside* the worker's own fetch/exec
//!   timers on purpose: it models externally-visible slowness (node
//!   contention, a sick NIC) that self-reported timings miss — exactly
//!   what the response-time tracker exists to catch.
//! * A shared wait bound — the serve-layer test/bench surfaces used
//!   to wait unboundedly on job handles; [`SERVE_JOB_DEADLINE`] (via
//!   `JobHandle::wait_timeout` and the load harness) replaces that
//!   with one bounded policy, so a hung dispatcher fails fast with a
//!   message instead of wedging the whole suite.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::util::rng::fnv1a;

/// Upper bound for any single serve-layer job (or whole small session)
/// in tests and benches — generous for debug-build CI, but bounded.
pub const SERVE_JOB_DEADLINE: Duration = Duration::from_secs(120);

/// One scripted slowdown: from its `from_task`-th task onward (0-based,
/// counted per worker), `worker` takes an extra `delay` per task.
#[derive(Debug, Clone, Copy)]
struct SlowRule {
    worker: usize,
    from_task: u64,
    delay: Duration,
}

/// One scripted fault: `worker`'s `at_task`-th task fails.
#[derive(Debug, Clone, Copy)]
struct FaultRule {
    worker: usize,
    at_task: u64,
}

/// One scripted crash: `worker` dies (unclean exit, no goodbye) at its
/// `at_task`-th task. Fires **once** per process: a restart-based
/// recovery attempt re-runs the same worker indices through the same
/// schedule, and a kill that re-fired forever would make the restart
/// baseline unfinishable.
#[derive(Debug)]
struct KillRule {
    worker: usize,
    at_task: u64,
    fired: AtomicBool,
}

impl Clone for KillRule {
    fn clone(&self) -> Self {
        KillRule {
            worker: self.worker,
            at_task: self.at_task,
            fired: AtomicBool::new(self.fired.load(Ordering::SeqCst)),
        }
    }
}

/// What the injector decided for one task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Disturbance {
    /// Extra wall-clock delay to impose before executing the task.
    pub delay: Duration,
    /// Report the task as failed instead of executing it.
    pub fail: bool,
    /// Crash the worker (unclean exit) instead of executing it.
    pub kill: bool,
}

/// See module docs. Build one, wrap it in an `Arc`, and hand it to
/// [`crate::exec::ExecConfig`] / [`crate::serve::PoolConfig`] (or a
/// raw [`crate::transport::BodyCfg`]); every decision is a pure
/// function of `(seed, worker, nth-task-on-that-worker)`, so reruns
/// and recovery attempts see identical turbulence.
#[derive(Debug, Default, Clone)]
pub struct Turbulence {
    seed: u64,
    slow: Vec<SlowRule>,
    faults: Vec<FaultRule>,
    kills: Vec<KillRule>,
    jitter_max: Duration,
}

impl Turbulence {
    pub fn new(seed: u64) -> Turbulence {
        Turbulence { seed, ..Default::default() }
    }

    /// From its `from_task`-th task onward, `worker` takes an extra
    /// `delay` per task.
    pub fn slow_from(
        mut self,
        worker: usize,
        from_task: u64,
        delay: Duration,
    ) -> Turbulence {
        self.slow.push(SlowRule { worker, from_task, delay });
        self
    }

    /// `worker`'s `at_task`-th task (0-based) fails.
    pub fn fail_at(mut self, worker: usize, at_task: u64) -> Turbulence {
        self.faults.push(FaultRule { worker, at_task });
        self
    }

    /// `worker` crashes (unclean exit, as if the process died) when it
    /// reaches its `at_task`-th task (0-based). Fires once: clones made
    /// *before* the kill fires share the armed state, so a restart
    /// attempt driven by the same `Arc<Turbulence>` runs clean.
    pub fn kill_at(mut self, worker: usize, at_task: u64) -> Turbulence {
        self.kills.push(KillRule {
            worker,
            at_task,
            fired: AtomicBool::new(false),
        });
        self
    }

    /// Add a seeded per-task jitter in `[0, max)` on top of every
    /// scripted delay (deterministic in `(seed, worker, task)`).
    pub fn with_jitter(mut self, max: Duration) -> Turbulence {
        self.jitter_max = max;
        self
    }

    /// The disturbance for `worker`'s `nth` task (0-based per-worker
    /// execution count).
    pub fn disturbance(&self, worker: usize, nth: u64) -> Disturbance {
        let mut delay = Duration::ZERO;
        for r in &self.slow {
            if r.worker == worker && nth >= r.from_task {
                delay += r.delay;
            }
        }
        if !self.jitter_max.is_zero() && delay > Duration::ZERO {
            let key = format!("{}:{worker}:{nth}", self.seed);
            let h = fnv1a(key.as_bytes());
            let frac = (h % 1024) as f64 / 1024.0;
            delay += Duration::from_secs_f64(
                self.jitter_max.as_secs_f64() * frac,
            );
        }
        let fail = self
            .faults
            .iter()
            .any(|f| f.worker == worker && f.at_task == nth);
        let kill = self.kills.iter().any(|k| {
            k.worker == worker
                && nth >= k.at_task
                && !k.fired.swap(true, Ordering::SeqCst)
        });
        Disturbance { delay, fail, kill }
    }

    /// Whether any rule targets `worker` at all (cheap pre-check).
    pub fn touches(&self, worker: usize) -> bool {
        self.slow.iter().any(|r| r.worker == worker)
            || self.faults.iter().any(|f| f.worker == worker)
            || self.kills.iter().any(|k| k.worker == worker)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn turbulence_is_deterministic_and_scoped() {
        let t = Turbulence::new(7)
            .slow_from(2, 40, Duration::from_millis(10))
            .with_jitter(Duration::from_millis(1));
        // untouched workers and early tasks are undisturbed
        assert_eq!(
            t.disturbance(0, 100),
            Disturbance { delay: Duration::ZERO, fail: false, kill: false }
        );
        assert_eq!(t.disturbance(2, 39).delay, Duration::ZERO);
        // from task 40, worker 2 is slow — and identically so on replay
        let a = t.disturbance(2, 40);
        let b = t.disturbance(2, 40);
        assert_eq!(a, b);
        assert!(a.delay >= Duration::from_millis(10));
        assert!(a.delay < Duration::from_millis(11));
        assert!(t.touches(2) && !t.touches(0));
    }

    #[test]
    fn faults_hit_exactly_their_task() {
        let t = Turbulence::new(1).fail_at(1, 3);
        assert!(!t.disturbance(1, 2).fail);
        assert!(t.disturbance(1, 3).fail);
        assert!(!t.disturbance(1, 4).fail);
        assert!(!t.disturbance(0, 3).fail);
    }

    #[test]
    fn kills_fire_once_from_their_task() {
        let t = Turbulence::new(1).kill_at(1, 2);
        assert!(t.touches(1) && !t.touches(0));
        assert!(!t.disturbance(1, 1).kill);
        assert!(!t.disturbance(0, 2).kill);
        // fires at (or after) its task — then never again, even on the
        // same (worker, nth): a restarted worker 1 replays clean.
        assert!(t.disturbance(1, 2).kill);
        assert!(!t.disturbance(1, 2).kill);
        assert!(!t.disturbance(1, 3).kill);
    }
}
