//! Deterministic PRNG (xoshiro256++) + distribution helpers.
//!
//! The offline vendor set has no `rand` crate, so we carry our own small,
//! well-known generator. Every stochastic component in the platform
//! (data generators, subsample index draws, failure injection, the
//! two-step scheduler's probe assignment) takes an explicit seed so whole
//! jobs — and whole experiments — replay bit-identically, which is what
//! makes job-level recovery testable (restart ⇒ same answer).

/// SplitMix64: seeds xoshiro and doubles as a cheap hash.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Hash a byte string to u64 (FNV-1a); used by the consistent-hash ring.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// SplitMix64-style avalanche finisher: fnv1a mixes short, similar
/// strings poorly in the high bits, so hash consumers that shard or
/// order by them (the dfs ring, the block cache) finish with this.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    /// Derive an independent stream (for per-worker / per-task RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Pareto with scale 1, shape alpha (heavy tail; smaller alpha = heavier).
    pub fn pareto(&mut self, alpha: f64) -> f64 {
        let u = 1.0 - self.f64();
        u.powf(-1.0 / alpha)
    }

    /// Exponential with rate lambda.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.f64();
        -u.ln() / lambda
    }

    /// Sample k distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: u64, k: u64) -> Vec<u64> {
        debug_assert!(k <= n);
        let mut chosen = Vec::with_capacity(k as usize);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut ss) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            ss += x * x;
        }
        let mean = s / n as f64;
        let var = ss / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn pareto_is_heavy_tailed() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..50_000).map(|_| r.pareto(1.5)).collect();
        let max = xs.iter().cloned().fold(0.0, f64::max);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!(max > 20.0 * mean, "max {max} mean {mean}");
        assert!(xs.iter().all(|&x| x >= 1.0));
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(17);
        for _ in 0..200 {
            let n = r.range(1, 64);
            let k = r.below(n + 1);
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k as usize);
            let mut uniq = s.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), s.len(), "duplicates in {s:?}");
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<u32>>());
    }
}
