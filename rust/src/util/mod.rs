//! Shared utilities: deterministic RNG, minimal JSON, stats, and the
//! bench/property harnesses that stand in for criterion/proptest in this
//! offline build (see DESIGN.md §2).

#[cfg(feature = "alloc-count")]
pub mod alloc_counter;
pub mod bench;
pub mod bench_record;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod testutil;

/// Render an aligned text table (used by the figures harness).
pub fn render_table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("\n## {title}\n"));
    let line = |cells: &[String]| -> String {
        let mut s = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:w$} | ", c, w = widths[i]));
        }
        s.trim_end().to_string() + "\n"
    };
    out.push_str(&line(
        &header.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
    ));
    out.push_str(&line(
        &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>(),
    ));
    for row in rows {
        out.push_str(&line(row));
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn table_renders_aligned() {
        let t = super::render_table(
            "T",
            &["a", "long_header"],
            &[vec!["1".into(), "2".into()]],
        );
        assert!(t.contains("## T"));
        assert!(t.contains("long_header"));
        assert!(t.lines().count() >= 4);
    }
}
