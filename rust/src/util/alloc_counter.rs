//! Opt-in counting global allocator (`--features alloc-count`).
//!
//! Wraps [`std::alloc::System`] and counts every allocation and
//! reallocation on a per-thread tally. The transport bench and the
//! zero-copy integration test install it as the `#[global_allocator]`
//! to assert the hot paths' allocation contracts — most importantly
//! that a warm cache-hit block fetch performs **zero** heap
//! allocations (intrusive-LRU touch + `Arc` clone only).
//!
//! The counter is thread-local so a measurement window on one thread
//! is not polluted by background pumps allocating on others. Frees are
//! not counted: the contract under test is "does this path allocate",
//! not "is it leak-free".
//!
//! Usage (in a bench or test binary):
//!
//! ```ignore
//! #[cfg(feature = "alloc-count")]
//! #[global_allocator]
//! static ALLOC: bts::util::alloc_counter::CountingAlloc =
//!     bts::util::alloc_counter::CountingAlloc;
//!
//! alloc_counter::reset();
//! let hit = cache.get("key");          // warm hit
//! assert_eq!(alloc_counter::allocations(), 0);
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCATIONS: Cell<u64> = const { Cell::new(0) };
}

/// Counting wrapper over the system allocator. Zero-sized; install as
/// `#[global_allocator]` in the binary that wants the tally.
pub struct CountingAlloc;

// SAFETY: pure delegation to `System`; the thread-local bump cannot
// itself allocate (Cell<u64> is plain data in TLS).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.with(|n| n.set(n.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.with(|n| n.set(n.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.with(|n| n.set(n.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

/// Allocations observed on this thread since the last [`reset`].
pub fn allocations() -> u64 {
    ALLOCATIONS.with(|n| n.get())
}

/// Zero this thread's allocation tally (start of a measurement window).
pub fn reset() {
    ALLOCATIONS.with(|n| n.set(0));
}

#[cfg(test)]
mod tests {
    // The counter only observes traffic when CountingAlloc is the
    // installed global allocator, which unit tests (library cdylib)
    // cannot do — the integration test and bench own that. Here we
    // just exercise the tally plumbing directly.
    use super::{allocations, reset, ALLOCATIONS};

    #[test]
    fn tally_is_thread_local_and_resettable() {
        reset();
        ALLOCATIONS.with(|n| n.set(n.get() + 3));
        assert_eq!(allocations(), 3);
        let other = std::thread::spawn(allocations).join().unwrap();
        assert_eq!(other, 0, "tally must not leak across threads");
        reset();
        assert_eq!(allocations(), 0);
    }
}
