//! The pluggable transport spine: one control-plane message grammar,
//! one worker body, two transports (DESIGN.md §11).
//!
//! Before this module, the platform had two execution paths: the real
//! one (`exec::cluster` + `serve` over in-process mpsc channels, with
//! the two-step scheduler, DFS, prefetching, cache and recovery) and a
//! feature-poor TCP path (`net::serve_job`) that shipped data inline
//! and bypassed all of it. The thesis's central trade — task-creation
//! and data-distribution overhead vs cache-miss savings — was only
//! measurable on the channel half. This module collapses both paths
//! into one spine over a swappable transport:
//!
//! * **Control plane** — [`Down`] (leader → worker: tasks, aborts,
//!   shutdown) and [`Up`] (worker → leader: completions, failures,
//!   abort acks, exit). The leader holds one [`link::WorkerLink`] per
//!   map slot; in-proc links are mpsc senders to a worker thread, TCP
//!   links write frames ([`crate::net::Message`]) to a socket whose
//!   read side is pumped back into the same shared `mpsc::Sender<Up>`
//!   the in-proc workers use — above the links, the leader cannot
//!   tell the transports apart.
//! * **Data plane** — workers fetch blocks through
//!   [`crate::dfs::BlockSource`]: in-proc workers hold the replicated
//!   [`crate::dfs::Dfs`] directly; remote workers hold a
//!   [`remote::RemoteDfs`] that proxies Get/Put over the same socket
//!   (served by the leader's pump from the real store, so remote
//!   fetches still go through response-time-aware replica selection
//!   and the shared block cache) with an optional worker-local
//!   [`crate::cache::BlockCache`] in front.
//! * **One worker body** — [`worker_body`] is the drain → wait →
//!   execute → report loop every map slot runs: solo `exec` worker
//!   threads, warm `serve` pool workers, and `bts worker --connect`
//!   processes. TCP workers get the two-step scheduler's probe/
//!   feedback batches, prefetching, per-task metrics, and job-level
//!   recovery for free, because those all live above (or below) this
//!   loop, not inside the transport.
//!
//! **Determinism across transports**: a job's output is a function of
//! its per-task seeds and the seq-ordered reduce, never of which
//! worker ran a task, in what order tasks finished, or how their
//! bytes travelled. Partials cross the wire as exact little-endian
//! `f32` bits, so an in-proc run and a loopback-TCP run of the same
//! seed produce bit-identical [`crate::coordinator::JobOutput`]s —
//! `rust/tests/integration_transport.rs` holds that contract.

pub mod link;
pub mod remote;

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;

use crate::cache::AffinityIndex;
use crate::coordinator::assemble::{execute_slices, MapTask, TaskPartial};
use crate::coordinator::recovery::FailurePlan;
use crate::data::block::Block;
use crate::data::{ModelParams, Workload};
use crate::dfs::{BlockSource, Prefetcher};
use crate::error::{Error, Result};
use crate::exec::Backend;
use crate::metrics::Timer;
use crate::scheduler::TaskSpec;
use crate::util::testutil::Turbulence;

pub use link::{accept_links, teardown, PumpCfg, RemoteWorkers, WorkerLink};
pub use remote::{run_remote_worker, RemoteWorkerOpts};

/// One task routed to a map slot, tagged with its tenant. `ns`
/// prefixes every block key (`""` for solo runs); `attempt` lets the
/// leader discard results that straggle in after a job restart;
/// `poison` is the serve layer's injected task fault.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskEnvelope {
    pub job: u64,
    pub attempt: u32,
    pub ns: Arc<str>,
    pub spec: TaskSpec,
    pub poison: bool,
}

/// One reduce partition assignment (the shuffle's receiving end). The
/// worker streams partition `partition`'s fragment of every map task
/// (`seq 0..n_tasks`, staged by the leader under
/// [`crate::reduce::shuffle_key`]s) through its prefetcher and runs
/// the seq-ordered reduce tree over them.
#[derive(Debug, Clone, PartialEq)]
pub struct ReduceSpec {
    pub partition: u32,
    pub partitions: u32,
    /// Map tasks whose fragments to fetch (one shuffle block each).
    pub n_tasks: u32,
    pub workload: Workload,
    /// Reduce keys this partition owns (ascending; informational —
    /// fragments carry their keys inline).
    pub keys: Vec<u32>,
}

/// A reduce task routed to a slot, tagged with its tenant — the
/// reduce-phase sibling of [`TaskEnvelope`].
#[derive(Debug, Clone, PartialEq)]
pub struct ReduceEnvelope {
    pub job: u64,
    pub attempt: u32,
    pub ns: Arc<str>,
    pub spec: ReduceSpec,
}

/// Leader → worker control messages, over any transport.
#[derive(Debug, Clone, PartialEq)]
pub enum Down {
    Task(Box<TaskEnvelope>),
    /// One scheduler refill window's worth of tasks, dispatched as a
    /// single message (one frame over TCP, one mpsc send in-proc).
    /// Semantically identical to the same envelopes sent as
    /// individual [`Down::Task`]s in order — batching is a transport
    /// optimization, never a scheduling decision.
    TaskBatch(Vec<TaskEnvelope>),
    /// A reduce partition to fetch, merge and report. Map and reduce
    /// tasks share the slot: the worker drains its map queue first.
    Reduce(Box<ReduceEnvelope>),
    /// Drop every queued task of `job` with attempt ≤ `upto_attempt`
    /// and purge the job's namespace from worker-local caches. The
    /// worker acknowledges with [`Up::Aborted`].
    Abort { job: u64, upto_attempt: u32 },
    /// Graceful leave (elastic membership): finish the in-flight task,
    /// return every queued task to the leader via [`Up::Drained`], and
    /// exit cleanly. Messages are handled between tasks, so the task
    /// under execution always completes and reports first.
    Drain,
    Shutdown,
}

/// One finished task, reported up the shuffle path. Prefetch and
/// cache counters are per-task deltas, so an accumulator can
/// attribute them to the right job even when one worker serves many
/// jobs.
#[derive(Debug, Clone)]
pub struct TaskDone {
    pub worker: usize,
    pub seq: usize,
    pub partial: TaskPartial,
    pub fetch_s: f64,
    pub exec_s: f64,
    /// Seconds the worker sat idle waiting for this task to arrive.
    pub queue_wait_s: f64,
    pub prefetch_hits: u64,
    pub prefetch_misses: u64,
    /// Shared/worker-local block-cache outcomes for this task's
    /// fetches (zero when no cache is attached anywhere).
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// One finished reduce partition, reported up. The partial's owned
/// lanes are bit-identical to the single-reducer tree; `shuffle_bytes`
/// is what this reducer actually pulled over the data plane.
#[derive(Debug, Clone)]
pub struct ReduceDone {
    pub worker: usize,
    pub partition: u32,
    pub partial: TaskPartial,
    pub fetch_s: f64,
    pub exec_s: f64,
    pub queue_wait_s: f64,
    pub shuffle_bytes: u64,
}

/// One completed task inside an [`Up::DoneBatch`] — the fields of
/// [`Up::Done`] flattened so a batch is a plain vector.
#[derive(Debug, Clone)]
pub struct DoneItem {
    pub job: u64,
    pub attempt: u32,
    pub done: TaskDone,
}

/// Worker → leader control messages, over any transport.
#[derive(Debug)]
pub enum Up {
    Done { job: u64, attempt: u32, done: Box<TaskDone> },
    /// Several completions coalesced into one message by the worker's
    /// ack batcher. Ordering contract: a worker flushes its pending
    /// batch before sending *any* other `Up`, so the leader's FIFO
    /// assumptions (every `Done` precedes the slot's `Drained` /
    /// `Exited`) hold exactly as they do for singles.
    DoneBatch(Vec<DoneItem>),
    /// A reduce partition completed (first report per partition wins;
    /// duplicates from speculative clones are dropped by the leader).
    ReduceDone { job: u64, attempt: u32, done: Box<ReduceDone> },
    /// One task of `(job, attempt)` failed. Solo runs treat this as
    /// fatal to the attempt; the serve dispatcher restarts just that
    /// tenant's job.
    TaskFailed { job: u64, attempt: u32, worker: usize, error: Error },
    /// Ack for [`Down::Abort`]: `dropped` queued tasks discarded.
    Aborted { worker: usize, dropped: u64 },
    /// Ack for [`Down::Drain`]: the slot returned `returned` queued
    /// (never-started) tasks and is about to exit cleanly. Link FIFO
    /// ordering guarantees every `Done` the slot produced has already
    /// arrived when the leader reads this, so requeueing the slot's
    /// in-flight window re-dispatches exactly the unfinished work.
    Drained { worker: usize, returned: u64 },
    /// Transport-level loss: the worker's link died without an
    /// orderly `Exited` (TCP reset, EOF mid-job, protocol error).
    /// Synthesized by the leader-side pump, never sent by a worker.
    Lost { worker: usize, error: Error },
    Exited { worker: usize, executed: u64, clean: bool },
}

/// Non-blocking receive outcome for a worker's control channel.
pub enum Poll {
    Msg(Down),
    Empty,
    Closed,
}

/// The worker's end of a transport: receive [`Down`]s, send [`Up`]s.
/// In-proc this is an mpsc pair; over TCP the receive side is fed by
/// a socket-reader thread and sends are framed writes.
pub trait WorkerChannel {
    fn try_recv(&mut self) -> Poll;
    /// Blocking receive; `None` means the link is gone.
    fn recv(&mut self) -> Option<Down>;
    /// `false` means the link is gone (the worker should wind down).
    fn send(&mut self, up: Up) -> bool;
}

/// The in-process channel: what `exec` worker threads and the serve
/// pool's warm workers run over.
pub struct InProcChannel {
    pub rx: mpsc::Receiver<Down>,
    pub tx: mpsc::Sender<Up>,
}

impl WorkerChannel for InProcChannel {
    fn try_recv(&mut self) -> Poll {
        match self.rx.try_recv() {
            Ok(d) => Poll::Msg(d),
            Err(mpsc::TryRecvError::Empty) => Poll::Empty,
            Err(mpsc::TryRecvError::Disconnected) => Poll::Closed,
        }
    }

    fn recv(&mut self) -> Option<Down> {
        self.rx.recv().ok()
    }

    fn send(&mut self, up: Up) -> bool {
        self.tx.send(up).is_ok()
    }
}

/// Per-slot knobs for [`worker_body`] — the superset of what the solo
/// executor, the warm pool, and a remote worker process need.
#[derive(Clone)]
pub struct BodyCfg {
    pub worker: usize,
    /// Upper bound on the prefetch depth k.
    pub prefetch_k: usize,
    /// Solo-run injected failure: report a fatal task failure and die
    /// after `after_tasks` completions on `on_attempt`.
    pub failure: Option<FailurePlan>,
    /// Pool semantics: report task errors ([`Up::TaskFailed`]) and
    /// keep serving — one tenant's bad job must not take this map
    /// slot away from the others. Solo semantics (`false`): a task
    /// error is fatal and the worker exits uncleanly.
    pub survive_task_errors: bool,
    /// Shared affinity registry (cache-affinity dispatch), if enabled.
    /// In-proc only: remote workers cannot reach the leader's
    /// registry, so their fetches simply go unrecorded.
    pub affinity: Option<Arc<AffinityIndex>>,
    /// Deterministic latency/fault injection for this slot
    /// ([`crate::util::testutil::Turbulence`]): scheduler tests and
    /// the straggler bench script "worker N is slow from task M"
    /// without bespoke worker bodies. The injected delay lands
    /// *outside* the task's own fetch/exec timers — externally-visible
    /// slowness the response-time tracker must catch on its own.
    pub turbulence: Option<Arc<Turbulence>>,
}

impl BodyCfg {
    /// Defaults for map slot `worker`: pool semantics, no injected
    /// failure, no affinity recording, no turbulence.
    pub fn new(worker: usize) -> BodyCfg {
        BodyCfg {
            worker,
            prefetch_k: 8,
            failure: None,
            survive_task_errors: true,
            affinity: None,
            turbulence: None,
        }
    }
}

/// Queue a task's block keys (under its namespace) for prefetch, in
/// task order.
pub(crate) fn enqueue_keys(pf: &mut Prefetcher, spec: &TaskSpec, ns: &str) {
    pf.enqueue(
        spec.task
            .sample_ids
            .iter()
            .map(|&id| crate::data::block::block_key(ns, spec.workload, id)),
    );
}

/// Queue a reduce partition's shuffle-block keys for prefetch, in
/// map-task (`seq`) order.
pub(crate) fn enqueue_reduce_keys(
    pf: &mut Prefetcher,
    spec: &ReduceSpec,
    ns: &str,
) {
    pf.enqueue((0..spec.n_tasks as usize).map(|seq| {
        crate::reduce::shuffle_key(ns, spec.partition, seq)
    }));
}

/// Fetch this partition's fragment of every map task, decode, and run
/// the seq-ordered reduce tree; returns (partial, fetch seconds, exec
/// seconds, shuffle bytes fetched).
pub(crate) fn run_reduce_task(
    p: &ModelParams,
    backend: &Backend,
    pf: &mut Prefetcher,
    spec: &ReduceSpec,
    ns: &str,
) -> Result<(TaskPartial, f64, f64, u64)> {
    pf.pump()?;
    let fetch_t = Timer::start();
    let mut fragments = Vec::with_capacity(spec.n_tasks as usize);
    let mut shuffle_bytes = 0u64;
    for seq in 0..spec.n_tasks as usize {
        let key = crate::reduce::shuffle_key(ns, spec.partition, seq);
        let bytes = pf.take(&key)?;
        shuffle_bytes += bytes.len() as u64;
        fragments
            .push(crate::reduce::decode_fragment(&bytes, p.stat_fields)?);
    }
    let fetch_s = fetch_t.secs();

    let exec_t = Timer::start();
    let partial =
        crate::reduce::run_reduce(backend, p, spec.workload, &fragments)?;
    let exec_s = exec_t.secs();
    pf.observe_exec(exec_s);
    Ok((partial, fetch_s, exec_s, shuffle_bytes))
}

/// Fetch, assemble and execute one task under a key namespace;
/// returns (partial, fetch seconds, exec seconds).
pub(crate) fn run_task(
    p: &ModelParams,
    backend: &Backend,
    pf: &mut Prefetcher,
    spec: &TaskSpec,
    ns: &str,
) -> Result<(TaskPartial, f64, f64)> {
    pf.pump()?;
    let fetch_t = Timer::start();
    let mut blocks = Vec::with_capacity(spec.task.sample_ids.len());
    for &id in &spec.task.sample_ids {
        let key = crate::data::block::block_key(ns, spec.workload, id);
        let bytes = pf.take(&key)?;
        blocks.push(Block::decode(&bytes)?);
    }
    let fetch_s = fetch_t.secs();

    let exec_t = Timer::start();
    let slices = MapTask::slices(p, spec.workload, &blocks, spec.seed)?;
    let partial = execute_slices(backend, p, slices)?;
    let exec_s = exec_t.secs();
    pf.observe_exec(exec_s);
    Ok((partial, fetch_s, exec_s))
}

/// Abort one job's queued tasks and worker-local cache entries, then
/// ack. Local-only purge: the job's staged blocks are unchanged
/// across attempts, so shared-cache entries stay coherent (and keep
/// the restart warm); shared-structure invalidation happens once, at
/// tenant retirement.
fn handle_abort<C: WorkerChannel>(
    queue: &mut VecDeque<TaskEnvelope>,
    rqueue: &mut VecDeque<ReduceEnvelope>,
    pf: &mut Prefetcher,
    chan: &mut C,
    worker: usize,
    job: u64,
    upto_attempt: u32,
) {
    let before = queue.len() + rqueue.len();
    queue.retain(|t| !(t.job == job && t.attempt <= upto_attempt));
    rqueue.retain(|t| !(t.job == job && t.attempt <= upto_attempt));
    let dropped = (before - queue.len() - rqueue.len()) as u64;
    pf.purge_prefix_local(&crate::dfs::job_ns(job));
    let _ = chan.send(Up::Aborted { worker, dropped });
}

/// Worker-side completion batcher: buffers [`TaskDone`]s so a burst
/// of tiny tasks acks as one [`Up::DoneBatch`] frame instead of one
/// frame each. Flush points preserve the transport's FIFO semantics:
/// before any non-`Done` send (so `Drained`/`Exited`/`TaskFailed`
/// never overtake a buffered completion), before blocking on an
/// empty queue (no completion is ever held while the slot idles),
/// and at [`FLUSH_AT`](UpBatcher::FLUSH_AT) pending to bound leader-
/// visible latency while the queue is deep.
struct UpBatcher {
    pending: Vec<DoneItem>,
}

impl UpBatcher {
    /// Pending completions that force a flush mid-queue. Matches the
    /// scheduler's typical refill burst for tiny tasks: deep enough
    /// to amortize framing, shallow enough that the leader's
    /// response-time tracker still sees per-burst progress.
    const FLUSH_AT: usize = 4;

    fn new() -> UpBatcher {
        UpBatcher { pending: Vec::new() }
    }

    /// Buffer one completion, flushing if the batch is full. Returns
    /// `false` when the link is gone.
    fn push<C: WorkerChannel>(
        &mut self,
        chan: &mut C,
        job: u64,
        attempt: u32,
        done: TaskDone,
    ) -> bool {
        self.pending.push(DoneItem { job, attempt, done });
        if self.pending.len() >= Self::FLUSH_AT {
            self.flush(chan)
        } else {
            true
        }
    }

    /// Send everything pending: a single completion goes as a plain
    /// [`Up::Done`] (no batch framing overhead for the common
    /// trickle), two or more as one [`Up::DoneBatch`].
    fn flush<C: WorkerChannel>(&mut self, chan: &mut C) -> bool {
        match self.pending.len() {
            0 => true,
            1 => {
                let it = self.pending.pop().expect("len checked");
                chan.send(Up::Done {
                    job: it.job,
                    attempt: it.attempt,
                    done: Box::new(it.done),
                })
            }
            _ => chan.send(Up::DoneBatch(std::mem::take(&mut self.pending))),
        }
    }
}

/// The one map-slot loop every transport runs: drain the control
/// channel into a local queue (so the prefetcher sees upcoming block
/// keys), execute front-of-queue tasks through the backend, report
/// [`TaskDone`]s up. Exits on `Shutdown` (clean) or channel death,
/// always announcing [`Up::Exited`] last. Returns the number of
/// tasks executed.
pub fn worker_body<C: WorkerChannel>(
    cfg: &BodyCfg,
    params: &ModelParams,
    backend: &Backend,
    source: Arc<dyn BlockSource>,
    chan: &mut C,
) -> u64 {
    let mut pf = Prefetcher::new(source, cfg.prefetch_k);
    if let Some(index) = cfg.affinity.clone() {
        pf = pf.with_affinity(cfg.worker, index);
    }
    let mut queue: VecDeque<TaskEnvelope> = VecDeque::new();
    let mut rqueue: VecDeque<ReduceEnvelope> = VecDeque::new();
    let mut acks = UpBatcher::new();
    let mut executed = 0u64;
    // Tasks popped for execution (turbulence indexes on this, not on
    // `executed`, so an injected fault doesn't re-fire forever).
    let mut seen = 0u64;
    let mut clean = false;
    'outer: loop {
        // Non-blocking drain: pick up everything the leader has queued
        // (feeding the prefetcher lookahead, across jobs in serve mode).
        loop {
            match chan.try_recv() {
                Poll::Msg(Down::Task(t)) => {
                    enqueue_keys(&mut pf, &t.spec, &t.ns);
                    queue.push_back(*t);
                }
                Poll::Msg(Down::TaskBatch(ts)) => {
                    for t in ts {
                        enqueue_keys(&mut pf, &t.spec, &t.ns);
                        queue.push_back(t);
                    }
                }
                Poll::Msg(Down::Reduce(r)) => {
                    enqueue_reduce_keys(&mut pf, &r.spec, &r.ns);
                    rqueue.push_back(*r);
                }
                Poll::Msg(Down::Abort { job, upto_attempt }) => {
                    // Completions must precede the abort ack (FIFO).
                    if !acks.flush(chan) {
                        break 'outer;
                    }
                    handle_abort(
                        &mut queue,
                        &mut rqueue,
                        &mut pf,
                        chan,
                        cfg.worker,
                        job,
                        upto_attempt,
                    );
                }
                Poll::Msg(Down::Drain) => {
                    let returned = (queue.len() + rqueue.len()) as u64;
                    queue.clear();
                    rqueue.clear();
                    // Every completion this slot produced must reach
                    // the leader before `Drained` — the ledger
                    // re-dispatches exactly what isn't acked.
                    let _ = acks.flush(chan);
                    let _ = chan.send(Up::Drained {
                        worker: cfg.worker,
                        returned,
                    });
                    clean = true;
                    break 'outer;
                }
                Poll::Msg(Down::Shutdown) => {
                    clean = true;
                    break 'outer;
                }
                Poll::Empty => break,
                Poll::Closed => {
                    if queue.is_empty() && rqueue.is_empty() {
                        break 'outer;
                    }
                    break;
                }
            }
        }
        // Idle: block for the next instruction, measuring queue wait.
        // Nothing queued means nothing left to batch with — flush any
        // pending completions before sleeping so the leader is never
        // waiting on acks this slot is sitting on.
        let mut queue_wait_s = 0.0;
        if queue.is_empty() && rqueue.is_empty() {
            if !acks.flush(chan) {
                break;
            }
            let wait_t = Timer::start();
            match chan.recv() {
                Some(Down::Task(t)) => {
                    queue_wait_s = wait_t.secs();
                    enqueue_keys(&mut pf, &t.spec, &t.ns);
                    queue.push_back(*t);
                }
                Some(Down::TaskBatch(ts)) => {
                    queue_wait_s = wait_t.secs();
                    for t in ts {
                        enqueue_keys(&mut pf, &t.spec, &t.ns);
                        queue.push_back(t);
                    }
                }
                Some(Down::Reduce(r)) => {
                    queue_wait_s = wait_t.secs();
                    enqueue_reduce_keys(&mut pf, &r.spec, &r.ns);
                    rqueue.push_back(*r);
                }
                Some(Down::Abort { job, upto_attempt }) => {
                    handle_abort(
                        &mut queue,
                        &mut rqueue,
                        &mut pf,
                        chan,
                        cfg.worker,
                        job,
                        upto_attempt,
                    );
                    continue;
                }
                Some(Down::Drain) => {
                    // Idle slot: nothing queued, nothing in flight.
                    let _ = chan.send(Up::Drained {
                        worker: cfg.worker,
                        returned: 0,
                    });
                    clean = true;
                    break;
                }
                Some(Down::Shutdown) => {
                    clean = true;
                    break;
                }
                None => break,
            }
        }
        let Some(task) = queue.pop_front() else {
            // No map task queued: run a reduce partition if one is
            // pending. Reduce slots share the worker loop (and its
            // turbulence schedule) with map slots — ISSUE 6 tentpole.
            let Some(r) = rqueue.pop_front() else { continue };
            let nth = seen;
            seen += 1;
            if let Some(tb) = &cfg.turbulence {
                let d = tb.disturbance(cfg.worker, nth);
                if !d.delay.is_zero() {
                    std::thread::sleep(d.delay);
                }
                if d.kill {
                    // Scripted crash: die without executing, without a
                    // goodbye. The unclean `Exited` is the membership
                    // plane's loss signal.
                    break 'outer;
                }
                if d.fail {
                    let sent = acks.flush(chan)
                        && chan.send(Up::TaskFailed {
                            job: r.job,
                            attempt: r.attempt,
                            worker: cfg.worker,
                            error: Error::Scheduler(format!(
                                "turbulence fault on worker {} (reduce partition {})",
                                cfg.worker, r.spec.partition
                            )),
                        });
                    if !sent || !cfg.survive_task_errors {
                        break;
                    }
                    continue;
                }
            }
            match run_reduce_task(params, backend, &mut pf, &r.spec, &r.ns) {
                Ok((partial, fetch_s, exec_s, shuffle_bytes)) => {
                    executed += 1;
                    let sent = acks.flush(chan)
                        && chan.send(Up::ReduceDone {
                            job: r.job,
                            attempt: r.attempt,
                            done: Box::new(ReduceDone {
                                worker: cfg.worker,
                                partition: r.spec.partition,
                                partial,
                                fetch_s,
                                exec_s,
                                queue_wait_s,
                                shuffle_bytes,
                            }),
                        });
                    if !sent {
                        break;
                    }
                }
                Err(e) => {
                    let sent = acks.flush(chan)
                        && chan.send(Up::TaskFailed {
                            job: r.job,
                            attempt: r.attempt,
                            worker: cfg.worker,
                            error: e,
                        });
                    if !sent || !cfg.survive_task_errors {
                        break;
                    }
                }
            }
            continue;
        };
        // Scripted turbulence: impose the slot's deterministic extra
        // latency (and/or fault) for its nth task before executing.
        let nth = seen;
        seen += 1;
        if let Some(tb) = &cfg.turbulence {
            let d = tb.disturbance(cfg.worker, nth);
            if !d.delay.is_zero() {
                std::thread::sleep(d.delay);
            }
            if d.kill {
                // Scripted crash (see the reduce path above).
                break 'outer;
            }
            if d.fail {
                let sent = acks.flush(chan)
                    && chan.send(Up::TaskFailed {
                        job: task.job,
                        attempt: task.attempt,
                        worker: cfg.worker,
                        error: Error::Scheduler(format!(
                            "turbulence fault on worker {} (task {})",
                            cfg.worker, task.spec.task.seq
                        )),
                    });
                if !sent || !cfg.survive_task_errors {
                    break;
                }
                continue;
            }
        }
        if task.poison {
            let sent = acks.flush(chan)
                && chan.send(Up::TaskFailed {
                    job: task.job,
                    attempt: task.attempt,
                    worker: cfg.worker,
                    error: Error::Scheduler(format!(
                        "injected task fault in job {} (attempt {}, task {})",
                        task.job, task.attempt, task.spec.task.seq
                    )),
                });
            if !sent || !cfg.survive_task_errors {
                break;
            }
            continue;
        }
        let (h0, m0) = (pf.hits, pf.misses);
        let (ch0, cm0) = (pf.cache_hits, pf.cache_misses);
        match run_task(params, backend, &mut pf, &task.spec, &task.ns) {
            Ok((partial, fetch_s, exec_s)) => {
                executed += 1;
                let done = TaskDone {
                    worker: cfg.worker,
                    seq: task.spec.task.seq,
                    partial,
                    fetch_s,
                    exec_s,
                    queue_wait_s,
                    prefetch_hits: pf.hits - h0,
                    prefetch_misses: pf.misses - m0,
                    cache_hits: pf.cache_hits - ch0,
                    cache_misses: pf.cache_misses - cm0,
                };
                let sent =
                    acks.push(chan, task.job, task.attempt, done);
                if !sent {
                    break;
                }
                if let Some(plan) = cfg.failure {
                    if plan.worker == cfg.worker
                        && task.attempt == plan.on_attempt
                        && executed >= plan.after_tasks
                    {
                        // The buffered `Done` for this task must land
                        // before the failure report.
                        let _ = acks.flush(chan)
                            && chan.send(Up::TaskFailed {
                                job: task.job,
                                attempt: task.attempt,
                                worker: cfg.worker,
                                error: Error::Scheduler(format!(
                                    "injected node failure on worker {} after {executed} tasks",
                                    cfg.worker
                                )),
                            });
                        break;
                    }
                }
            }
            Err(e) => {
                let sent = acks.flush(chan)
                    && chan.send(Up::TaskFailed {
                        job: task.job,
                        attempt: task.attempt,
                        worker: cfg.worker,
                        error: e,
                    });
                if !sent || !cfg.survive_task_errors {
                    break;
                }
            }
        }
    }
    let _ = acks.flush(chan);
    let _ = chan.send(Up::Exited {
        worker: cfg.worker,
        executed,
        clean,
    });
    executed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Workload;
    use crate::dfs::{Dfs, LatencyModel};
    use crate::kneepoint::{pack, TaskSizing};

    fn staged_job(
        samples: usize,
    ) -> (Arc<Dfs>, Vec<TaskSpec>, Arc<Backend>, ModelParams) {
        let params = ModelParams::default();
        let backend = Arc::new(Backend::native(params.clone()));
        let ds =
            crate::workloads::build_small(Workload::Eaglet, &params, samples);
        let dfs = Dfs::new(2, 1, LatencyModel::none());
        crate::exec::cluster::stage_dataset(ds.as_ref(), &dfs, "");
        let specs: Vec<TaskSpec> = pack(ds.metas(), TaskSizing::Tiniest)
            .into_iter()
            .map(|t| TaskSpec::new(t, Workload::Eaglet, 7))
            .collect();
        (dfs, specs, backend, params)
    }

    fn envelope(spec: TaskSpec, poison: bool) -> Down {
        Down::Task(Box::new(TaskEnvelope {
            job: 0,
            attempt: 1,
            ns: "".into(),
            spec,
            poison,
        }))
    }

    /// Run a body on its own thread, feed it `downs`, collect `want`
    /// task outcomes (Done/TaskFailed), then shut it down. Mirrors a
    /// real leader: Shutdown only goes out once the work is answered
    /// (a Shutdown seen during the drain skips queued tasks — the
    /// abort contract).
    fn drive(
        cfg: BodyCfg,
        params: ModelParams,
        backend: Arc<Backend>,
        dfs: Arc<Dfs>,
        downs: Vec<Down>,
        want: usize,
    ) -> (u64, Vec<Up>) {
        let (down_tx, down_rx) = mpsc::channel();
        let (up_tx, up_rx) = mpsc::channel();
        let body = std::thread::spawn(move || {
            let mut chan = InProcChannel { rx: down_rx, tx: up_tx };
            worker_body(&cfg, &params, &backend, dfs, &mut chan)
        });
        for d in downs {
            down_tx.send(d).unwrap();
        }
        let mut ups = Vec::new();
        let mut outcomes = 0;
        while outcomes < want {
            let up = up_rx.recv().expect("body hung up early");
            outcomes += match &up {
                Up::Done { .. } | Up::TaskFailed { .. } => 1,
                Up::DoneBatch(items) => items.len(),
                _ => 0,
            };
            ups.push(up);
        }
        down_tx.send(Down::Shutdown).unwrap();
        let executed = body.join().unwrap();
        while let Ok(up) = up_rx.try_recv() {
            ups.push(up);
        }
        (executed, ups)
    }

    #[test]
    fn body_executes_then_exits_clean_on_shutdown() {
        let (dfs, specs, backend, params) = staged_job(4);
        let n = specs.len();
        let downs: Vec<Down> =
            specs.into_iter().map(|s| envelope(s, false)).collect();
        let (executed, ups) =
            drive(BodyCfg::new(0), params, backend, dfs, downs, n);
        assert_eq!(executed, n as u64);
        let dones: usize = ups
            .iter()
            .map(|u| match u {
                Up::Done { job: 0, attempt: 1, .. } => 1,
                Up::DoneBatch(items) => items
                    .iter()
                    .filter(|it| it.job == 0 && it.attempt == 1)
                    .count(),
                _ => 0,
            })
            .sum();
        assert_eq!(dones, n);
        assert!(ups.iter().any(|u| matches!(
            u,
            Up::Exited { executed: e, clean: true, .. } if *e == n as u64
        )));
    }

    #[test]
    fn poison_reports_failure_and_pool_worker_survives() {
        let (dfs, specs, backend, params) = staged_job(3);
        let n = specs.len();
        let downs: Vec<Down> = specs
            .into_iter()
            .enumerate()
            .map(|(i, s)| envelope(s, i == 0))
            .collect();
        let (executed, ups) =
            drive(BodyCfg::new(3), params, backend, dfs, downs, n);
        assert_eq!(executed, 2, "poison must not kill a pool worker");
        let failed = ups
            .iter()
            .filter(|u| matches!(u, Up::TaskFailed { worker: 3, .. }))
            .count();
        assert_eq!(failed, 1);
    }

    #[test]
    fn task_batch_executes_like_singles_and_acks_in_batches() {
        let (dfs, specs, backend, params) = staged_job(4);
        let n = specs.len();
        let envs: Vec<TaskEnvelope> = specs
            .into_iter()
            .map(|s| TaskEnvelope {
                job: 0,
                attempt: 1,
                ns: "".into(),
                spec: s,
                poison: false,
            })
            .collect();
        let (executed, ups) = drive(
            BodyCfg::new(0),
            params,
            backend,
            dfs,
            vec![Down::TaskBatch(envs)],
            n,
        );
        assert_eq!(executed, n as u64);
        // A queue at least FLUSH_AT deep must coalesce some acks.
        if n >= UpBatcher::FLUSH_AT {
            assert!(
                ups.iter().any(|u| matches!(u, Up::DoneBatch(_))),
                "expected at least one batched ack from {n} tasks"
            );
        }
        // And the batch must land before the slot's exit frame.
        let exit_at = ups
            .iter()
            .position(|u| matches!(u, Up::Exited { .. }))
            .expect("missing Exited");
        let last_done = ups
            .iter()
            .rposition(|u| {
                matches!(u, Up::Done { .. } | Up::DoneBatch(_))
            })
            .expect("missing completions");
        assert!(last_done < exit_at, "completion after Exited");
    }

    #[test]
    fn body_runs_reduce_partitions_bit_identical() {
        use crate::coordinator::JobOutput;
        use crate::reduce::{self, Partitioner};
        let params = ModelParams::default();
        let backend = Arc::new(Backend::native(params.clone()));
        let dfs = Dfs::new(2, 1, LatencyModel::none());
        let partials: Vec<TaskPartial> = (0..3)
            .map(|i| TaskPartial::Eaglet {
                alod: (0..params.grid)
                    .map(|k| (k as f32) * 0.25 + i as f32)
                    .collect(),
                weight: 1.0 + i as f32,
            })
            .collect();
        let weights =
            reduce::key_weights(Workload::Eaglet, &params, &partials)
                .unwrap();
        let plan = reduce::build_plan(Partitioner::Skew, &weights, 2);
        let (blocks, staged_bytes) =
            reduce::stage_fragments(&params, "", &plan, &partials).unwrap();
        for (k, b) in blocks {
            dfs.put(&k, b);
        }
        let (down_tx, down_rx) = mpsc::channel();
        let (up_tx, up_rx) = mpsc::channel();
        let body = {
            let backend = Arc::clone(&backend);
            let params = params.clone();
            let dfs = Arc::clone(&dfs);
            std::thread::spawn(move || {
                let mut chan = InProcChannel { rx: down_rx, tx: up_tx };
                worker_body(&BodyCfg::new(0), &params, &backend, dfs, &mut chan)
            })
        };
        for partition in 0..plan.partitions {
            down_tx
                .send(Down::Reduce(Box::new(ReduceEnvelope {
                    job: 0,
                    attempt: 1,
                    ns: "".into(),
                    spec: ReduceSpec {
                        partition,
                        partitions: plan.partitions,
                        n_tasks: partials.len() as u32,
                        workload: Workload::Eaglet,
                        keys: plan.keys_of(partition),
                    },
                })))
                .unwrap();
        }
        let mut reduced: Vec<Option<TaskPartial>> =
            vec![None; plan.partitions as usize];
        let mut fetched_bytes = 0u64;
        let mut got = 0;
        while got < plan.partitions {
            match up_rx.recv().expect("body hung up early") {
                Up::ReduceDone { job: 0, attempt: 1, done } => {
                    assert!(done.shuffle_bytes > 0);
                    fetched_bytes += done.shuffle_bytes;
                    reduced[done.partition as usize] = Some(done.partial);
                    got += 1;
                }
                up => panic!("unexpected message: {up:?}"),
            }
        }
        down_tx.send(Down::Shutdown).unwrap();
        let executed = body.join().unwrap();
        assert_eq!(executed, plan.partitions as u64);
        assert_eq!(fetched_bytes, staged_bytes);
        let reduced: Vec<TaskPartial> =
            reduced.into_iter().map(|p| p.unwrap()).collect();
        let out = reduce::assemble_output(
            &params,
            Workload::Eaglet,
            &plan,
            &reduced,
        )
        .unwrap();
        // Oracle: the map-side-only aggregation over the same partials.
        let pairs: Vec<(Vec<f32>, f32)> = partials
            .iter()
            .map(|p| match p {
                TaskPartial::Eaglet { alod, weight } => {
                    (alod.clone(), *weight)
                }
                _ => unreachable!(),
            })
            .collect();
        let (oracle_alod, oracle_w) =
            crate::coordinator::reduce_eaglet(&*backend, &params, pairs)
                .unwrap();
        match out {
            JobOutput::Eaglet { alod, weight } => {
                assert_eq!(alod, oracle_alod, "lanes must be bit-identical");
                assert_eq!(weight, oracle_w);
            }
            other => panic!("wrong output kind: {other:?}"),
        }
    }

    #[test]
    fn turbulence_fault_on_reduce_keeps_pool_slot_alive() {
        use crate::reduce::{self, Partitioner};
        use crate::util::testutil::Turbulence;
        let params = ModelParams::default();
        let backend = Arc::new(Backend::native(params.clone()));
        let dfs = Dfs::new(2, 1, LatencyModel::none());
        let partials: Vec<TaskPartial> = (0..2)
            .map(|i| TaskPartial::Eaglet {
                alod: vec![0.5 + i as f32; params.grid],
                weight: 1.0,
            })
            .collect();
        let weights =
            reduce::key_weights(Workload::Eaglet, &params, &partials)
                .unwrap();
        let plan = reduce::build_plan(Partitioner::Hash, &weights, 1);
        let (blocks, _) =
            reduce::stage_fragments(&params, "", &plan, &partials).unwrap();
        for (k, b) in blocks {
            dfs.put(&k, b);
        }
        let (down_tx, down_rx) = mpsc::channel();
        let (up_tx, up_rx) = mpsc::channel();
        let cfg = BodyCfg {
            turbulence: Some(Arc::new(Turbulence::new(7).fail_at(0, 0))),
            ..BodyCfg::new(0)
        };
        let body = {
            let backend = Arc::clone(&backend);
            let params = params.clone();
            let dfs = Arc::clone(&dfs);
            std::thread::spawn(move || {
                let mut chan = InProcChannel { rx: down_rx, tx: up_tx };
                worker_body(&cfg, &params, &backend, dfs, &mut chan)
            })
        };
        let envelope = || {
            Down::Reduce(Box::new(ReduceEnvelope {
                job: 0,
                attempt: 1,
                ns: "".into(),
                spec: ReduceSpec {
                    partition: 0,
                    partitions: 1,
                    n_tasks: partials.len() as u32,
                    workload: Workload::Eaglet,
                    keys: plan.keys_of(0),
                },
            }))
        };
        // First dispatch hits the injected fault; the slot must
        // report it and keep serving (pool semantics).
        down_tx.send(envelope()).unwrap();
        match up_rx.recv().expect("body hung up early") {
            Up::TaskFailed { job: 0, attempt: 1, worker: 0, .. } => {}
            up => panic!("expected reduce fault, got {up:?}"),
        }
        // The leader's recovery re-dispatches the partition; the
        // retry lands past the fault window and succeeds.
        down_tx.send(envelope()).unwrap();
        match up_rx.recv().expect("slot died after the fault") {
            Up::ReduceDone { job: 0, attempt: 1, done } => {
                assert_eq!(done.partition, 0);
            }
            up => panic!("expected reduce completion, got {up:?}"),
        }
        down_tx.send(Down::Shutdown).unwrap();
        assert_eq!(body.join().unwrap(), 1, "only the retry executed");
    }

    #[test]
    fn abort_drops_queued_tasks_and_acks() {
        let (dfs, specs, backend, params) = staged_job(3);
        let n = specs.len() as u64;
        let (down_tx, down_rx) = mpsc::channel();
        let (up_tx, up_rx) = mpsc::channel();
        for s in specs {
            down_tx
                .send(Down::Task(Box::new(TaskEnvelope {
                    job: 9,
                    attempt: 1,
                    ns: crate::dfs::job_ns(9).into(),
                    spec: s,
                    poison: false,
                })))
                .unwrap();
        }
        down_tx.send(Down::Abort { job: 9, upto_attempt: 1 }).unwrap();
        down_tx.send(Down::Shutdown).unwrap();
        let mut chan = InProcChannel { rx: down_rx, tx: up_tx };
        worker_body(&BodyCfg::new(0), &params, &backend, dfs, &mut chan);
        // Everything the drain saw before the abort was dropped; the
        // ack accounts for all of it (the drain enqueues all three
        // tasks before the first execution begins, minus at most the
        // one already popped).
        let mut dropped = None;
        while let Ok(up) = up_rx.try_recv() {
            if let Up::Aborted { dropped: d, .. } = up {
                dropped = Some(d);
            }
        }
        let d = dropped.expect("abort must be acked");
        assert!(d >= n - 1, "dropped {d} of {n}");
    }
}
