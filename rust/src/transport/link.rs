//! Leader-side worker links: the one handle a leader holds per map
//! slot, whatever the transport underneath.
//!
//! * [`WorkerLink::spawn_inproc`] — a worker thread running
//!   [`super::worker_body`] over mpsc channels (the historical
//!   `exec`/`serve` transport).
//! * [`accept_links`] — remote `bts worker --connect` processes over
//!   framed TCP: each accepted connection gets a **pump** thread that
//!   translates incoming frames into the same shared
//!   `mpsc::Sender<Up>` the in-proc workers feed, and answers the
//!   worker's `DfsGet`/`DfsPut` data-plane requests directly from the
//!   leader's replicated [`Dfs`] — so remote fetches still pass
//!   through response-time-aware replica selection and the shared
//!   block cache, and the dispatcher never blocks on another
//!   worker's I/O.
//!
//! A link that dies without an orderly `Exited` (reset, EOF mid-job,
//! protocol garbage) is surfaced as [`Up::Lost`] followed by a
//! synthesized unclean [`Up::Exited`], so leaders that wait for every
//! slot's exit never hang on a vanished worker — the worker-failure
//! path job-level recovery keys off.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::{BodyCfg, Down, InProcChannel, Up};
use crate::data::ModelParams;
use crate::dfs::{BlockSource, Dfs};
use crate::error::{Error, Result};
use crate::exec::Backend;
use crate::net::protocol::{
    configure_stream, FrameReader, FramedWriter, Message, NetCounters,
    ACCEPT_TIMEOUT, HANDSHAKE_TIMEOUT, PING_INTERVAL, PUMP_IDLE_TIMEOUT,
};
use crate::scheduler::ResponseTimeTracker;

/// The leader-side frame writer for one TCP link: scratch-buffer
/// encode, vectored data-plane writes, shared between the dispatcher
/// (Down frames) and the pump (DfsBlock replies) under one lock.
type LinkWriter = Arc<Mutex<FramedWriter<BufWriter<TcpStream>>>>;

/// Remote map slots for a leader: a pre-bound listener plus how many
/// workers to accept on it. Binding is the caller's job (so tests can
/// bind port 0 and learn the address, and job-level recovery can
/// reuse one listener across attempts — reconnecting workers land in
/// the backlog and are adopted by the next attempt).
#[derive(Debug, Clone)]
pub struct RemoteWorkers {
    pub listener: Arc<TcpListener>,
    pub count: usize,
}

impl RemoteWorkers {
    /// Bind `addr` and expect `count` workers to connect.
    pub fn bind(addr: &str, count: usize) -> Result<RemoteWorkers> {
        let listener = TcpListener::bind(addr)?;
        Ok(RemoteWorkers { listener: Arc::new(listener), count })
    }

    /// The bound address (`--listen 127.0.0.1:0` resolves here).
    pub fn addr(&self) -> String {
        self.listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_default()
    }
}

/// Timing knobs for a TCP link's leader-side pump (satellite of the
/// elastic-membership work: turbulence tests tighten these instead of
/// sleeping wall-clock seconds). `idle_timeout` should be several
/// heartbeat intervals — one missed ping is jitter, six is a
/// partition.
#[derive(Debug, Clone, Copy)]
pub struct PumpCfg {
    /// The heartbeat interval the *worker* was asked to ping at; gaps
    /// beyond this feed the response-time tracker as link drag.
    pub ping_interval: Duration,
    /// Reads idle longer than this surface the peer as [`Up::Lost`].
    pub idle_timeout: Duration,
}

impl Default for PumpCfg {
    fn default() -> Self {
        PumpCfg {
            ping_interval: PING_INTERVAL,
            idle_timeout: PUMP_IDLE_TIMEOUT,
        }
    }
}

impl PumpCfg {
    /// Derive both knobs from one `--heartbeat-ms` value, keeping the
    /// default 6:1 idle-to-ping ratio.
    pub fn from_heartbeat_ms(ms: u64) -> PumpCfg {
        let ping = Duration::from_millis(ms.max(1));
        PumpCfg { ping_interval: ping, idle_timeout: ping * 6 }
    }
}

enum LinkSender {
    InProc(mpsc::Sender<Down>),
    Tcp(LinkWriter),
}

/// The leader's handle to one map slot. `send` is the entire control
/// surface; above this type, in-proc and TCP workers are
/// indistinguishable.
pub struct WorkerLink {
    worker: usize,
    sender: LinkSender,
    /// The worker thread (in-proc) or frame pump (TCP), joined at
    /// teardown.
    handle: Option<thread::JoinHandle<()>>,
}

impl WorkerLink {
    /// Spawn a local worker thread over [`super::worker_body`].
    pub fn spawn_inproc(
        cfg: BodyCfg,
        params: ModelParams,
        backend: Arc<Backend>,
        source: Arc<dyn BlockSource>,
        up: mpsc::Sender<Up>,
        thread_label: &str,
    ) -> Result<WorkerLink> {
        let worker = cfg.worker;
        let (tx, rx) = mpsc::channel::<Down>();
        let handle = thread::Builder::new()
            .name(format!("{thread_label}-{worker}"))
            .spawn(move || {
                let mut chan = InProcChannel { rx, tx: up };
                super::worker_body(&cfg, &params, &backend, source, &mut chan);
            })
            .map_err(|e| {
                Error::Scheduler(format!("spawn worker {worker}: {e}"))
            })?;
        Ok(WorkerLink {
            worker,
            sender: LinkSender::InProc(tx),
            handle: Some(handle),
        })
    }

    /// Adopt one accepted remote connection as map slot `worker`:
    /// handshake (Hello → Welcome), then spawn the frame pump. When a
    /// response-time `tracker` is supplied, the pump reports each
    /// heartbeat's gap overrun into it — a congested or drifting link
    /// makes its slot look slower to the dynamic scheduler even while
    /// a long task keeps the control plane otherwise silent.
    pub fn adopt_tcp(
        stream: TcpStream,
        worker: usize,
        dfs: Arc<Dfs>,
        up: mpsc::Sender<Up>,
        tracker: Option<Arc<ResponseTimeTracker>>,
        counters: Arc<NetCounters>,
    ) -> Result<WorkerLink> {
        configure_stream(&stream)?;
        let mut rd = BufReader::new(stream.try_clone()?);
        match Message::read_deadline(&mut rd, Some(HANDSHAKE_TIMEOUT))? {
            Message::Hello { .. } => {}
            other => {
                return Err(Error::Protocol(format!(
                    "expected Hello, got {other:?}"
                )))
            }
        }
        WorkerLink::adopt_handshaken(
            stream,
            rd,
            worker,
            dfs,
            up,
            tracker,
            PumpCfg::default(),
            counters,
        )
    }

    /// The post-handshake half of [`WorkerLink::adopt_tcp`]: the
    /// caller has already configured the stream and consumed the
    /// peer's `Hello` from `rd` (the membership acceptor does this to
    /// decide admit-vs-refuse before committing a slot). Sends
    /// `Welcome` and spawns the frame pump with the given timing.
    /// `counters` is the leader endpoint's shared data-plane tally —
    /// every frame this link writes is accounted there.
    #[allow(clippy::too_many_arguments)]
    pub fn adopt_handshaken(
        stream: TcpStream,
        rd: BufReader<TcpStream>,
        worker: usize,
        dfs: Arc<Dfs>,
        up: mpsc::Sender<Up>,
        tracker: Option<Arc<ResponseTimeTracker>>,
        pump_cfg: PumpCfg,
        counters: Arc<NetCounters>,
    ) -> Result<WorkerLink> {
        let wr: LinkWriter = Arc::new(Mutex::new(FramedWriter::new(
            BufWriter::new(stream),
            counters,
        )));
        {
            // The mutex is seconds old, but a panic between creation
            // and here would poison it — surface that as a protocol
            // failure on this link, never a leader panic.
            let mut g = wr.lock().map_err(|_| {
                Error::Protocol(format!(
                    "link {worker}: writer lock poisoned before Welcome"
                ))
            })?;
            g.send(&Message::Welcome { worker: worker as u32 })?;
        }
        let pump_wr = wr.clone();
        let handle = thread::Builder::new()
            .name(format!("bts-link-pump-{worker}"))
            .spawn(move || {
                pump(worker, rd, dfs, pump_wr, up, tracker, pump_cfg)
            })
            .map_err(|e| {
                Error::Scheduler(format!("spawn link pump {worker}: {e}"))
            })?;
        Ok(WorkerLink {
            worker,
            sender: LinkSender::Tcp(wr),
            handle: Some(handle),
        })
    }

    pub fn worker(&self) -> usize {
        self.worker
    }

    pub fn is_remote(&self) -> bool {
        matches!(self.sender, LinkSender::Tcp(_))
    }

    /// Push one control message down the link. `false` means the link
    /// is gone (its `Up::Lost`/`Exited` explains).
    pub fn send(&self, msg: Down) -> bool {
        match &self.sender {
            LinkSender::InProc(tx) => tx.send(msg).is_ok(),
            LinkSender::Tcp(wr) => {
                let Ok(mut g) = wr.lock() else { return false };
                g.send(&Message::Down(msg)).is_ok()
            }
        }
    }

    /// Join the worker thread / pump. Call after `Up::Exited` has
    /// been collected (or after sending `Shutdown`). `false` means
    /// the joined thread panicked.
    pub fn join(mut self) -> bool {
        match self.handle.take() {
            Some(h) => h.join().is_ok(),
            None => true,
        }
    }
}

/// The per-connection frame pump: forward the worker's control
/// messages into the leader's shared up-channel (rewriting the worker
/// id to this link's slot — accounting trusts the link, not the
/// peer), and serve its DFS data-plane requests from the real store.
fn pump(
    worker: usize,
    mut rd: BufReader<TcpStream>,
    dfs: Arc<Dfs>,
    wr: LinkWriter,
    up: mpsc::Sender<Up>,
    tracker: Option<Arc<ResponseTimeTracker>>,
    cfg: PumpCfg,
) {
    let lost = |error: Error| {
        let _ = up.send(Up::Lost { worker, error });
        // Synthesized unclean exit: leaders waiting for every slot's
        // Exited must not hang on a vanished worker.
        let _ = up.send(Up::Exited { worker, executed: 0, clean: false });
    };
    let mut last_ping: Option<Instant> = None;
    // Per-pump frame reader: one scratch buffer reused across every
    // control frame this link ever receives, and DfsPut payloads read
    // straight into their final Arc.
    let mut frames = FrameReader::new();
    loop {
        // Idle-bounded read: workers heartbeat ([`Message::Ping`])
        // even mid-task, so several missed intervals means a silently
        // partitioned peer (no FIN/RST will ever come) — surface it
        // as Lost instead of wedging the leader forever.
        match frames.read(&mut rd, Some(cfg.idle_timeout)) {
            Ok(Message::Up(u)) => {
                let exiting = matches!(u, Up::Exited { .. });
                if up.send(rewrite_worker(u, worker)).is_err() || exiting {
                    return;
                }
            }
            Ok(Message::Ping) => {
                // Heartbeat-gap overrun → response-time tracker: a
                // ping that arrives late past its interval is link (or
                // peer) drag the slot's own timers never report.
                if let Some(t) = &tracker {
                    if let Some(prev) = last_ping {
                        let overrun = prev
                            .elapsed()
                            .saturating_sub(cfg.ping_interval)
                            .as_secs_f64();
                        t.observe_rtt(worker, overrun);
                    }
                }
                last_ping = Some(Instant::now());
            }
            Ok(Message::DfsGet { key }) => {
                let reply = match dfs.get_traced(&key) {
                    // The store's Arc rides into the frame write
                    // directly — no deep copy per served block.
                    Ok((data, _wall, _lookup)) => {
                        Message::DfsBlock { data, key }
                    }
                    Err(e) => {
                        Message::DfsMiss { key, message: e.to_string() }
                    }
                };
                let ok = match wr.lock() {
                    Ok(mut g) => g.send(&reply).is_ok(),
                    Err(_) => false,
                };
                if !ok {
                    lost(Error::Protocol(format!(
                        "worker {worker}: data-plane write failed"
                    )));
                    return;
                }
            }
            Ok(Message::DfsPut { key, data }) => {
                // The Arc built by the frame reader goes into the
                // store as-is — a remote put is now one allocation
                // end-to-end (socket read → replica store).
                dfs.put(&key, data);
            }
            Ok(other) => {
                lost(Error::Protocol(format!(
                    "worker {worker} sent unexpected {other:?}"
                )));
                return;
            }
            Err(e) => {
                lost(e);
                return;
            }
        }
    }
}

/// Stamp the link's slot id over whatever the peer claimed.
fn rewrite_worker(u: Up, worker: usize) -> Up {
    match u {
        Up::Done { job, attempt, mut done } => {
            done.worker = worker;
            Up::Done { job, attempt, done }
        }
        Up::DoneBatch(mut items) => {
            for it in &mut items {
                it.done.worker = worker;
            }
            Up::DoneBatch(items)
        }
        Up::ReduceDone { job, attempt, mut done } => {
            done.worker = worker;
            Up::ReduceDone { job, attempt, done }
        }
        Up::TaskFailed { job, attempt, error, .. } => {
            Up::TaskFailed { job, attempt, worker, error }
        }
        Up::Aborted { dropped, .. } => Up::Aborted { worker, dropped },
        Up::Lost { error, .. } => Up::Lost { worker, error },
        Up::Exited { executed, clean, .. } => {
            Up::Exited { worker, executed, clean }
        }
    }
}

/// Orderly link teardown: `Shutdown` to every link, then join them
/// all. Leaders use this on partial-standup failures (a remote worker
/// that never arrived must not strand the slots that did).
pub fn teardown(links: Vec<WorkerLink>) {
    for l in &links {
        let _ = l.send(Down::Shutdown);
    }
    for l in links {
        l.join();
    }
}

/// Accept `remote.count` workers, assigning slots `first_slot..`.
/// Each accept + handshake is bounded ([`ACCEPT_TIMEOUT`] /
/// [`HANDSHAKE_TIMEOUT`]), so a missing worker fails the run instead
/// of wedging it. `tracker` (dynamic scheduling) receives each link's
/// heartbeat-gap overruns.
pub fn accept_links(
    remote: &RemoteWorkers,
    first_slot: usize,
    dfs: &Arc<Dfs>,
    up: &mpsc::Sender<Up>,
    tracker: Option<Arc<ResponseTimeTracker>>,
    counters: Arc<NetCounters>,
) -> Result<Vec<WorkerLink>> {
    let mut links = Vec::with_capacity(remote.count);
    remote.listener.set_nonblocking(true)?;
    for i in 0..remote.count {
        let deadline = Instant::now() + ACCEPT_TIMEOUT;
        let stream = loop {
            match remote.listener.accept() {
                Ok((stream, _addr)) => break stream,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    if Instant::now() > deadline {
                        return Err(Error::Protocol(format!(
                            "timed out waiting for remote worker {} of {}",
                            i + 1,
                            remote.count
                        )));
                    }
                    thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(Error::Io(e)),
            }
        };
        links.push(WorkerLink::adopt_tcp(
            stream,
            first_slot + i,
            dfs.clone(),
            up.clone(),
            tracker.clone(),
            counters.clone(),
        )?);
    }
    Ok(links)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::LatencyModel;

    #[test]
    fn remote_workers_bind_reports_resolved_addr() {
        let rw = RemoteWorkers::bind("127.0.0.1:0", 1).unwrap();
        let addr = rw.addr();
        assert!(addr.starts_with("127.0.0.1:"));
        assert!(!addr.ends_with(":0"), "port should be resolved: {addr}");
    }

    #[test]
    fn accept_rejects_non_hello_first_frame() {
        let rw = RemoteWorkers::bind("127.0.0.1:0", 1).unwrap();
        let addr = rw.addr();
        let client = thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            configure_stream(&stream).unwrap();
            let mut wr = BufWriter::new(stream);
            Message::DfsGet { key: "x".into() }
                .write_to(&mut wr)
                .unwrap();
            // keep the socket open until the leader judges the frame
            thread::sleep(std::time::Duration::from_millis(200));
        });
        let dfs = Dfs::new(1, 1, LatencyModel::none());
        let (up_tx, _up_rx) = mpsc::channel();
        let err = accept_links(
            &rw,
            0,
            &dfs,
            &up_tx,
            None,
            Arc::new(NetCounters::default()),
        )
        .unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        client.join().unwrap();
    }

    #[test]
    fn dead_tcp_link_surfaces_lost_and_unclean_exit() {
        let rw = RemoteWorkers::bind("127.0.0.1:0", 1).unwrap();
        let addr = rw.addr();
        let client = thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            configure_stream(&stream).unwrap();
            let mut rd = BufReader::new(stream.try_clone().unwrap());
            let mut wr = BufWriter::new(stream);
            Message::Hello { worker: 0 }.write_to(&mut wr).unwrap();
            let Message::Welcome { worker } =
                Message::read_from(&mut rd).unwrap()
            else {
                panic!("expected Welcome")
            };
            assert_eq!(worker, 4);
            // vanish without an Exited — a crashed worker
        });
        let dfs = Dfs::new(1, 1, LatencyModel::none());
        let (up_tx, up_rx) = mpsc::channel();
        let links = accept_links(
            &rw,
            4,
            &dfs,
            &up_tx,
            None,
            Arc::new(NetCounters::default()),
        )
        .unwrap();
        client.join().unwrap();
        match up_rx.recv().unwrap() {
            Up::Lost { worker: 4, .. } => {}
            other => panic!("expected Lost, got {other:?}"),
        }
        match up_rx.recv().unwrap() {
            Up::Exited { worker: 4, clean: false, .. } => {}
            other => panic!("expected unclean Exited, got {other:?}"),
        }
        for l in links {
            assert!(l.is_remote());
            l.join();
        }
    }
}
