//! The remote worker session: what `bts worker --connect` runs.
//!
//! One TCP connection carries both planes. A **reader thread** splits
//! incoming frames: control messages ([`Down`]) feed the same channel
//! type the in-proc workers drain, and DFS answers feed the
//! [`RemoteDfs`] response queue. Sends (task results up, block
//! fetches out) share one framed writer behind a mutex. The worker
//! body itself is [`super::worker_body`] — the identical loop the
//! in-proc slots run, which is the whole point: a remote worker gets
//! the two-step scheduler's batches, prefetching (the [`Prefetcher`]
//! pumps ahead through [`RemoteDfs`] exactly as it does through a
//! local [`crate::dfs::Dfs`]), per-task metrics, and job-level
//! recovery without any TCP-specific logic.
//!
//! [`RemoteDfs`] fronts the leader-proxied fetch path with an
//! optional worker-local [`BlockCache`]: re-fetched blocks (steals,
//! multi-task samples, warm tenants in serve mode) are served from
//! worker memory without touching the wire. Key-mapping coherence
//! rides on the platform's key discipline — a job's namespaced keys
//! are staged once and never rebound to different bytes within a
//! leader session — and aborts purge the job's prefix locally.
//!
//! [`Prefetcher`]: crate::dfs::Prefetcher

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::{BodyCfg, Down, Poll, Up, WorkerChannel};
use crate::cache::BlockCache;
use crate::dfs::{BlockSource, CacheLookup};
use crate::error::{Error, Result};
use crate::exec::Backend;
use crate::net::protocol::{
    configure_stream, FrameReader, FramedWriter, Message, NetCounters,
    DFS_FETCH_TIMEOUT, HANDSHAKE_TIMEOUT, PING_INTERVAL,
};
use crate::runtime::Exec as _;

/// Knobs for one remote worker session.
#[derive(Debug, Clone)]
pub struct RemoteWorkerOpts {
    /// Upper bound on the prefetch depth k.
    pub prefetch_k: usize,
    /// Worker-local block cache budget in MiB (0 disables): re-used
    /// blocks skip the wire entirely.
    pub cache_mb: usize,
    /// Keep retrying the initial connect for this long (the leader
    /// may not have bound its listener yet).
    pub connect_window: Duration,
    /// Fault injection for disconnect tests: after this many task
    /// completions the link is severed without an orderly goodbye,
    /// simulating a crashed or partitioned worker.
    pub drop_link_after: Option<u64>,
    /// Heartbeat ping interval (`--heartbeat-ms`). Must match the
    /// leader's expectation: the leader treats gaps beyond its own
    /// configured interval as link drag, and several missed intervals
    /// as a partition.
    pub heartbeat: Duration,
}

impl Default for RemoteWorkerOpts {
    fn default() -> Self {
        RemoteWorkerOpts {
            prefetch_k: 8,
            cache_mb: 0,
            connect_window: Duration::from_secs(20),
            drop_link_after: None,
            heartbeat: PING_INTERVAL,
        }
    }
}

/// SIGTERM → graceful drain. The handler only flips a flag; the
/// worker channel notices between tasks and synthesizes
/// [`Down::Drain`], so a `kill <pid>` (or an orchestrator's stop)
/// finishes the in-flight task, returns queued work to the leader,
/// and exits clean — the CLI-less half of the `bts drain` path.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static DRAIN: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_term(_sig: i32) {
        DRAIN.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        extern "C" {
            // libc's `signal(2)` — the crate has no libc dependency,
            // so bind the symbol directly (fn pointers are word-sized
            // on every supported target).
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_term as extern "C" fn(i32) as usize);
        }
    }

    pub fn requested() -> bool {
        DRAIN.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sig {
    pub fn install() {}

    pub fn requested() -> bool {
        false
    }
}

/// The session's shared framed writer: both planes (task results up,
/// DFS traffic out) funnel through it, so payload frames ride the
/// vectored zero-copy path and the worker-side [`NetCounters`] see
/// every byte.
type SessionWriter = Arc<Mutex<FramedWriter<BufWriter<TcpStream>>>>;

/// A DFS answer routed off the socket by the reader thread.
enum DfsReply {
    Block { key: String, data: Arc<Vec<u8>> },
    Miss { key: String, message: String },
}

/// Leader-proxied block fetches: `DfsGet` out, `DfsBlock`/`DfsMiss`
/// back, with an optional local cache in front. The worker body is
/// single-threaded, so at most one fetch is outstanding; stale
/// replies (from an earlier timed-out request) are skipped by key.
pub struct RemoteDfs {
    wr: SessionWriter,
    resp: Mutex<mpsc::Receiver<DfsReply>>,
    cache: Option<BlockCache>,
}

impl RemoteDfs {
    fn new(
        wr: SessionWriter,
        resp: mpsc::Receiver<DfsReply>,
        cache_mb: usize,
    ) -> RemoteDfs {
        RemoteDfs {
            wr,
            resp: Mutex::new(resp),
            cache: (cache_mb > 0).then(|| BlockCache::new(cache_mb << 20, 4)),
        }
    }

    /// Publish a block into the leader's replicated store. The caller
    /// keeps its `Arc`; the bytes go onto the wire vectored, straight
    /// from the shared buffer — no staging copy.
    pub fn put(&self, key: &str, data: Arc<Vec<u8>>) -> Result<()> {
        let mut g = self
            .wr
            .lock()
            .map_err(|_| Error::Dfs("writer poisoned".into()))?;
        g.send(&Message::DfsPut { key: key.to_string(), data })
    }
}

impl BlockSource for RemoteDfs {
    fn get_traced(
        &self,
        key: &str,
    ) -> Result<(Arc<Vec<u8>>, f64, CacheLookup)> {
        let t = Instant::now();
        let epoch = if let Some(c) = &self.cache {
            if let Some(data) = c.get(key) {
                return Ok((
                    data,
                    t.elapsed().as_secs_f64(),
                    CacheLookup::Hit,
                ));
            }
            Some(c.key_epoch(key))
        } else {
            None
        };
        {
            let mut g = self
                .wr
                .lock()
                .map_err(|_| Error::Dfs("writer poisoned".into()))?;
            g.send(&Message::DfsGet { key: key.to_string() })?;
        }
        let rx = self
            .resp
            .lock()
            .map_err(|_| Error::Dfs("response channel poisoned".into()))?;
        let deadline = Instant::now() + DFS_FETCH_TIMEOUT;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(Error::Dfs(format!(
                    "remote fetch of {key} timed out after {DFS_FETCH_TIMEOUT:?}"
                )));
            }
            match rx.recv_timeout(left) {
                Ok(DfsReply::Block { key: k, data }) if k == key => {
                    let lookup = match (&self.cache, epoch) {
                        (Some(c), Some(e)) => {
                            c.fill(key, &data, e);
                            CacheLookup::Miss
                        }
                        _ => CacheLookup::Unattached,
                    };
                    return Ok((data, t.elapsed().as_secs_f64(), lookup));
                }
                Ok(DfsReply::Miss { key: k, message }) if k == key => {
                    return Err(Error::Dfs(message));
                }
                Ok(_) => continue, // stale answer to a timed-out fetch
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(Error::Dfs(format!(
                        "link died while fetching {key}"
                    )))
                }
            }
        }
    }

    fn cache_purge_prefix(&self, prefix: &str) {
        if let Some(c) = &self.cache {
            c.purge_prefix(prefix);
        }
    }
}

/// The worker's end of a TCP link. Receives are fed by the reader
/// thread; sends are framed writes through the shared writer.
struct TcpWorkerChannel {
    rx: mpsc::Receiver<Down>,
    wr: SessionWriter,
    /// Raw handle for the disconnect fault injection.
    stream: TcpStream,
    dones_sent: u64,
    drop_link_after: Option<u64>,
    /// SIGTERM drain already synthesized (once is enough — the body
    /// exits on it).
    drain_sent: bool,
}

impl TcpWorkerChannel {
    /// A pending SIGTERM becomes one synthesized [`Down::Drain`].
    fn take_signal(&mut self) -> Option<Down> {
        if sig::requested() && !self.drain_sent {
            self.drain_sent = true;
            return Some(Down::Drain);
        }
        None
    }
}

impl WorkerChannel for TcpWorkerChannel {
    fn try_recv(&mut self) -> Poll {
        if let Some(d) = self.take_signal() {
            return Poll::Msg(d);
        }
        match self.rx.try_recv() {
            Ok(d) => Poll::Msg(d),
            Err(mpsc::TryRecvError::Empty) => Poll::Empty,
            Err(mpsc::TryRecvError::Disconnected) => Poll::Closed,
        }
    }

    fn recv(&mut self) -> Option<Down> {
        // Poll-bounded block so a SIGTERM that lands while the slot is
        // idle still drains promptly.
        loop {
            if let Some(d) = self.take_signal() {
                return Some(d);
            }
            match self.rx.recv_timeout(Duration::from_millis(100)) {
                Ok(d) => return Some(d),
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => return None,
            }
        }
    }

    fn send(&mut self, up: Up) -> bool {
        // Batched acks count every item inside the frame, so the
        // fault-injection cap means the same thing with batching on:
        // at most `cap` completions ever reach the leader.
        let dones = match &up {
            Up::Done { .. } => 1,
            Up::DoneBatch(items) => items.len() as u64,
            _ => 0,
        };
        if dones > 0 {
            if let Some(cap) = self.drop_link_after {
                if self.dones_sent + dones > cap {
                    // Injected crash: sever the link instead of
                    // reporting the result(s).
                    let _ = self.stream.shutdown(std::net::Shutdown::Both);
                    return false;
                }
            }
            self.dones_sent += dones;
        }
        let Ok(mut g) = self.wr.lock() else { return false };
        g.send(&Message::Up(up)).is_ok()
    }
}

fn connect_retry(addr: &str, window: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + window;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() > deadline {
                    return Err(Error::Io(e));
                }
                thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Connect to a leader at `addr`, handshake, and serve one session of
/// the shared worker body over the link. Returns the number of tasks
/// executed (the session ends when the leader sends `Shutdown` or the
/// link dies).
pub fn run_remote_worker(
    addr: &str,
    backend: Arc<Backend>,
    opts: &RemoteWorkerOpts,
) -> Result<u64> {
    sig::install();
    let stream = connect_retry(addr, opts.connect_window)?;
    configure_stream(&stream)?;
    let mut rd = BufReader::new(stream.try_clone()?);
    // The worker keeps its own wire counters — they feed nothing
    // today (reports are leader-side) but keep the writer honest and
    // debuggable without a global static.
    let counters = Arc::new(NetCounters::default());
    let wr: SessionWriter = Arc::new(Mutex::new(FramedWriter::new(
        BufWriter::new(stream.try_clone()?),
        counters,
    )));
    {
        // Poisoned-lock paths must exit the session as an error, not
        // a panic — the worker loop may be wrapped in a respawner.
        let mut g = wr.lock().map_err(|_| {
            Error::Protocol(
                "session writer lock poisoned before Hello".into(),
            )
        })?;
        g.send(&Message::Hello { worker: 0 })?;
    }
    let worker = match Message::read_deadline(
        &mut rd,
        Some(HANDSHAKE_TIMEOUT),
    )? {
        Message::Welcome { worker } => worker as usize,
        Message::Error { message } => return Err(Error::Protocol(message)),
        other => {
            return Err(Error::Protocol(format!(
                "expected Welcome, got {other:?}"
            )))
        }
    };
    let (down_tx, down_rx) = mpsc::channel::<Down>();
    let (resp_tx, resp_rx) = mpsc::channel::<DfsReply>();
    // Pinger: heartbeat on a dedicated timer thread, so the leader's
    // idle clock keeps running even while the body is deep in a long
    // task. Exits when the link dies (write failure). Detached — its
    // next tick notices the closed socket after the session ends.
    {
        let ping_wr = wr.clone();
        let heartbeat = opts.heartbeat;
        thread::Builder::new()
            .name(format!("bts-remote-ping-{worker}"))
            .spawn(move || loop {
                thread::sleep(heartbeat);
                let Ok(mut g) = ping_wr.lock() else { return };
                if g.send(&Message::Ping).is_err() {
                    return;
                }
            })
            .map_err(|e| {
                Error::Scheduler(format!("spawn remote pinger: {e}"))
            })?;
    }
    // Reader: split the socket into control and data-plane channels.
    // Exits on link death or protocol garbage; dropping `down_tx`
    // wakes the body out of its blocking recv. Detached on purpose —
    // it unblocks only when the leader closes its end, which may be
    // after the body has already returned on an error path.
    thread::Builder::new()
        .name(format!("bts-remote-reader-{worker}"))
        .spawn(move || {
            // Per-session frame reader: control payloads decode into a
            // reused scratch buffer; DFS block bytes land once in the
            // `Arc` that the cache and kernel will share.
            let mut frames = FrameReader::new();
            loop {
                match frames.read(&mut rd, None) {
                    Ok(Message::Down(d)) => {
                        if down_tx.send(d).is_err() {
                            return;
                        }
                    }
                    Ok(Message::DfsBlock { key, data }) => {
                        if resp_tx
                            .send(DfsReply::Block { key, data })
                            .is_err()
                        {
                            return;
                        }
                    }
                    Ok(Message::DfsMiss { key, message }) => {
                        if resp_tx
                            .send(DfsReply::Miss { key, message })
                            .is_err()
                        {
                            return;
                        }
                    }
                    // Tolerated, though leaders don't ping.
                    Ok(Message::Ping) => {}
                    Ok(_) | Err(_) => return,
                }
            }
        })
        .map_err(|e| {
            Error::Scheduler(format!("spawn remote reader: {e}"))
        })?;
    let source: Arc<dyn BlockSource> =
        Arc::new(RemoteDfs::new(wr.clone(), resp_rx, opts.cache_mb));
    let mut chan = TcpWorkerChannel {
        rx: down_rx,
        wr,
        stream,
        dones_sent: 0,
        drop_link_after: opts.drop_link_after,
        drain_sent: false,
    };
    let cfg = BodyCfg {
        worker,
        prefetch_k: opts.prefetch_k,
        failure: None,
        survive_task_errors: true,
        affinity: None,
        turbulence: None,
    };
    let params = backend.manifest().params.clone();
    Ok(super::worker_body(&cfg, &params, &backend, source, &mut chan))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_opts_are_sane() {
        let o = RemoteWorkerOpts::default();
        assert!(o.prefetch_k >= 1);
        assert_eq!(o.cache_mb, 0);
        assert!(o.drop_link_after.is_none());
        assert!(o.connect_window > Duration::ZERO);
    }

    #[test]
    fn connect_retry_times_out_on_dead_addr() {
        // Port 1 on loopback: nothing listens there in CI.
        let err = connect_retry(
            "127.0.0.1:1",
            Duration::from_millis(50),
        )
        .unwrap_err();
        assert!(matches!(err, Error::Io(_)), "{err}");
    }

    // Full-session behavior (handshake, task execution, DFS-proxied
    // fetches, disconnect recovery) is covered end to end in
    // rust/tests/integration_transport.rs.
}
