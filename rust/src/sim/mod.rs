//! Cluster/event simulation — the testbed replacement for the §4
//! experiments that need 72-core clusters, 1 Gb/s networks, and
//! heterogeneous/virtualized hardware (DESIGN.md §2).

pub mod cluster;
pub mod engine;
pub mod reduce_model;

pub use cluster::{Cluster, HardwareType, NodeSpec, VIRT_SLOWDOWN};
pub use engine::{simulate, SimParams, SimResult};
pub use reduce_model::{
    reduce_phase, shuffle_bytes, sweep_reduce_tasks, ReduceParams,
};

use crate::cachesim::CacheConfig;
use crate::data::Workload;
use crate::kneepoint::{self, CurvePoint};

/// Build the cache-penalty curve for `simulate` from the offline profile:
/// normalized CPI as a function of task size (≥ 1.0 at the minimum).
///
/// Results are memoized process-wide: the offline profile is a pure
/// function of (workload, cache geometry), and figure generators /
/// the SLO planner request it hundreds of times (perf pass, see
/// EXPERIMENTS.md §Perf).
pub fn penalty_curve(workload: Workload, cache: &CacheConfig) -> Vec<CurvePoint> {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    type Key = (Workload, usize, usize);
    static CACHE: OnceLock<Mutex<HashMap<Key, Vec<CurvePoint>>>> =
        OnceLock::new();
    let key = (workload, cache.l2_bytes, cache.l3_bytes);
    let map = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(v) = map.lock().unwrap().get(&key) {
        return v.clone();
    }
    let profile = kneepoint::profile_workload(
        workload,
        cache,
        &kneepoint::default_sizes(),
        None,
    );
    // Per-workload base CPI: the profiler's `cpi` assumes every retired
    // instruction costs 1 cycle of non-memory work (`cpi(1.0)` = 1 +
    // memory stalls/instr). The legacy EAGLET pipeline retires far more
    // compute per memory touch (MERLIN's likelihood math) than the Bash
    // Netflix scripts, which damps how much the cache knee shows up in
    // *runtime*. Chosen so the sim reproduces the paper's runtime
    // ratios: Fig 4's modest +15–23% knee gain and Fig 8's 10–90% BTS
    // margin over BLT (never the raw 35×/1000× AMAT figures — those are
    // per-access, not per-second).
    let base_cpi = match workload {
        Workload::Eaglet => 12.0,
        Workload::NetflixHi | Workload::NetflixLo => 5.0,
        // Executed-only kernels: scan-shaped, Netflix-like stall mix.
        Workload::SeqAddr | Workload::Ssag => 5.0,
    };
    let extra = base_cpi - 1.0;
    let min_cpi = profile
        .points
        .iter()
        .map(|p| p.cpi)
        .fold(f64::INFINITY, f64::min)
        .max(1e-9);
    let curve: Vec<CurvePoint> = profile
        .points
        .iter()
        .map(|p| CurvePoint {
            task_bytes: p.task_bytes,
            miss_rate: ((extra + p.cpi) / (extra + min_cpi)).max(1.0),
        })
        .collect();
    map.lock().unwrap().insert(key, curve.clone());
    curve
}

/// Default SimParams for a workload at a given job size, using the
/// Sandy-Bridge profile and calibration constants measured from the real
/// runtime (see `workloads::calibration`).
pub fn default_params(
    workload: Workload,
    job_bytes: usize,
    compute_s_per_mib: f64,
) -> SimParams {
    let cache = CacheConfig::sandy_bridge();
    // Sample sizes at the thesis's scale: a bi-polar-study family is
    // 230 MB / 400 ≈ 575 KB and a tiniest task is one family-subsample
    // ("30 x 400 families could run in its own map slot"); a Netflix
    // movie is 118 KB (§4.1.1.2). `components` is the per-task software
    // launch count; `remote_read_frac` reproduces Fig 12's 45%-of-1Gb/s
    // at 1 TB.
    let (sample_bytes, reduce, components, frac) = match workload {
        Workload::Eaglet => (576 * 1024, ReduceParams::eaglet_like(), 6, 0.40),
        Workload::NetflixHi => (118 * 1024, ReduceParams::netflix_like(), 1, 0.30),
        Workload::NetflixLo => (118 * 1024, ReduceParams::netflix_like(), 1, 0.30),
        // One bare f32 series per sample (sa_len/ssag_len defaults);
        // single-binary kernels shaped like the Netflix reduce.
        Workload::SeqAddr => (2 * 1024, ReduceParams::netflix_like(), 1, 0.30),
        Workload::Ssag => (1024, ReduceParams::netflix_like(), 1, 0.30),
    };
    SimParams {
        job_bytes,
        sample_bytes,
        compute_s_per_mib,
        penalty: penalty_curve(workload, &cache),
        kneepoint_bytes: kneepoint::kneepoint_bytes(workload, &cache),
        remote_read_frac: frac,
        reduce,
        outliers: workload == Workload::Eaglet,
        components,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn penalty_curve_is_normalized_and_rising() {
        let c = penalty_curve(Workload::Eaglet, &CacheConfig::sandy_bridge());
        assert!(!c.is_empty());
        assert!(c.iter().all(|p| p.miss_rate >= 1.0));
        let first = c.first().unwrap().miss_rate;
        let last = c.last().unwrap().miss_rate;
        assert!(last > first, "penalty should grow with task size");
    }
}
