//! Reduce/shuffle phase model (Fig 16), after Zhang et al. [41].
//!
//! §4.2.4: "The BashReduce platform does not support multiple reduce
//! slots gracefully ... We used simulation to understand the impact of
//! multiple reduce stages, and corresponding communication delay. We
//! used formulas from [41] ... calibrated with average map time, reduce
//! time, and shuffle time from our experiments with 1-node map reduce."
//!
//! Model: with `r` reduce tasks,
//!   shuffle(r) = (intermediate bytes × fanout(r)) / network
//!   reduce(r)  = reduce_work / min(r, cores) + r × reduce_task_overhead
//! EAGLET is compute-heavy (intermediate data small ⇒ diminishing
//! returns immediately); Netflix moves real intermediate volume and
//! benefits from parallel reduce before communication wins.
//!
//! Since PR 6 this model is the *analytical counterpart of an
//! executed stage*: `crate::reduce` + `ExecConfig::reduce_tasks` run
//! the shuffle and the reduce partitions for real on the worker pool.
//! `rust/tests/integration_reduce.rs` cross-validates the two in
//! direction (zero network demand at r=1, shuffle bytes
//! non-decreasing in r); DESIGN.md §13 documents why absolute
//! seconds/bytes are deliberately not compared (thesis-era hardware
//! constants here vs real in-memory fragment movement there).

use super::cluster::Cluster;
use crate::platforms::PlatformSpec;

#[derive(Debug, Clone)]
pub struct ReduceParams {
    /// Intermediate bytes produced per input byte.
    pub intermediate_ratio: f64,
    /// Reduce compute seconds per MiB of *input* (aggregated work).
    pub reduce_s_per_mib: f64,
    /// Number of reduce tasks.
    pub reduce_tasks: usize,
}

impl ReduceParams {
    /// EAGLET: tiny ALOD grids — "secondary genetic analysis is compute
    /// intensive; adding reduce tasks quickly exhibits diminishing
    /// returns".
    pub fn eaglet_like() -> Self {
        ReduceParams {
            intermediate_ratio: 0.002,
            reduce_s_per_mib: 0.0002,
            reduce_tasks: 1,
        }
    }

    /// Netflix: per-movie/month stat tensors are a real fraction of the
    /// input — "the Netflix workload, however, can speed up at the reduce
    /// stage".
    pub fn netflix_like() -> Self {
        ReduceParams {
            intermediate_ratio: 0.08,
            reduce_s_per_mib: 0.012,
            reduce_tasks: 1,
        }
    }

    pub fn with_reduce_tasks(mut self, r: usize) -> Self {
        self.reduce_tasks = r.max(1);
        self
    }
}

/// Shuffle bytes that cross the network for `r` reduce tasks: each mapper
/// partition reaches every reducer; with more reducers a larger share of
/// intermediate data is non-local (1 - 1/r stays remote).
pub fn shuffle_bytes(p: &ReduceParams, job_bytes: usize) -> f64 {
    let inter = job_bytes as f64 * p.intermediate_ratio;
    let r = p.reduce_tasks as f64;
    inter * (1.0 - 1.0 / r).max(0.0) + inter * 0.05 // +local serialization
}

/// (shuffle_s, reduce_s) for a job.
pub fn reduce_phase(
    p: &ReduceParams,
    job_bytes: usize,
    cluster: &Cluster,
    platform: &PlatformSpec,
) -> (f64, f64) {
    let capacity = cluster.network_gbps * 1e9 / 8.0;
    let shuffle_s = shuffle_bytes(p, job_bytes) / capacity;
    let job_mib = job_bytes as f64 / (1024.0 * 1024.0);
    let work = job_mib * p.reduce_s_per_mib;
    let r = p.reduce_tasks.min(cluster.total_cores()).max(1);
    let reduce_s = work / r as f64
        + p.reduce_tasks as f64 * platform.per_task_overhead_s(0.1);
    (shuffle_s, reduce_s)
}

/// Fig-16 sweep: total reduce-phase time and network demand vs r.
pub fn sweep_reduce_tasks(
    base: &ReduceParams,
    job_bytes: usize,
    cluster: &Cluster,
    platform: &PlatformSpec,
    rs: &[usize],
) -> Vec<(usize, f64, f64)> {
    rs.iter()
        .map(|&r| {
            let p = base.clone().with_reduce_tasks(r);
            let (s, d) = reduce_phase(&p, job_bytes, cluster, platform);
            (r, s + d, shuffle_bytes(&p, job_bytes))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms::PlatformSpec;
    use crate::sim::cluster::{Cluster, HardwareType};

    fn cluster() -> Cluster {
        Cluster::homogeneous(HardwareType::TypeII, 6)
    }

    #[test]
    fn eaglet_reduce_has_diminishing_returns() {
        let job = 1 << 30; // 1 GiB
        let sweep = sweep_reduce_tasks(
            &ReduceParams::eaglet_like(),
            job,
            &cluster(),
            &PlatformSpec::bts(),
            &[1, 2, 4, 8, 16, 32],
        );
        // best r is small; r=32 is worse than r=2
        let t2 = sweep.iter().find(|s| s.0 == 2).unwrap().1;
        let t32 = sweep.iter().find(|s| s.0 == 32).unwrap().1;
        assert!(t32 >= t2, "eaglet should not keep improving: {t2} vs {t32}");
    }

    #[test]
    fn netflix_reduce_benefits_then_saturates() {
        let job = 1 << 30;
        let sweep = sweep_reduce_tasks(
            &ReduceParams::netflix_like(),
            job,
            &cluster(),
            &PlatformSpec::bts(),
            &[1, 2, 4, 8, 16, 64],
        );
        let t1 = sweep[0].1;
        let t8 = sweep.iter().find(|s| s.0 == 8).unwrap().1;
        assert!(t8 < t1 * 0.6, "netflix should speed up: {t1} -> {t8}");
    }

    #[test]
    fn network_demand_increases_with_reducers() {
        let p = ReduceParams::netflix_like();
        let job = 1 << 30;
        let b1 = shuffle_bytes(&p.clone().with_reduce_tasks(1), job);
        let b8 = shuffle_bytes(&p.clone().with_reduce_tasks(8), job);
        let b64 = shuffle_bytes(&p.with_reduce_tasks(64), job);
        assert!(b1 < b8 && b8 < b64, "Fig 16: demand must grow");
    }
}
