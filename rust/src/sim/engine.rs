//! Discrete-event job simulator: list-scheduling of map tasks over
//! heterogeneous cores + a shared-network model + the reduce-phase model.
//!
//! This is the testbed replacement (DESIGN.md §2): per-task compute cost
//! is calibrated from *measured* PJRT execution of the real kernels, the
//! cache penalty curve comes from the cache simulator (Fig 2), and the
//! platform overhead constants from `platforms::spec`. Whole-job effects
//! — startup amortization, knee benefits, heterogeneity, network caps,
//! crossovers vs job size — then *emerge*.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::cluster::Cluster;
use super::reduce_model::{reduce_phase, ReduceParams};
use crate::kneepoint::{pack, CurvePoint, TaskSizing};
use crate::data::SampleMeta;
use crate::platforms::{PlatformSpec, SizingKind};

/// Workload-side inputs to the simulator.
#[derive(Debug, Clone)]
pub struct SimParams {
    /// Total job input bytes.
    pub job_bytes: usize,
    /// Mean bytes per sample (tasks hold whole samples).
    pub sample_bytes: usize,
    /// Compute seconds per MiB of input on a reference (Type II) core at
    /// the *best* task size — calibrate from real kernel runs.
    pub compute_s_per_mib: f64,
    /// Cache-penalty curve: multiplier ≥ 1 on compute time as a function
    /// of task size (from `kneepoint::Profile::cpi` normalized).
    pub penalty: Vec<CurvePoint>,
    /// Kneepoint task size (bytes) the platform would choose under
    /// `SizingKind::Kneepoint`.
    pub kneepoint_bytes: usize,
    /// Fraction of input each task re-reads over the network when its
    /// data is not node-local (BashReduce stages locally; Hadoop reads
    /// HDFS).
    pub remote_read_frac: f64,
    pub reduce: ReduceParams,
    /// Heavy-tailed sample sizes (outliers) — when false all samples are
    /// `sample_bytes`.
    pub outliers: bool,
    /// Software components launched per map task (§4.1.2: EAGLET spans
    /// >5 packages in 3 languages; Netflix is one Bash script). Each
    /// component pays the platform's launch cost — this is why tiniest
    /// tasks hurt EAGLET more than Netflix (Fig 8).
    pub components: usize,
}

impl SimParams {
    /// Interpolate the penalty curve at `task_bytes` (flat extrapolation).
    pub fn penalty_at(&self, task_bytes: usize) -> f64 {
        let c = &self.penalty;
        if c.is_empty() {
            return 1.0;
        }
        if task_bytes <= c[0].task_bytes {
            return c[0].miss_rate;
        }
        for w in c.windows(2) {
            if task_bytes <= w[1].task_bytes {
                let t = (task_bytes - w[0].task_bytes) as f64
                    / (w[1].task_bytes - w[0].task_bytes).max(1) as f64;
                return w[0].miss_rate + t * (w[1].miss_rate - w[0].miss_rate);
            }
        }
        c.last().unwrap().miss_rate
    }
}

#[derive(Debug, Clone)]
pub struct SimResult {
    pub startup_s: f64,
    pub map_s: f64,
    pub shuffle_s: f64,
    pub reduce_s: f64,
    pub total_s: f64,
    pub tasks: usize,
    pub task_bytes: usize,
    pub network_utilization: f64,
    pub throughput_mbs: f64,
}

/// Build the synthetic sample list for the job.
///
/// Sample granularity is capped: a 1 TB job at 4.6 KB/sample would mean
/// 230 M metas, which only costs memory without changing any modeled
/// ratio — above the cap we coarsen samples (several real samples per
/// meta), keeping sample_bytes ≪ the kneepoint so packing behaviour is
/// preserved.
fn synth_samples(p: &SimParams) -> Vec<SampleMeta> {
    const MAX_SAMPLES: usize = 1 << 20;
    let coarse = p.job_bytes / MAX_SAMPLES;
    // never coarsen past a quarter-kneepoint: multi-sample packing at the
    // knee must stay representative
    let cap = (p.kneepoint_bytes / 4).max(p.sample_bytes);
    let sample_bytes = coarse.clamp(p.sample_bytes, cap);
    let n = (p.job_bytes / sample_bytes).max(1);
    let mut metas: Vec<SampleMeta> = (0..n as u64)
        .map(|id| SampleMeta { id, bytes: sample_bytes, units: 1 })
        .collect();
    if p.outliers && n >= 3 {
        metas[0].bytes = p.sample_bytes * 15; // the thesis's 15× sample
        metas[1].bytes = p.sample_bytes * 7; //  and the 7× sample
    }
    metas
}

/// Map the platform's sizing policy onto packing.
fn sizing_for(platform: &PlatformSpec, p: &SimParams, slots: usize) -> TaskSizing {
    match platform.sizing {
        SizingKind::Kneepoint => TaskSizing::Kneepoint(p.kneepoint_bytes),
        SizingKind::Large => TaskSizing::LargeSn { workers: slots },
        SizingKind::Tiniest => TaskSizing::Tiniest,
        SizingKind::Fixed(b) => TaskSizing::Fixed(b),
    }
}

/// Simulate one job end to end.
pub fn simulate(
    platform: &PlatformSpec,
    cluster: &Cluster,
    p: &SimParams,
) -> SimResult {
    let slots = cluster.total_cores();
    let metas = synth_samples(p);
    let tasks = pack(&metas, sizing_for(platform, p, slots));
    let mean_task_bytes = (tasks.iter().map(|t| t.bytes).sum::<usize>()
        / tasks.len().max(1))
    .max(1);

    // --- map phase: list-schedule tasks onto cores ----------------------
    // BinaryHeap of Reverse<(free_time_ns, core)> — earliest-free core
    // next; models BTS's queue-driven workers / Hadoop's slot scheduler
    // and "round robin scheduler skipped over busy, slower cores".
    let speeds = cluster.core_speeds();
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> =
        (0..slots).map(|c| Reverse((0u64, c))).collect();
    let mut map_end: u64 = 0;
    for t in &tasks {
        let Reverse((free_ns, core)) = heap.pop().unwrap();
        let mib = t.bytes as f64 / (1024.0 * 1024.0);
        let compute =
            mib * p.compute_s_per_mib * p.penalty_at(t.bytes) / speeds[core];
        let overhead = platform.per_task_overhead_s(mib)
            + platform.launch_per_task_s * (p.components.max(1) - 1) as f64;
        let dur_ns = ((compute + overhead) * 1e9) as u64;
        let end = free_ns + dur_ns;
        map_end = map_end.max(end);
        heap.push(Reverse((end, core)));
    }
    let mut map_s = map_end as f64 / 1e9;

    // --- network: shared-link cap ---------------------------------------
    // Bytes that cross the network during the map phase: remote reads
    // (+ speculative duplicates on VH).
    let mut moved = p.job_bytes as f64 * p.remote_read_frac;
    if platform.speculative {
        moved *= 1.10; // duplicate launches re-read ~10% of input
    }
    let capacity_bytes_s = cluster.network_gbps * 1e9 / 8.0;
    let net_time = moved / capacity_bytes_s;
    let network_utilization = if map_s > 0.0 {
        (net_time / map_s).min(1.0)
    } else {
        0.0
    };
    if net_time > map_s {
        map_s = net_time; // network-bound region (Fig 12 flattening)
    }

    // --- shuffle + reduce -------------------------------------------------
    let (shuffle_s, reduce_s) =
        reduce_phase(&p.reduce, p.job_bytes, cluster, platform);

    let startup_s = platform.startup_s(slots);
    let total_s = startup_s + map_s + shuffle_s + reduce_s;
    SimResult {
        startup_s,
        map_s,
        shuffle_s,
        reduce_s,
        total_s,
        tasks: tasks.len(),
        task_bytes: mean_task_bytes,
        network_utilization,
        throughput_mbs: p.job_bytes as f64 / (1024.0 * 1024.0) / total_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms::PlatformSpec;
    use crate::sim::cluster::HardwareType;

    fn params(job_mb: usize) -> SimParams {
        SimParams {
            job_bytes: job_mb * 1024 * 1024,
            sample_bytes: 64 * 1024,
            compute_s_per_mib: 0.2,
            penalty: vec![
                CurvePoint { task_bytes: 1 << 20, miss_rate: 1.0 },
                CurvePoint { task_bytes: 4 << 20, miss_rate: 1.3 },
                CurvePoint { task_bytes: 24 << 20, miss_rate: 3.0 },
            ],
            kneepoint_bytes: 2 * 1024 * 1024,
            remote_read_frac: 0.1,
            reduce: ReduceParams::eaglet_like(),
            outliers: false,
            components: 1,
        }
    }

    fn cluster() -> Cluster {
        Cluster::homogeneous(HardwareType::TypeII, 6)
    }

    #[test]
    fn penalty_interpolates() {
        let p = params(100);
        assert_eq!(p.penalty_at(512 * 1024), 1.0);
        let mid = p.penalty_at(2 * 1024 * 1024 + 512 * 1024);
        assert!((1.0..1.3).contains(&mid));
        assert_eq!(p.penalty_at(100 << 20), 3.0);
    }

    #[test]
    fn bts_beats_vanilla_hadoop_on_small_jobs() {
        let p = params(12);
        let bts = simulate(&PlatformSpec::bts(), &cluster(), &p);
        let vh = simulate(&PlatformSpec::vanilla_hadoop(), &cluster(), &p);
        let speedup = vh.total_s / bts.total_s;
        assert!(
            speedup > 2.5,
            "BTS should dominate VH on 12MB jobs, got {speedup:.2}x"
        );
    }

    #[test]
    fn speedup_shrinks_with_job_size() {
        let small = params(12);
        let large = params(4096);
        let c = cluster();
        let s_small = simulate(&PlatformSpec::vanilla_hadoop(), &c, &small)
            .total_s
            / simulate(&PlatformSpec::bts(), &c, &small).total_s;
        let s_large = simulate(&PlatformSpec::vanilla_hadoop(), &c, &large)
            .total_s
            / simulate(&PlatformSpec::bts(), &c, &large).total_s;
        assert!(
            s_small > s_large,
            "startup amortization should shrink the gap: {s_small} vs {s_large}"
        );
        assert!(s_large > 1.0, "BTS keeps winning via task sizing");
    }

    #[test]
    fn kneepoint_beats_large_and_tiniest() {
        let p = params(512);
        let c = cluster();
        let bts = simulate(&PlatformSpec::bts(), &c, &p).total_s;
        let blt = simulate(&PlatformSpec::blt(), &c, &p).total_s;
        let btt = simulate(&PlatformSpec::btt(), &c, &p).total_s;
        assert!(bts < blt, "bts {bts} vs blt {blt}");
        assert!(bts < btt, "bts {bts} vs btt {btt}");
    }

    #[test]
    fn more_cores_help_until_startup_dominates() {
        let p = params(16 * 1024);
        let t12 = simulate(
            &PlatformSpec::bts(),
            &Cluster::homogeneous(HardwareType::TypeII, 1),
            &p,
        )
        .total_s;
        let t72 = simulate(&PlatformSpec::bts(), &cluster(), &p).total_s;
        assert!(t72 < t12 / 3.0, "should scale: 12c {t12} vs 72c {t72}");

        // tiny job: scaling out stops helping
        let tiny = params(4);
        let t12 = simulate(
            &PlatformSpec::bts(),
            &Cluster::homogeneous(HardwareType::TypeII, 1),
            &tiny,
        )
        .total_s;
        let t72 = simulate(&PlatformSpec::bts(), &cluster(), &tiny).total_s;
        assert!(
            t72 > t12 * 0.5,
            "startup should eat the gain on tiny jobs: {t12} vs {t72}"
        );
    }

    #[test]
    fn heterogeneous_slow_node_hurts_small_jobs_proportionally_less_on_large() {
        let hetero = Cluster::heterogeneous(1, 2); // 12 slow + 64 fast
        let homo = Cluster::homogeneous(HardwareType::TypeIII, 2); // hmm 64
        // compare per-core-normalized runtimes on small vs large jobs
        let small = params(8);
        let large = params(2048);
        let rel = |c: &Cluster, p: &SimParams| {
            simulate(&PlatformSpec::bts(), c, p).total_s
        };
        let small_ratio = rel(&hetero, &small) / rel(&homo, &small);
        let large_ratio = rel(&hetero, &large) / rel(&homo, &large);
        // the slow node's drag is diluted on large jobs (work stealing /
        // more tasks to rebalance)... or at least not worse
        assert!(
            large_ratio <= small_ratio * 1.35 && large_ratio < 1.5,
            "small {small_ratio} large {large_ratio}"
        );
    }

    #[test]
    fn network_cap_flattens_throughput() {
        let mut p = params(8192);
        p.compute_s_per_mib = 0.001; // compute-light => network-bound
        p.remote_read_frac = 1.0;
        let r = simulate(&PlatformSpec::bts(), &cluster(), &p);
        assert!(
            r.network_utilization > 0.9,
            "expected network-bound, util {}",
            r.network_utilization
        );
    }

    #[test]
    fn outliers_slow_the_job() {
        let mut with = params(256);
        with.outliers = true;
        let without = params(256);
        let c = cluster();
        let t_with = simulate(&PlatformSpec::bts(), &c, &with).total_s;
        let t_without = simulate(&PlatformSpec::bts(), &c, &without).total_s;
        assert!(t_with >= t_without, "{t_with} vs {t_without}");
    }
}
