//! Cluster description — the Table-2 hardware types and node sets used
//! throughout §4.
//!
//! | | Type I | Type II | Type III |
//! | Processor | Xeon | Xeon | Opteron |
//! | Cores/Node | 12 | 12 | 32 |
//! | Speed | 2.0G | 2.3G | 2.3G |
//! | L2 | 15MB | 15MB | 32MB |
//! | Memory | 32GB | 32GB | 64GB |
//! | Virtualized | No | No | Yes |

use crate::cachesim::CacheConfig;

/// Virtualization slowdown observed in §4.2.4 ("we observed slowdown of
/// 16% across both workloads" on user-mode Linux VMs).
pub const VIRT_SLOWDOWN: f64 = 0.16;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HardwareType {
    TypeI,
    TypeII,
    TypeIII,
}

impl HardwareType {
    pub fn name(&self) -> &'static str {
        match self {
            HardwareType::TypeI => "Type I (Xeon 12c @2.0GHz)",
            HardwareType::TypeII => "Type II (Xeon 12c @2.3GHz)",
            HardwareType::TypeIII => "Type III (Opteron 32c @2.3GHz, virtualized)",
        }
    }

    pub fn cores(&self) -> usize {
        match self {
            HardwareType::TypeI | HardwareType::TypeII => 12,
            HardwareType::TypeIII => 32,
        }
    }

    pub fn ghz(&self) -> f64 {
        match self {
            HardwareType::TypeI => 2.0,
            _ => 2.3,
        }
    }

    pub fn l2_mb(&self) -> usize {
        match self {
            HardwareType::TypeI | HardwareType::TypeII => 15,
            HardwareType::TypeIII => 32,
        }
    }

    pub fn mem_gb(&self) -> usize {
        match self {
            HardwareType::TypeI | HardwareType::TypeII => 32,
            HardwareType::TypeIII => 64,
        }
    }

    pub fn virtualized(&self) -> bool {
        matches!(self, HardwareType::TypeIII)
    }

    /// Relative core speed vs Type II (the reference testbed): clock
    /// ratio × virtualization penalty.
    pub fn speed_factor(&self) -> f64 {
        let clock = self.ghz() / 2.3;
        if self.virtualized() {
            clock * (1.0 - VIRT_SLOWDOWN)
        } else {
            clock
        }
    }

    /// Cache hierarchy for the kneepoint profiler on this hardware.
    pub fn cache_config(&self) -> CacheConfig {
        match self {
            HardwareType::TypeI | HardwareType::TypeII => {
                CacheConfig::sandy_bridge()
            }
            HardwareType::TypeIII => CacheConfig::opteron(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub hw: HardwareType,
}

#[derive(Debug, Clone)]
pub struct Cluster {
    pub nodes: Vec<NodeSpec>,
    /// Shared interconnect capacity (the §4.2.3 testbed ran on 1 Gb/s).
    pub network_gbps: f64,
}

impl Cluster {
    pub fn homogeneous(hw: HardwareType, nodes: usize) -> Self {
        Cluster {
            nodes: vec![NodeSpec { hw }; nodes],
            network_gbps: 1.0,
        }
    }

    /// The §4.2.4 heterogeneous setup: `slow` Type-I nodes (15% slower)
    /// among Type-III nodes, 60 cores total in the thesis.
    pub fn heterogeneous(slow_nodes: usize, fast_nodes: usize) -> Self {
        let mut nodes = vec![NodeSpec { hw: HardwareType::TypeI }; slow_nodes];
        nodes.extend(vec![
            NodeSpec { hw: HardwareType::TypeIII };
            fast_nodes
        ]);
        Cluster { nodes, network_gbps: 1.0 }
    }

    pub fn total_cores(&self) -> usize {
        self.nodes.iter().map(|n| n.hw.cores()).sum()
    }

    /// Per-core speed factors, flattened (the list scheduler's view).
    pub fn core_speeds(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(self.total_cores());
        for n in &self.nodes {
            for _ in 0..n.hw.cores() {
                v.push(n.hw.speed_factor());
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values() {
        assert_eq!(HardwareType::TypeI.cores(), 12);
        assert_eq!(HardwareType::TypeIII.cores(), 32);
        assert_eq!(HardwareType::TypeI.ghz(), 2.0);
        assert_eq!(HardwareType::TypeIII.l2_mb(), 32);
        assert!(HardwareType::TypeIII.virtualized());
        assert!(!HardwareType::TypeII.virtualized());
    }

    #[test]
    fn speed_factors_ordered() {
        let s1 = HardwareType::TypeI.speed_factor();
        let s2 = HardwareType::TypeII.speed_factor();
        let s3 = HardwareType::TypeIII.speed_factor();
        assert!(s2 > s1, "Type II faster clock than I");
        assert!(s2 > s3, "virtualization should cost Type III");
        assert!((s2 - 1.0).abs() < 1e-12, "Type II is the reference");
    }

    #[test]
    fn cluster_core_accounting() {
        let c = Cluster::homogeneous(HardwareType::TypeII, 6);
        assert_eq!(c.total_cores(), 72); // the thesis's 72-core testbed
        assert_eq!(c.core_speeds().len(), 72);
        let h = Cluster::heterogeneous(1, 2);
        assert_eq!(h.total_cores(), 12 + 64);
    }
}
