//! Run metrics: timers, counters, and job reports.
//!
//! BTS exposes the same signals the thesis reports: startup time,
//! per-task runtime overhead, throughput (MB/s), prefetch hit rate, and
//! the replication factor trajectory. The optional `monitor` feature in
//! the coordinator samples these every second, reproducing the
//! "BTS with monitoring" experiment (§4.2.2).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::{num, obj, s, Json};
use crate::util::stats::{summarize, Summary};

/// Monotonic wall-clock timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Thread-safe f64 accumulator (microsecond resolution).
#[derive(Default)]
pub struct SecsCounter(AtomicU64);

impl SecsCounter {
    pub fn add(&self, secs: f64) {
        self.0
            .fetch_add((secs * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        self.0.load(Ordering::Relaxed) as f64 / 1e6
    }
}

/// Everything a finished job reports (EXPERIMENTS.md rows are printed
/// from these).
#[derive(Debug, Clone)]
pub struct JobReport {
    pub workload: String,
    pub platform: String,
    pub tasks: usize,
    pub samples: usize,
    pub input_bytes: usize,
    pub startup_s: f64,
    pub map_s: f64,
    pub reduce_s: f64,
    pub total_s: f64,
    pub task_exec: Summary,
    pub task_fetch: Summary,
    /// Leader-observed task turnaround: dispatch → first completion.
    /// Unlike `task_exec`/`task_fetch` (worker self-reports), this
    /// includes queue drag and any slowness the worker's own timers
    /// cannot see — the signal the dynamic scheduler reacts to, and
    /// the one speculation improves (a straggler's turnaround is its
    /// winning clone's, not the stuck original's).
    pub task_turnaround: Summary,
    /// Tasks cloned past the straggler threshold (speculation).
    pub speculated: u64,
    /// Speculated tasks whose clone beat the original.
    pub won_by_clone: u64,
    /// Executed reduce partitions (1 = the leader-side seq-ordered
    /// reduce; >1 = a shuffled worker-pool reduce phase).
    pub reduce_tasks: usize,
    /// Intermediate bytes staged into the store by the shuffle
    /// (0 when no shuffle ran).
    pub shuffle_bytes: u64,
    /// Max reduce-partition load over the balanced ideal (1.0 =
    /// perfect balance; the partitioner quality signal).
    pub shuffle_imbalance: f64,
    /// Leader-observed reduce turnaround: dispatch → first completion
    /// per partition (all-zero summary when no shuffle ran).
    pub reduce_turnaround: Summary,
    pub prefetch_hit_rate: f64,
    /// Shared block-cache hit rate over this job's store fetches
    /// (0 when the executor ran without a cache attached).
    pub cache_hit_rate: f64,
    pub final_rf: usize,
    pub restarts: u32,
    /// Data-plane wire counters (see `net::protocol::NetCounters`).
    /// All four stay 0 for in-proc runs — mpsc links are not a wire.
    pub frames_sent: u64,
    /// Tasks/completions that rode a TaskBatch/DoneBatch frame instead
    /// of paying their own frame + flush.
    pub frames_batched: u64,
    pub wire_bytes: u64,
    /// DfsBlock/DfsPut payloads written vectored straight from the
    /// shared `Arc<Vec<u8>>` — no staging copy.
    pub blocks_zero_copy: u64,
}

impl JobReport {
    /// Throughput in MB/s over the whole job (the thesis's headline
    /// metric; 117 Mb/s per 12-core node on EAGLET).
    pub fn throughput_mbs(&self) -> f64 {
        if self.total_s <= 0.0 {
            return 0.0;
        }
        self.input_bytes as f64 / (1024.0 * 1024.0) / self.total_s
    }

    /// Serialize to JSON — the record format `BENCH_*.json` trajectory
    /// entries and `results/exec_baseline.json` are built from.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("workload", s(&self.workload)),
            ("platform", s(&self.platform)),
            ("tasks", num(self.tasks as f64)),
            ("samples", num(self.samples as f64)),
            ("input_bytes", num(self.input_bytes as f64)),
            ("startup_s", num(self.startup_s)),
            ("map_s", num(self.map_s)),
            ("reduce_s", num(self.reduce_s)),
            ("total_s", num(self.total_s)),
            ("throughput_mbs", num(self.throughput_mbs())),
            ("task_exec_p50_s", num(self.task_exec.p50)),
            ("task_exec_p95_s", num(self.task_exec.p95)),
            ("task_fetch_p50_s", num(self.task_fetch.p50)),
            ("task_turnaround_p50_s", num(self.task_turnaround.p50)),
            ("task_turnaround_p99_s", num(self.task_turnaround.p99)),
            ("speculated", num(self.speculated as f64)),
            ("won_by_clone", num(self.won_by_clone as f64)),
            ("reduce_tasks", num(self.reduce_tasks as f64)),
            ("shuffle_bytes", num(self.shuffle_bytes as f64)),
            ("shuffle_imbalance", num(self.shuffle_imbalance)),
            ("reduce_turnaround_p50_s", num(self.reduce_turnaround.p50)),
            ("reduce_turnaround_p99_s", num(self.reduce_turnaround.p99)),
            ("prefetch_hit_rate", num(self.prefetch_hit_rate)),
            ("cache_hit_rate", num(self.cache_hit_rate)),
            ("final_rf", num(self.final_rf as f64)),
            ("restarts", num(self.restarts as f64)),
            ("frames_sent", num(self.frames_sent as f64)),
            ("frames_batched", num(self.frames_batched as f64)),
            ("wire_bytes", num(self.wire_bytes as f64)),
            ("blocks_zero_copy", num(self.blocks_zero_copy as f64)),
        ])
    }

    pub fn render(&self) -> String {
        format!(
            "job[{} on {}] {} tasks / {} samples / {:.2} MB in {:.3}s \
             (startup {:.3}s, map {:.3}s, reduce {:.3}s) => {:.2} MB/s; \
             task exec p50 {:.1}ms p95 {:.1}ms; fetch p50 {:.2}ms; \
             turnaround p99 {:.1}ms; speculated {} (clone won {}); \
             reducers {} (shuffle {:.2} MB, imbalance {:.2}); \
             prefetch hits {:.0}%; cache hits {:.0}%; rf {}; restarts {}",
            self.workload,
            self.platform,
            self.tasks,
            self.samples,
            self.input_bytes as f64 / (1024.0 * 1024.0),
            self.total_s,
            self.startup_s,
            self.map_s,
            self.reduce_s,
            self.throughput_mbs(),
            self.task_exec.p50 * 1e3,
            self.task_exec.p95 * 1e3,
            self.task_fetch.p50 * 1e3,
            self.task_turnaround.p99 * 1e3,
            self.speculated,
            self.won_by_clone,
            self.reduce_tasks,
            self.shuffle_bytes as f64 / (1024.0 * 1024.0),
            self.shuffle_imbalance,
            self.prefetch_hit_rate * 100.0,
            self.cache_hit_rate * 100.0,
            self.final_rf,
            self.restarts,
        )
    }
}

/// Jain's fairness index over non-negative allocations:
/// `(Σx)² / (n·Σx²)`. 1.0 = perfectly even, `1/n` = one tenant got
/// everything. An empty or all-zero slice reports 1.0 (nothing was
/// allocated, so nothing was unfair).
pub fn jain_index(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().sum();
    let sumsq: f64 = xs.iter().map(|x| x * x).sum();
    if n == 0.0 || sumsq == 0.0 {
        1.0
    } else {
        (sum * sum) / (n * sumsq)
    }
}

/// What a federation session reports (DESIGN.md §15): per-leader
/// utilization, shedding, deterministic spillover accounting, and the
/// per-tenant fairness index the DRF queue is gated on.
#[derive(Debug, Clone)]
pub struct FederationReport {
    /// Leader instances the federation started with.
    pub leaders: usize,
    /// Submissions that reached the front-door (before admission).
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    /// Rejected by the front-door's SLO admission gate.
    pub admission_rejected: u64,
    /// Load-shed with a `Shed` retry-after frame.
    pub shed: u64,
    /// Jobs routed to a sibling leader because the home shard was
    /// saturated (deterministic: counted at routing decision time).
    pub spilled: u64,
    /// Jobs re-homed after their leader was killed.
    pub rehomed: u64,
    pub wall_s: f64,
    /// Jobs completed per leader (index = leader id).
    pub leader_completed: Vec<u64>,
    /// Busy fraction per leader: share of front-door sweeps that saw
    /// the leader with at least one active job.
    pub leader_utilization: Vec<f64>,
    /// Distinct tenants seen.
    pub tenants: usize,
    /// Jain's index over per-tenant completed jobs.
    pub fairness: f64,
}

impl FederationReport {
    /// Shed events as a fraction of everything that arrived.
    pub fn shed_rate(&self) -> f64 {
        if self.jobs_submitted == 0 {
            0.0
        } else {
            self.shed as f64 / self.jobs_submitted as f64
        }
    }

    /// SLO misses as the admission gate saw them (rejected at the
    /// door; the fixed-miss-rate axis of `BENCH_federation.json`).
    pub fn slo_miss_rate(&self) -> f64 {
        if self.jobs_submitted == 0 {
            0.0
        } else {
            self.admission_rejected as f64 / self.jobs_submitted as f64
        }
    }

    /// Aggregate completed-job throughput over the session.
    pub fn jobs_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.jobs_completed as f64 / self.wall_s
        }
    }

    /// Flat JSON record for `results/BENCH_federation.json`.
    pub fn metrics_json(&self) -> Json {
        obj(vec![
            ("platform", s("bts-federation")),
            ("leaders", num(self.leaders as f64)),
            ("jobs_submitted", num(self.jobs_submitted as f64)),
            ("jobs_completed", num(self.jobs_completed as f64)),
            ("jobs_failed", num(self.jobs_failed as f64)),
            ("admission_rejected", num(self.admission_rejected as f64)),
            ("shed", num(self.shed as f64)),
            ("shed_rate", num(self.shed_rate())),
            ("slo_miss_rate", num(self.slo_miss_rate())),
            ("spilled", num(self.spilled as f64)),
            ("rehomed", num(self.rehomed as f64)),
            ("wall_s", num(self.wall_s)),
            ("jobs_per_s", num(self.jobs_per_s())),
            ("tenants", num(self.tenants as f64)),
            ("fairness", num(self.fairness)),
            (
                "leader_completed",
                Json::Arr(
                    self.leader_completed
                        .iter()
                        .map(|&c| num(c as f64))
                        .collect(),
                ),
            ),
            (
                "leader_utilization",
                Json::Arr(
                    self.leader_utilization
                        .iter()
                        .map(|&u| num(u))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn render(&self) -> String {
        let util: Vec<String> = self
            .leader_utilization
            .iter()
            .map(|u| format!("{:.0}%", u * 100.0))
            .collect();
        format!(
            "federation[{} leaders] {} submitted, {} completed \
             ({} failed) in {:.2}s => {:.1} jobs/s; rejected {} \
             ({:.0}% miss), shed {} ({:.0}%), spilled {}, rehomed {}; \
             {} tenants, fairness {:.3}; per-leader done {:?}, \
             busy [{}]",
            self.leaders,
            self.jobs_submitted,
            self.jobs_completed,
            self.jobs_failed,
            self.wall_s,
            self.jobs_per_s(),
            self.admission_rejected,
            self.slo_miss_rate() * 100.0,
            self.shed,
            self.shed_rate() * 100.0,
            self.spilled,
            self.rehomed,
            self.tenants,
            self.fairness,
            self.leader_completed,
            util.join(", "),
        )
    }
}

/// Builder used by the coordinator while a job runs.
#[derive(Default)]
pub struct JobMetrics {
    pub exec_times: std::sync::Mutex<Vec<f64>>,
    pub fetch_times: std::sync::Mutex<Vec<f64>>,
    pub prefetch_hits: AtomicU64,
    pub prefetch_misses: AtomicU64,
}

impl JobMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe_exec(&self, secs: f64) {
        self.exec_times.lock().unwrap().push(secs);
    }

    pub fn observe_fetch(&self, secs: f64) {
        self.fetch_times.lock().unwrap().push(secs);
    }

    pub fn exec_summary(&self) -> Summary {
        let v = self.exec_times.lock().unwrap();
        summarize(if v.is_empty() { &[0.0] } else { &v })
    }

    pub fn fetch_summary(&self) -> Summary {
        let v = self.fetch_times.lock().unwrap();
        summarize(if v.is_empty() { &[0.0] } else { &v })
    }

    pub fn hit_rate(&self) -> f64 {
        let h = self.prefetch_hits.load(Ordering::Relaxed) as f64;
        let m = self.prefetch_misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let r = JobReport {
            workload: "eaglet".into(),
            platform: "bts".into(),
            tasks: 10,
            samples: 100,
            input_bytes: 10 * 1024 * 1024,
            startup_s: 0.1,
            map_s: 1.0,
            reduce_s: 0.1,
            total_s: 2.0,
            task_exec: summarize(&[0.01]),
            task_fetch: summarize(&[0.001]),
            task_turnaround: summarize(&[0.02]),
            speculated: 2,
            won_by_clone: 1,
            reduce_tasks: 4,
            shuffle_bytes: 2048,
            shuffle_imbalance: 1.25,
            reduce_turnaround: summarize(&[0.03]),
            prefetch_hit_rate: 0.9,
            cache_hit_rate: 0.5,
            final_rf: 3,
            restarts: 0,
            frames_sent: 0,
            frames_batched: 0,
            wire_bytes: 0,
            blocks_zero_copy: 0,
        };
        assert!((r.throughput_mbs() - 5.0).abs() < 1e-9);
        assert!(r.render().contains("5.00 MB/s"));
        // json round-trips through the parser and keeps the fields
        let j = Json::parse(&r.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.req_str("workload").unwrap(), "eaglet");
        assert_eq!(j.req_usize("tasks").unwrap(), 10);
        assert!((j.req_f64("throughput_mbs").unwrap() - 5.0).abs() < 1e-9);
        assert_eq!(j.req_usize("speculated").unwrap(), 2);
        assert_eq!(j.req_usize("won_by_clone").unwrap(), 1);
        assert!(j.req_f64("task_turnaround_p99_s").is_ok());
        assert_eq!(j.req_usize("reduce_tasks").unwrap(), 4);
        assert_eq!(j.req_usize("shuffle_bytes").unwrap(), 2048);
        assert!((j.req_f64("shuffle_imbalance").unwrap() - 1.25).abs() < 1e-9);
        assert!(j.req_f64("reduce_turnaround_p99_s").is_ok());
        assert!(r.render().contains("reducers 4"));
    }

    #[test]
    fn metrics_accumulate() {
        let m = JobMetrics::new();
        m.observe_exec(0.5);
        m.observe_exec(1.5);
        m.observe_fetch(0.1);
        m.prefetch_hits.store(9, Ordering::Relaxed);
        m.prefetch_misses.store(1, Ordering::Relaxed);
        assert!((m.exec_summary().mean - 1.0).abs() < 1e-9);
        assert!((m.hit_rate() - 0.9).abs() < 1e-9);
    }

    #[test]
    fn secs_counter() {
        let c = SecsCounter::default();
        c.add(0.25);
        c.add(0.25);
        assert!((c.get() - 0.5).abs() < 1e-3);
    }

    #[test]
    fn jain_index_bounds_and_extremes() {
        assert!((jain_index(&[1.0, 1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        // one tenant got everything: index = 1/n
        assert!((jain_index(&[8.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        // empty / all-zero: nothing allocated, reported as fair
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
        let mixed = jain_index(&[3.0, 1.0, 2.0]);
        assert!(mixed > 1.0 / 3.0 && mixed < 1.0);
    }

    #[test]
    fn federation_report_math_and_json() {
        let r = FederationReport {
            leaders: 2,
            jobs_submitted: 20,
            jobs_completed: 14,
            jobs_failed: 1,
            admission_rejected: 2,
            shed: 3,
            spilled: 4,
            rehomed: 2,
            wall_s: 7.0,
            leader_completed: vec![9, 5],
            leader_utilization: vec![0.8, 0.5],
            tenants: 6,
            fairness: 0.91,
        };
        assert!((r.shed_rate() - 0.15).abs() < 1e-12);
        assert!((r.slo_miss_rate() - 0.1).abs() < 1e-12);
        assert!((r.jobs_per_s() - 2.0).abs() < 1e-12);
        let j = Json::parse(&r.metrics_json().to_string_pretty()).unwrap();
        assert_eq!(j.req_usize("leaders").unwrap(), 2);
        assert_eq!(j.req_usize("spilled").unwrap(), 4);
        assert!((j.req_f64("shed_rate").unwrap() - 0.15).abs() < 1e-12);
        assert!((j.req_f64("fairness").unwrap() - 0.91).abs() < 1e-12);
        assert_eq!(j.req_arr("leader_completed").unwrap().len(), 2);
        assert!(r.render().contains("2 leaders"));
        assert!(r.render().contains("spilled 4"));
    }
}
