//! `bts` — the platform launcher.
//!
//! ```text
//! bts repro [--only ID[,ID...]] [--out DIR]     regenerate paper figures
//! bts run [--config FILE] [--set k=v ...]       run a real job end to end
//! bts exec [--workload W] [--cache-mb MB]
//!     [--listen ADDR --workers-remote N] [...]  run via the cluster executor
//! bts suite GRID.toml [--out-dir DIR]           run a declarative scenario grid
//! bts serve [--jobs N] [--workers N]
//!     [--listen ADDR --workers-remote N] [...]  sustained multi-tenant load
//! bts submit [--workload W] [--deadline S]
//!     [--frontdoor ADDR --tenant T]             one job through the service
//! bts frontdoor [--listen ADDR --leaders N]     sharded multi-leader serving
//! bts fedctl stats|kill N|shutdown              control a running front-door
//! bts profile [--workload W]                    offline kneepoint profiling
//! bts calibrate                                 measure sim constants from PJRT
//! bts plan --slo SECONDS [--workload W]         SLO planner (Fig 13 machinery)
//! bts worker --connect ADDR [--cache-mb MB]     serve as a remote map slot
//! bts drain WORKER --connect ADDR               ask a leader to drain a slot
//! bts list                                      list figure ids
//! ```
//!
//! Flags accept both `--name value` and `--name=value`; unknown flags
//! and stray positional arguments are errors, not silence.

use std::sync::Arc;

use bts::cachesim::CacheConfig;
use bts::config::Config;
use bts::coordinator::run_with_recovery;
use bts::data::Workload;
use bts::error::{Error, Result};
use bts::figures::{all, Ctx};
use bts::kneepoint::{
    default_sizes, kneepoint_bytes, profile_workload, smallest_kneepoint,
    KNEE_THRESHOLD,
};
use bts::runtime::Manifest;
use bts::util::cli::Flags;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("repro") => cmd_repro(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("exec") => cmd_exec(&args[1..]),
        Some("suite") => cmd_suite(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("submit") => cmd_submit(&args[1..]),
        Some("frontdoor") => cmd_frontdoor(&args[1..]),
        Some("fedctl") => cmd_fedctl(&args[1..]),
        Some("profile") => cmd_profile(&args[1..]),
        Some("calibrate") => {
            Flags::parse(&args[1..], &[])?;
            cmd_calibrate()
        }
        Some("plan") => cmd_plan(&args[1..]),
        Some("worker") => cmd_worker(&args[1..]),
        Some("drain") => cmd_drain(&args[1..]),
        Some("list") => {
            Flags::parse(&args[1..], &[])?;
            for f in all() {
                println!("{:10} {}", f.id, f.title);
            }
            Ok(())
        }
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => Err(Error::Config(format!(
            "unknown command {other}; see `bts help`"
        ))),
    }
}

const HELP: &str = "\
bts — an efficient and balanced platform for data-parallel subsampling workloads

commands:
  repro [--only IDs] [--out DIR]    regenerate every paper table/figure
  run [--config F] [--set k=v]...   run a real job (PJRT execution)
  exec [--workload W] [--workers N] [--samples N] [--sizing S]
       [--cache-mb MB] [--affinity on|off] [--speculate on|off]
       [--straggler-pct P] [--out-json FILE] [--batch on|off]
       [--reduce-tasks R] [--partitioner hash|skew]
       [--listen ADDR --workers-remote N] [--elastic on|off]
       [--heartbeat-ms MS] [--straggler-poll-ms MS]
                                    run a job through the cluster
                                    executor (native kernels when
                                    artifacts are unavailable); with
                                    --listen, accepts N `bts worker`
                                    processes as extra map slots;
                                    --elastic keeps the listener open
                                    for the whole job: late workers
                                    join mid-job, drained/lost ones
                                    leave with only their in-flight
                                    tasks re-dispatched (task-level
                                    checkpointing, no job restart);
                                    --speculate clones straggling
                                    tasks past the p<P> response-time
                                    threshold (first result wins);
                                    --reduce-tasks > 1 shuffles map
                                    output into R executed reduce
                                    partitions (bit-identical result);
                                    writes results/BENCH_exec.json
  suite GRID.toml [--out-dir DIR]   expand a TOML scenario grid
                                    (workload/transport/cache-mb/
                                    affinity/speculate/batch/
                                    turbulence/reduce-tasks axes; see
                                    ci/suite_smoke.toml) and run every
                                    cell with repetitions through the
                                    cluster executor; hard-errors if
                                    any cell's repetitions disagree on
                                    the job output; writes one row per
                                    cell to results/BENCH_suite.json
  serve [--jobs N] [--workers N] [--rate R] [--max-active N]
        [--samples N] [--seed S] [--cache-mb MB] [--affinity on|off]
        [--speculate on|off] [--straggler-pct P]
        [--listen ADDR --workers-remote N] [--elastic on|off]
        [--heartbeat-ms MS] [--straggler-poll-ms MS]
                                    sustained mixed load through the
                                    long-lived multi-tenant service;
                                    with --elastic, workers join and
                                    leave the warm pool mid-session;
                                    writes results/BENCH_serve.json
  submit [--workload W] [--samples N] [--workers N] [--deadline S]
         [--reduce-tasks R] [--partitioner hash|skew]
         [--frontdoor ADDR] [--tenant T] [--out-json FILE]
                                    one job through the service
                                    (admission estimate + SLO gate);
                                    with --frontdoor, routes through a
                                    running federation front-door
                                    instead of a private service;
                                    refusals are structured — the
                                    admission/shed reason and a
                                    retry-after hint go to stderr and
                                    to --out-json
  frontdoor [--listen ADDR] [--leaders N] [--workers N]
            [--max-active N] [--cache-mb MB] [--backlog-cap N]
            [--outstanding-cap N] [--vnodes N]
                                    run N independent leader instances
                                    behind one sharding, DRF fair-
                                    queueing, load-shedding admission
                                    point (`bts submit --frontdoor`)
  fedctl stats|kill N|shutdown [--frontdoor ADDR]
                                    inspect the shard map, kill a
                                    leader (tenants re-home), or drain
                                    and stop a running front-door
  profile [--workload W]            offline task-size -> miss-rate profiling
  calibrate                         measure compute s/MiB from artifacts
  plan --slo S [--workload W]       best configuration under an SLO
  worker --connect A [--cache-mb MB] [--prefetch-k N]
         [--heartbeat-ms MS]
                                    join a leader as a remote map slot
                                    (serves until the leader shuts the
                                    session down, it is drained, or it
                                    gets SIGTERM — which drains too);
                                    an elastic leader admits it
                                    mid-job, a static one refuses it
                                    with a versioned error
  drain WORKER --connect A          ask the leader to drain map slot
                                    WORKER: it finishes its running
                                    task, returns queued work, exits
  list                              list figure ids

flags take `--name value` or `--name=value`; unknown flags are errors.
";

/// The `--workload` flag (defaulting to eaglet), parsed strictly.
fn workload_flag(f: &Flags) -> Result<Workload> {
    let w = f.get("--workload").unwrap_or("eaglet");
    Workload::parse(w)
        .ok_or_else(|| Error::Config(format!("unknown workload {w}")))
}

/// An on/off flag (`--affinity on`), parsed strictly.
fn on_off_flag(f: &Flags, name: &str, default: bool) -> Result<bool> {
    match f.get(name) {
        None => Ok(default),
        Some("on" | "true" | "1") => Ok(true),
        Some("off" | "false" | "0") => Ok(false),
        Some(v) => Err(Error::Config(format!(
            "bad {name} value {v}; want on|off"
        ))),
    }
}

/// `--reduce-tasks N` + `--partitioner hash|skew`, parsed strictly.
/// N = 1 (the default) keeps the leader-side seq-ordered reduce; N > 1
/// runs the executed shuffle + reduce phase on the worker pool.
fn reduce_flags(f: &Flags) -> Result<(usize, bts::reduce::Partitioner)> {
    let r: usize = f.num_at_least("--reduce-tasks", 1, 1)?;
    let p = match f.get("--partitioner") {
        None => bts::reduce::Partitioner::Hash,
        Some(v) => bts::reduce::Partitioner::parse(v).ok_or_else(|| {
            Error::Config(format!("bad --partitioner {v}; want hash|skew"))
        })?,
    };
    Ok((r, p))
}

/// `--speculate on|off` + `--straggler-pct P` (a percentile in
/// (0, 100]), parsed strictly.
fn speculation_flags(f: &Flags) -> Result<(bool, f64)> {
    let speculate = on_off_flag(f, "--speculate", false)?;
    let pct: f64 = f.num("--straggler-pct", 95.0)?;
    if !pct.is_finite() || pct <= 0.0 || pct > 100.0 {
        return Err(Error::Config(format!(
            "bad --straggler-pct {pct}; want a percentile in (0, 100]"
        )));
    }
    Ok((speculate, pct))
}

fn cmd_repro(args: &[String]) -> Result<()> {
    let f = Flags::parse(args, &["--only", "--out"])?;
    // repeatable + comma-splittable; `--only fig4,` is an error
    let only_ids = f.list("--only")?;
    let only: Option<Vec<&str>> = (!only_ids.is_empty())
        .then(|| only_ids.iter().map(String::as_str).collect());
    let out_dir = f.get("--out");
    if let Some(d) = out_dir {
        std::fs::create_dir_all(d)?;
    }
    let (ctx, kernel) = Ctx::calibrated();
    eprintln!(
        "simulator constants (thesis-anchored, s/MiB processed): eaglet {:.3}, netflix_hi {:.3}, netflix_lo {:.3}",
        ctx.eaglet_s_per_mib, ctx.netflix_hi_s_per_mib, ctx.netflix_lo_s_per_mib
    );
    match kernel {
        Some([e, hi, lo]) => eprintln!(
            "measured PJRT kernel cost (health check): eaglet {e:.4}, netflix_hi {hi:.4}, netflix_lo {lo:.4} s/MiB"
        ),
        None => eprintln!("artifacts not built: kernel health check skipped"),
    }
    for fig in all() {
        if let Some(ids) = &only {
            if !ids.contains(&fig.id) {
                continue;
            }
        }
        let text = (fig.generate)(&ctx);
        println!("\n===== {} — {} =====\n{}", fig.id, fig.title, text);
        if let Some(d) = out_dir {
            std::fs::write(format!("{d}/{}.txt", fig.id), &text)?;
        }
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let f = Flags::parse(args, &["--config", "--set"])?;
    let mut cfg = match f.get("--config") {
        Some(path) => Config::load(path)?,
        None => Config::default(),
    };
    for kv in f.get_all("--set") {
        let (k, v) = kv.split_once('=').ok_or_else(|| {
            Error::Config(format!("bad --set {kv}; want key=value"))
        })?;
        cfg.set(k, v)?;
    }
    let manifest = Arc::new(Manifest::load_default()?);
    let knee = kneepoint_bytes(cfg.workload, &CacheConfig::sandy_bridge());
    println!(
        "workload {}  sizing {:?}  kneepoint {:.2} MB  workers {}",
        cfg.workload.name(),
        cfg.sizing,
        knee as f64 / (1024.0 * 1024.0),
        cfg.workers
    );
    let ds = bts::workloads::build(
        cfg.workload,
        &manifest.params,
        cfg.job_bytes,
    );
    let job_cfg = cfg.to_job_config(knee);
    let r = run_with_recovery(ds.as_ref(), manifest, &job_cfg, 3)?;
    println!("{}", r.report.render());
    println!(
        "scheduler: {} refills, {} steals; rf trajectory {:?}",
        r.sched.refills, r.sched.steals, r.rf_trajectory
    );
    print_output(&r.output);
    Ok(())
}

fn print_output(output: &bts::coordinator::JobOutput) {
    match output {
        bts::coordinator::JobOutput::Eaglet { alod, weight } => {
            println!("ALOD over {weight} chunks:");
            for (i, v) in alod.iter().enumerate() {
                println!("  grid {i:2}: {v:8.4}");
            }
        }
        bts::coordinator::JobOutput::Netflix(stats) => {
            println!("per-month mean rating (95% CI half-width, n):");
            for m in 0..stats.mean.len() {
                println!(
                    "  month {m:2}: {:.3} (±{:.3}, n={})",
                    stats.mean[m], stats.ci_half[m], stats.count[m]
                );
            }
        }
    }
}

/// `--elastic on|off` + `--heartbeat-ms MS` + `--straggler-poll-ms MS`,
/// parsed strictly. The defaults are the protocol's ping interval and
/// the scheduler's speculation poll — the values that were hard-coded
/// before they became flags.
fn elastic_flags(f: &Flags) -> Result<(bool, u64, u64)> {
    let elastic = on_off_flag(f, "--elastic", false)?;
    let heartbeat_ms: u64 = f.num(
        "--heartbeat-ms",
        bts::net::protocol::PING_INTERVAL.as_millis() as u64,
    )?;
    if heartbeat_ms == 0 {
        return Err(Error::Config(
            "--heartbeat-ms must be at least 1".into(),
        ));
    }
    let straggler_poll_ms: u64 = f.num(
        "--straggler-poll-ms",
        bts::scheduler::SPECULATION_POLL.as_millis() as u64,
    )?;
    if straggler_poll_ms == 0 {
        return Err(Error::Config(
            "--straggler-poll-ms must be at least 1".into(),
        ));
    }
    Ok((elastic, heartbeat_ms, straggler_poll_ms))
}

/// `--listen ADDR` + `--workers-remote N` → remote map slots, parsed
/// strictly. Statically, each flag requires the other; with elastic
/// membership on, `--listen` alone is legal — the leader starts with
/// its local slots and admits workers as they connect.
fn remote_flags(
    f: &Flags,
    elastic: bool,
) -> Result<Option<bts::transport::RemoteWorkers>> {
    let count: usize = f.num("--workers-remote", 0)?;
    match (f.get("--listen"), count) {
        (Some(addr), n) if n > 0 || elastic => {
            let remote = bts::transport::RemoteWorkers::bind(addr, n)?;
            if n > 0 {
                println!(
                    "listening on {} for {} remote worker{} \
                     (`bts worker --connect {}`)",
                    remote.addr(),
                    n,
                    if n == 1 { "" } else { "s" },
                    remote.addr()
                );
            } else {
                println!(
                    "listening on {} for elastic joiners \
                     (`bts worker --connect {}`)",
                    remote.addr(),
                    remote.addr()
                );
            }
            Ok(Some(remote))
        }
        (Some(_), _) => Err(Error::Config(
            "--listen needs --workers-remote N (how many to accept) \
             or --elastic on"
                .into(),
        )),
        (None, n) if n > 0 => Err(Error::Config(
            "--workers-remote needs --listen ADDR".into(),
        )),
        _ => Ok(None),
    }
}

/// The job statistic as deterministic JSON — what the CI transport and
/// suite smokes diff between an in-proc and a loopback-TCP run of the
/// same seed (bit-identical outputs ⇒ byte-identical files). Lives on
/// [`bts::coordinator::JobOutput`] so `bts suite` rows share it.
fn output_json(output: &bts::coordinator::JobOutput) -> bts::util::json::Json {
    output.to_json()
}

fn cmd_exec(args: &[String]) -> Result<()> {
    use bts::exec::{run_cluster, Backend, ExecConfig};
    use bts::kneepoint::TaskSizing;
    use bts::runtime::Exec as _;

    let f = Flags::parse(
        args,
        &[
            "--workload",
            "--workers",
            "--samples",
            "--sizing",
            "--cache-mb",
            "--affinity",
            "--speculate",
            "--straggler-pct",
            "--listen",
            "--workers-remote",
            "--out-json",
            "--reduce-tasks",
            "--partitioner",
            "--elastic",
            "--heartbeat-ms",
            "--straggler-poll-ms",
            "--batch",
        ],
    )?;
    let w = workload_flag(&f)?;
    let workers: usize = f.num("--workers", 4)?;
    let samples: usize = f.num("--samples", 200)?;
    let cache_mb: usize = f.num("--cache-mb", 0)?;
    let affinity = on_off_flag(&f, "--affinity", false)?;
    let (speculate, straggler_pct) = speculation_flags(&f)?;
    let (reduce_tasks, partitioner) = reduce_flags(&f)?;
    let (elastic, heartbeat_ms, straggler_poll_ms) = elastic_flags(&f)?;
    // --batch off reproduces the historical one-frame-per-task wire
    // behaviour (the CI equivalence gate diffs the two). The window
    // itself is the scheduler refill window — there is no size knob.
    let batch = on_off_flag(&f, "--batch", true)?;
    let remote = remote_flags(&f, elastic)?;
    let backend = Arc::new(Backend::auto());
    let params = backend.manifest().params.clone();
    let knee = kneepoint_bytes(w, &CacheConfig::sandy_bridge());
    let sizing = match f.get("--sizing") {
        None | Some("kneepoint") => {
            // small synthetic datasets: cap the knee so jobs still
            // split into a meaningful number of tiny tasks
            TaskSizing::Kneepoint(knee.min(256 * 1024))
        }
        Some("tiniest") => TaskSizing::Tiniest,
        Some("large") => TaskSizing::LargeSn { workers },
        Some(n) => TaskSizing::Fixed(bts::config::parse_bytes(n)?),
    };
    let cfg = ExecConfig {
        sizing,
        workers,
        remote,
        cache_mb,
        affinity,
        sched: bts::scheduler::SchedConfig {
            dynamic: speculate,
            speculate,
            straggler_pct,
            straggler_poll_ms,
            ..Default::default()
        },
        reduce_tasks,
        partitioner,
        elastic,
        heartbeat_ms,
        batch_dispatch: batch,
        ..Default::default()
    };
    let ds = bts::workloads::build_small(w, &params, samples);
    println!(
        "backend {}  workload {}  {} samples  sizing {:?}  {} workers \
         (+{} remote{})  cache {} MB  affinity {}  speculate {}  \
         reducers {} ({})",
        backend.name(),
        w.name(),
        samples,
        cfg.sizing,
        cfg.workers,
        cfg.remote.as_ref().map_or(0, |r| r.count),
        if cfg.elastic { ", elastic" } else { "" },
        cfg.cache_mb,
        if cfg.affinity { "on" } else { "off" },
        if speculate {
            format!("on (p{straggler_pct:.0})")
        } else {
            "off".into()
        },
        reduce_tasks,
        partitioner.name(),
    );
    let r = run_cluster(ds.as_ref(), backend, &cfg)?;
    println!("{}", r.report.render());
    println!(
        "scheduler: dispatch {:.1} µs/call over {} calls; queue wait \
         p50 {:.3} ms p95 {:.3} ms; {} refills, {} steals, {} affine; \
         {} speculated ({} won by clone); rf {:?}; dfs served {:.2} MB",
        r.overhead.dispatch_us_per_call(),
        r.overhead.dispatch_calls,
        r.overhead.queue_wait.p50 * 1e3,
        r.overhead.queue_wait.p95 * 1e3,
        r.sched.refills,
        r.sched.steals,
        r.sched.affinity_routed,
        r.sched.speculated,
        r.sched.won_by_clone,
        r.rf_trajectory,
        r.dfs_bytes_served as f64 / 1048576.0
    );
    print_output(&r.output);
    if let Some(out) = f.get("--out-json") {
        use bts::util::json::{num, obj};
        if let Some(dir) = std::path::Path::new(out).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        // Two subtrees: "output" is the deterministic job statistic
        // (what equivalence gates diff), "data_plane" the wire
        // counters (which legitimately differ between batched and
        // unbatched runs).
        let rec = obj(vec![
            ("output", output_json(&r.output)),
            (
                "data_plane",
                obj(vec![
                    ("frames_sent", num(r.report.frames_sent as f64)),
                    ("frames_batched", num(r.report.frames_batched as f64)),
                    ("wire_bytes", num(r.report.wire_bytes as f64)),
                    (
                        "blocks_zero_copy",
                        num(r.report.blocks_zero_copy as f64),
                    ),
                ]),
            ),
        ]);
        std::fs::write(out, rec.to_string_pretty())?;
        println!("wrote {out}");
    }
    let mut rec = r.metrics_json();
    if let bts::util::json::Json::Obj(m) = &mut rec {
        m.insert("label".into(), bts::util::json::s(w.name()));
    }
    let path = bts::util::bench_record::write("exec", vec![rec])?;
    println!("wrote {path}");
    Ok(())
}

/// `bts suite GRID.toml` — expand a declarative scenario grid and run
/// every cell through the cluster executor, enforcing repetition
/// bit-identity and writing one row per cell to
/// `results/BENCH_suite.json` (see [`bts::suite`]).
fn cmd_suite(args: &[String]) -> Result<()> {
    use bts::exec::Backend;
    use bts::suite::{cell_label, run_suite, SuiteSpec};

    let (path, rest) = match args.first() {
        Some(p) if !p.starts_with("--") => (p.as_str(), &args[1..]),
        _ => {
            return Err(Error::Config(
                "usage: bts suite GRID.toml [--out-dir DIR]".into(),
            ))
        }
    };
    let f = Flags::parse(rest, &["--out-dir"])?;
    let text = std::fs::read_to_string(path).map_err(|e| {
        Error::Config(format!("cannot read grid file {path}: {e}"))
    })?;
    let spec = SuiteSpec::parse(&text)?;
    let backend = Arc::new(Backend::auto());
    println!(
        "suite {}: {} axes -> {} cells x {} reps ({} samples/cell), \
         backend {}",
        spec.name,
        spec.axes.len(),
        spec.n_cells(),
        spec.reps,
        spec.samples,
        backend.name()
    );
    for (ci, cell) in spec.cells().iter().enumerate() {
        println!("  cell {ci:3}: {}", cell_label(cell));
    }
    let rows = run_suite(&spec, backend)?;
    let n = rows.len();
    let out_dir = f.get("--out-dir").unwrap_or("results");
    let out = bts::util::bench_record::write_in(out_dir, "suite", rows)?;
    println!("all {n} cells deterministic across {} reps", spec.reps);
    println!("wrote {out}");
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    use bts::exec::Backend;
    use bts::serve::{run_load, LoadConfig};

    let f = Flags::parse(
        args,
        &[
            "--jobs",
            "--workers",
            "--rate",
            "--seed",
            "--max-active",
            "--samples",
            "--cache-mb",
            "--affinity",
            "--speculate",
            "--straggler-pct",
            "--listen",
            "--workers-remote",
            "--elastic",
            "--heartbeat-ms",
            "--straggler-poll-ms",
        ],
    )?;
    let (speculate, straggler_pct) = speculation_flags(&f)?;
    let (elastic, heartbeat_ms, straggler_poll_ms) = elastic_flags(&f)?;
    let cfg = LoadConfig {
        jobs: f.num("--jobs", 20)?,
        workers: f.num("--workers", 4)?,
        max_active: f.num("--max-active", 4)?,
        arrival_rate_per_s: f.num("--rate", 25.0)?,
        seed: f.num("--seed", 0xB75)?,
        base_samples: f.num("--samples", 40)?,
        cache_mb: f.num("--cache-mb", 0)?,
        affinity: on_off_flag(&f, "--affinity", false)?,
        speculate,
        straggler_pct,
        remote: remote_flags(&f, elastic)?,
        elastic,
        heartbeat_ms,
        straggler_poll_ms,
        ..Default::default()
    };
    let backend = Arc::new(Backend::auto());
    println!(
        "serving {} mixed jobs over {} warm workers (+{} remote{}, max {} \
         multiplexed, ~{:.0} arrivals/s)",
        cfg.jobs,
        cfg.workers,
        cfg.remote.as_ref().map_or(0, |r| r.count),
        if cfg.elastic { ", elastic" } else { "" },
        cfg.max_active,
        cfg.arrival_rate_per_s
    );
    let out = run_load(backend, &cfg)?;
    for r in &out.results {
        println!("  {}", r.render_row());
    }
    println!("{}", out.report.render());
    println!(
        "admission rejected {} infeasible-deadline submissions at the door",
        out.report.jobs_rejected
    );
    let mut rec = out.report.metrics_json();
    if let bts::util::json::Json::Obj(m) = &mut rec {
        m.insert(
            "label".into(),
            bts::util::json::s(&format!(
                "jobs={} workers={}",
                cfg.jobs, cfg.workers
            )),
        );
    }
    let path = bts::util::bench_record::write("serve", vec![rec])?;
    println!("wrote {path}");
    Ok(())
}

/// Write `record` to `path`, creating parent directories.
fn write_json_file(path: &str, record: &bts::util::json::Json) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, record.to_string_pretty())?;
    Ok(())
}

/// Surface a structured refusal: the admission/shed reason plus a
/// retry hint on stderr, and (when `--out-json` was given) the same
/// verdict as a machine-readable record. Errors that are not
/// submission refusals pass through untouched.
fn report_rejection(
    e: &Error,
    estimate_s: Option<f64>,
    out_json: Option<&str>,
) -> Result<()> {
    use bts::util::json::{num, obj, s, Json};
    let record = match e {
        Error::Admission(reason) => {
            eprintln!("submission rejected (admission): {reason}");
            if let Some(est) = estimate_s {
                eprintln!(
                    "hint: the planner needs {est:.1}s of model time; \
                     retry with --deadline at least that"
                );
            }
            obj(vec![
                ("rejected", s("admission")),
                ("reason", s(reason)),
                ("estimate_s", estimate_s.map_or(Json::Null, num)),
                // retrying the identical request cannot succeed; only
                // a looser deadline can
                ("retry_after_s", Json::Null),
            ])
        }
        Error::Shed { retry_after_s, reason } => {
            eprintln!("submission rejected (shed): {reason}");
            eprintln!(
                "hint: the front-door is overloaded; retry after \
                 {retry_after_s:.1}s"
            );
            obj(vec![
                ("rejected", s("shed")),
                ("reason", s(reason)),
                ("estimate_s", estimate_s.map_or(Json::Null, num)),
                ("retry_after_s", num(*retry_after_s)),
            ])
        }
        _ => return Ok(()),
    };
    if let Some(path) = out_json {
        write_json_file(path, &record)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_submit(args: &[String]) -> Result<()> {
    use bts::exec::Backend;
    use bts::serve::{JobRequest, JobService, PoolConfig, ServeConfig};

    let f = Flags::parse(
        args,
        &[
            "--workload",
            "--samples",
            "--workers",
            "--deadline",
            "--seed",
            "--reduce-tasks",
            "--partitioner",
            "--frontdoor",
            "--tenant",
            "--out-json",
        ],
    )?;
    let w = workload_flag(&f)?;
    let samples: usize = f.num("--samples", 40)?;
    let workers: usize = f.num("--workers", 4)?;
    let seed: u64 = f.num("--seed", 0xB75)?;
    let (reduce_tasks, partitioner) = reduce_flags(&f)?;
    let out_json = f.get("--out-json");
    let mut req = JobRequest::new(w, samples)
        .with_seed(seed)
        .with_reduce(reduce_tasks, partitioner);
    if let Some(d) = f.get("--deadline") {
        req = req.with_deadline(d.parse().map_err(|_| {
            Error::Config(format!("bad --deadline value {d}"))
        })?);
    }

    if let Some(addr) = f.get("--frontdoor") {
        // route through a running federation front-door; the output is
        // bit-identical to the private-service path below by the
        // determinism contract (the integration oracle diffs the two).
        let tenant = f.get("--tenant").unwrap_or("cli");
        let out = match bts::federation::submit_via_frontdoor(
            addr, tenant, &req,
        ) {
            Ok(out) => out,
            Err(e) => {
                report_rejection(&e, None, out_json)?;
                return Err(e);
            }
        };
        println!(
            "front-door {addr} routed job {} for tenant {tenant} to \
             leader {}{}",
            out.job,
            out.leader,
            if out.spilled { " (spilled)" } else { "" }
        );
        print_output(&out.output);
        if let Some(path) = out_json {
            write_json_file(path, &output_json(&out.output))?;
            println!("wrote {path}");
        }
        return Ok(());
    }

    let backend = Arc::new(Backend::auto());
    let svc = JobService::start(
        backend,
        ServeConfig {
            pool: PoolConfig { workers, ..Default::default() },
            ..Default::default()
        },
    )?;
    let est = svc.estimate_s(&req);
    println!(
        "planner estimate: {est:.1}s (model seconds) for {samples} \
         samples of {}",
        w.name()
    );
    let result = match svc.submit(req) {
        Ok(h) => h.wait()?,
        Err(e) => {
            // surface the admission verdict; a shutdown hiccup must
            // not mask it
            let _ = svc.shutdown();
            report_rejection(&e, Some(est), out_json)?;
            return Err(e);
        }
    };
    println!("{}", result.report.render());
    println!(
        "queue wait {:.1}ms; time to first partial {:.1}ms; e2e {:.1}ms",
        result.queue_wait_s * 1e3,
        result.ttfp_s * 1e3,
        result.e2e_s * 1e3
    );
    print_output(&result.output);
    if let Some(path) = out_json {
        write_json_file(path, &output_json(&result.output))?;
        println!("wrote {path}");
    }
    svc.shutdown()?;
    Ok(())
}

/// Default front-door address (`bts frontdoor` listener and the
/// `fedctl` client side).
const DEFAULT_FRONTDOOR: &str = "127.0.0.1:7470";

fn cmd_frontdoor(args: &[String]) -> Result<()> {
    use bts::exec::Backend;
    use bts::federation::{serve_frontdoor, Federation, FederationConfig};

    let f = Flags::parse(
        args,
        &[
            "--listen",
            "--leaders",
            "--workers",
            "--max-active",
            "--cache-mb",
            "--backlog-cap",
            "--outstanding-cap",
            "--vnodes",
        ],
    )?;
    let addr = f.get("--listen").unwrap_or(DEFAULT_FRONTDOOR);
    let cfg = FederationConfig {
        leaders: f.num("--leaders", 2)?,
        workers_per_leader: f.num("--workers", 2)?,
        max_active_per_leader: f.num("--max-active", 2)?,
        cache_mb_per_leader: f.num("--cache-mb", 0)?,
        leader_outstanding_cap: f.num("--outstanding-cap", 4)?,
        backlog_cap: f.num("--backlog-cap", 64)?,
        vnodes: f.num("--vnodes", 32)?,
    };
    let listener = std::net::TcpListener::bind(addr).map_err(|e| {
        Error::Protocol(format!("bind front-door {addr}: {e}"))
    })?;
    let local = listener.local_addr()?;
    let backend = Arc::new(Backend::auto());
    println!(
        "front-door on {local}: {} leaders x {} workers each \
         (backend {}; `bts submit --frontdoor {local}`)",
        cfg.leaders,
        cfg.workers_per_leader,
        backend.name()
    );
    let label = format!(
        "leaders={} workers={}",
        cfg.leaders, cfg.workers_per_leader
    );
    let fed = Federation::start(backend, cfg)?;
    let report = serve_frontdoor(listener, fed)?;
    println!("{}", report.render());
    let mut rec = report.metrics_json();
    if let bts::util::json::Json::Obj(m) = &mut rec {
        m.insert("label".into(), bts::util::json::s(&label));
    }
    let path = bts::util::bench_record::write("frontdoor", vec![rec])?;
    println!("wrote {path}");
    Ok(())
}

fn print_shard_map(stats: &[bts::net::protocol::LeaderStat]) {
    for st in stats {
        println!(
            "  leader {} {}  active {}  queued {}  completed {}",
            st.leader,
            if st.alive { "alive" } else { "dead " },
            st.active,
            st.queued,
            st.completed
        );
    }
}

/// `bts fedctl stats|kill N|shutdown --frontdoor ADDR` — the
/// front-door control plane.
fn cmd_fedctl(args: &[String]) -> Result<()> {
    const USAGE: &str =
        "usage: bts fedctl stats|kill N|shutdown [--frontdoor ADDR]";
    let verb = match args.first() {
        Some(v) if !v.starts_with("--") => v.as_str(),
        _ => return Err(Error::Config(USAGE.into())),
    };
    match verb {
        "stats" => {
            let f = Flags::parse(&args[1..], &["--frontdoor"])?;
            let addr = f.get("--frontdoor").unwrap_or(DEFAULT_FRONTDOOR);
            println!("shard map of front-door {addr}:");
            print_shard_map(&bts::federation::frontdoor_stats(addr)?);
            Ok(())
        }
        "kill" => {
            let idx = match args.get(1) {
                Some(v) if !v.starts_with("--") => v.as_str(),
                _ => return Err(Error::Config(USAGE.into())),
            };
            let leader: u32 = idx.parse().map_err(|_| {
                Error::Config(format!(
                    "bad leader index {idx}; want a number"
                ))
            })?;
            let f = Flags::parse(&args[2..], &["--frontdoor"])?;
            let addr = f.get("--frontdoor").unwrap_or(DEFAULT_FRONTDOOR);
            let stats = bts::federation::frontdoor_kill(addr, leader)?;
            println!(
                "leader {leader} killed; its tenants re-home to the \
                 surviving shard map:"
            );
            print_shard_map(&stats);
            Ok(())
        }
        "shutdown" => {
            let f = Flags::parse(&args[1..], &["--frontdoor"])?;
            let addr = f.get("--frontdoor").unwrap_or(DEFAULT_FRONTDOOR);
            bts::federation::frontdoor_shutdown(addr)?;
            println!("front-door {addr} acknowledged shutdown; draining");
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown fedctl verb {other}; {USAGE}"
        ))),
    }
}

fn cmd_profile(args: &[String]) -> Result<()> {
    let f = Flags::parse(args, &["--workload"])?;
    let w = workload_flag(&f)?;
    let cache = CacheConfig::sandy_bridge();
    let profile = profile_workload(w, &cache, &default_sizes(), None);
    println!("task MB    L2 miss/instr   L3 miss/instr   AMAT");
    for p in &profile.points {
        println!(
            "{:8.2}   {:12.6}   {:12.6}   {:6.1}",
            p.task_bytes as f64 / (1024.0 * 1024.0),
            p.l2_mpi,
            p.l3_mpi,
            p.amat
        );
    }
    let knee = smallest_kneepoint(&profile.l2_curve(), KNEE_THRESHOLD);
    println!(
        "smallest kneepoint: {}",
        knee.map(|b| format!("{:.2} MB", b as f64 / 1048576.0))
            .unwrap_or_else(|| "none".into())
    );
    Ok(())
}

fn cmd_calibrate() -> Result<()> {
    let (ctx, kernel) = Ctx::calibrated();
    println!("simulator model constants (thesis-anchored):");
    println!("  eaglet     {:.5} s/MiB processed", ctx.eaglet_s_per_mib);
    println!("  netflix_hi {:.5} s/MiB processed", ctx.netflix_hi_s_per_mib);
    println!("  netflix_lo {:.5} s/MiB processed", ctx.netflix_lo_s_per_mib);
    match kernel {
        Some([e, hi, lo]) => {
            println!("measured PJRT kernel cost on this host:");
            println!("  eaglet     {e:.5} s/MiB");
            println!("  netflix_hi {hi:.5} s/MiB");
            println!("  netflix_lo {lo:.5} s/MiB");
        }
        None => println!("artifacts not built: run `make artifacts` to measure kernels"),
    }
    Ok(())
}

fn cmd_plan(args: &[String]) -> Result<()> {
    let f = Flags::parse(args, &["--slo", "--workload"])?;
    let w = workload_flag(&f)?;
    let slo: f64 = f
        .get("--slo")
        .ok_or_else(|| Error::Config("--slo SECONDS required".into()))?
        .parse()
        .map_err(|_| Error::Config("bad --slo".into()))?;
    let ctx = Ctx::default();
    let jobs: Vec<usize> = [4, 16, 64, 230, 1024, 4096, 16384, 65536]
        .iter()
        .map(|mb| mb * 1024 * 1024)
        .collect();
    match bts::slo::best_under_slo(
        w,
        slo,
        &[12, 36, 72],
        &jobs,
        ctx.compute_s_per_mib(w),
    ) {
        Some(p) => println!(
            "best: {} cores, {:.0} MB job, {:.1}s, {:.1} MB/s ({:.0}% of peak)",
            p.best.cores,
            p.best.job_bytes as f64 / 1048576.0,
            p.best.total_s,
            p.best.throughput_mbs,
            p.frac_of_peak * 100.0
        ),
        None => println!("no configuration meets a {slo}s SLO"),
    }
    Ok(())
}

fn cmd_worker(args: &[String]) -> Result<()> {
    use bts::exec::Backend;
    use bts::transport::RemoteWorkerOpts;

    let f = Flags::parse(
        args,
        &["--connect", "--cache-mb", "--prefetch-k", "--heartbeat-ms"],
    )?;
    let addr = f.get("--connect").unwrap_or("127.0.0.1:7462");
    let heartbeat_ms: u64 = f.num(
        "--heartbeat-ms",
        bts::net::protocol::PING_INTERVAL.as_millis() as u64,
    )?;
    if heartbeat_ms == 0 {
        return Err(Error::Config(
            "--heartbeat-ms must be at least 1".into(),
        ));
    }
    let opts = RemoteWorkerOpts {
        cache_mb: f.num("--cache-mb", 0)?,
        prefetch_k: f.num("--prefetch-k", 8)?,
        heartbeat: std::time::Duration::from_millis(heartbeat_ms),
        ..Default::default()
    };
    let backend = Arc::new(Backend::auto());
    println!(
        "worker connecting to {addr} (backend {}, cache {} MB)",
        backend.name(),
        opts.cache_mb
    );
    let n = bts::net::run_worker(addr, backend, &opts)?;
    println!("worker session done: executed {n} tasks");
    Ok(())
}

/// `bts drain WORKER --connect ADDR` — the graceful-departure control
/// plane: ask the leader's membership acceptor to send slot WORKER a
/// drain. The ack is the echoed frame; the worker itself finishes its
/// running task, hands queued work back, and exits.
fn cmd_drain(args: &[String]) -> Result<()> {
    let (worker, rest) = match args.first() {
        Some(w) if !w.starts_with("--") => (w.as_str(), &args[1..]),
        _ => {
            return Err(Error::Config(
                "usage: bts drain WORKER --connect ADDR".into(),
            ))
        }
    };
    let worker: u32 = worker.parse().map_err(|_| {
        Error::Config(format!("bad worker index {worker}; want a number"))
    })?;
    let f = Flags::parse(rest, &["--connect"])?;
    let addr = f.get("--connect").unwrap_or("127.0.0.1:7462");
    bts::net::request_drain(addr, worker)?;
    println!("drain of worker {worker} acknowledged by leader {addr}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    // Flags parsing itself is covered in bts::util::cli; here we only
    // test the binary's own helper on top of it.
    #[test]
    fn workload_flag_parses_and_rejects() {
        let f = Flags::parse(
            &argv(&["--workload=netflix_lo"]),
            &["--workload"],
        )
        .unwrap();
        assert_eq!(workload_flag(&f).unwrap(), Workload::NetflixLo);
        let f = Flags::parse(&argv(&["--workload", "what"]), &["--workload"])
            .unwrap();
        assert!(workload_flag(&f).is_err());
        let f = Flags::parse(&argv(&[]), &["--workload"]).unwrap();
        assert_eq!(workload_flag(&f).unwrap(), Workload::Eaglet);
    }

    #[test]
    fn on_off_flag_parses_and_rejects() {
        let f = Flags::parse(&argv(&["--affinity=on"]), &["--affinity"])
            .unwrap();
        assert!(on_off_flag(&f, "--affinity", false).unwrap());
        let f = Flags::parse(&argv(&["--affinity", "off"]), &["--affinity"])
            .unwrap();
        assert!(!on_off_flag(&f, "--affinity", true).unwrap());
        let f = Flags::parse(&argv(&[]), &["--affinity"]).unwrap();
        assert!(on_off_flag(&f, "--affinity", true).unwrap());
        let f = Flags::parse(&argv(&["--affinity=maybe"]), &["--affinity"])
            .unwrap();
        assert!(on_off_flag(&f, "--affinity", false).is_err());
    }

    #[test]
    fn reduce_flags_parse_and_reject() {
        use bts::reduce::Partitioner;
        let names = &["--reduce-tasks", "--partitioner"][..];
        let f = Flags::parse(&argv(&[]), names).unwrap();
        assert_eq!(reduce_flags(&f).unwrap(), (1, Partitioner::Hash));
        let f = Flags::parse(
            &argv(&["--reduce-tasks=4", "--partitioner", "skew"]),
            names,
        )
        .unwrap();
        assert_eq!(reduce_flags(&f).unwrap(), (4, Partitioner::Skew));
        let f =
            Flags::parse(&argv(&["--reduce-tasks", "0"]), names).unwrap();
        assert!(reduce_flags(&f).is_err(), "zero reducers must be rejected");
        let f = Flags::parse(&argv(&["--partitioner=zipf"]), names).unwrap();
        assert!(reduce_flags(&f).is_err(), "unknown partitioner rejected");
    }

    #[test]
    fn elastic_flags_parse_and_reject() {
        let names =
            &["--elastic", "--heartbeat-ms", "--straggler-poll-ms"][..];
        let f = Flags::parse(&argv(&[]), names).unwrap();
        let (elastic, hb, poll) = elastic_flags(&f).unwrap();
        assert!(!elastic);
        assert_eq!(
            hb,
            bts::net::protocol::PING_INTERVAL.as_millis() as u64
        );
        assert_eq!(
            poll,
            bts::scheduler::SPECULATION_POLL.as_millis() as u64
        );
        let f = Flags::parse(
            &argv(&[
                "--elastic=on",
                "--heartbeat-ms",
                "250",
                "--straggler-poll-ms=7",
            ]),
            names,
        )
        .unwrap();
        assert_eq!(elastic_flags(&f).unwrap(), (true, 250, 7));
        for bad in
            [&["--heartbeat-ms", "0"][..], &["--straggler-poll-ms", "0"][..]]
        {
            let f = Flags::parse(&argv(bad), names).unwrap();
            assert!(
                elastic_flags(&f).is_err(),
                "{bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn fedctl_requires_a_verb_and_kill_an_index() {
        assert!(cmd_fedctl(&argv(&[])).is_err());
        assert!(cmd_fedctl(&argv(&["--frontdoor", "x"])).is_err());
        assert!(cmd_fedctl(&argv(&["reboot"])).is_err());
        assert!(cmd_fedctl(&argv(&["kill"])).is_err());
        assert!(cmd_fedctl(&argv(&["kill", "two"])).is_err());
    }

    #[test]
    fn drain_requires_a_worker_index() {
        assert!(cmd_drain(&argv(&[])).is_err());
        assert!(cmd_drain(&argv(&["--connect", "x"])).is_err());
        assert!(cmd_drain(&argv(&["two"])).is_err());
    }

    #[test]
    fn speculation_flags_parse_and_reject() {
        let names = &["--speculate", "--straggler-pct"][..];
        let f = Flags::parse(&argv(&[]), names).unwrap();
        assert_eq!(speculation_flags(&f).unwrap(), (false, 95.0));
        let f = Flags::parse(
            &argv(&["--speculate=on", "--straggler-pct", "99"]),
            names,
        )
        .unwrap();
        assert_eq!(speculation_flags(&f).unwrap(), (true, 99.0));
        for bad in ["0", "-5", "101", "NaN"] {
            let f = Flags::parse(
                &argv(&["--straggler-pct", bad]),
                names,
            )
            .unwrap();
            assert!(
                speculation_flags(&f).is_err(),
                "--straggler-pct {bad} must be rejected"
            );
        }
    }
}
