//! `bts suite` — the declarative scenario-matrix runner.
//!
//! A suite is a TOML grid file (parsed by [`toml::TomlDoc`], a
//! dependency-free subset reader) that names axes over the executor's
//! knobs — workload, transport, cache budget, affinity, speculation,
//! dispatch batching, turbulence, reduce fan-out — and the runner
//! expands the cross product, runs every cell `reps` times through the
//! same [`ExecConfig`] plumbing `bts exec` uses, and emits one
//! schema-versioned `results/BENCH_suite.json` with a row per cell:
//! the cell's axis values, the full [`ExecResult::metrics_json`]
//! counter set, and the job `output` subtree.
//!
//! Two properties make the suite an *oracle*, not just a sweep:
//!
//! * **Repetition bit-identity.** Every cell runs `reps` times and the
//!   runner hard-errors if any repetition's `output` differs — the
//!   platform's determinism contract (same seed ⇒ same statistic,
//!   regardless of transport, cache, speculation, or turbulence) is
//!   enforced on every cell of every suite, every run.
//! * **Exec equivalence.** Cells deliberately reuse `bts exec`'s
//!   defaults (seed, kneepoint cap, backend), so CI can diff any
//!   cell's `output` against a direct `bts exec --workload W` run.
//!
//! Grid file shape (see `[grid]` keys in [`GRID_KEYS`]):
//!
//! ```toml
//! [suite]
//! name = "smoke"
//! reps = 2
//! samples = 24
//!
//! [factors]
//! caches = [0, 8]
//!
//! [grid]
//! workload = ["seqaddr", "ssag"]   # array ⇒ axis
//! transport = ["inproc", "tcp"]
//! cache-mb = "$caches$"            # whole-value factor reference
//! speculate = "off"                # scalar ⇒ fixed for every cell
//! ```
//!
//! Axis order is declaration order: the first `[grid]` key is the
//! outermost loop of the cross product, so rows come out grouped the
//! way the file reads.

pub mod toml;

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::cachesim::CacheConfig;
use crate::data::{Dataset, Workload};
use crate::error::{Error, Result};
use crate::exec::{
    run_cluster_with_recovery, Backend, ExecConfig, ExecResult,
};
use crate::kneepoint::{kneepoint_bytes, TaskSizing};
use crate::net::run_worker;
use crate::reduce::Partitioner;
use crate::scheduler::SchedConfig;
use crate::transport::{RemoteWorkerOpts, RemoteWorkers};
use crate::util::json::{num, s, Json};
use crate::util::testutil::Turbulence;
use crate::workloads::build_small;

use self::toml::TomlDoc;

/// The knobs a `[grid]` may sweep. Anything else is a config error —
/// a typo'd axis must not silently run a default.
pub const GRID_KEYS: &[&str] = &[
    "workload",
    "transport",
    "cache-mb",
    "affinity",
    "speculate",
    "straggler-pct",
    "batch",
    "turbulence",
    "reduce-tasks",
    "partitioner",
    "workers",
];

/// Remote TCP slots a `transport = "tcp"` cell runs (plus one local
/// slot for the leader-side mix, mirroring the integration oracles).
const TCP_REMOTE_SLOTS: usize = 2;
/// Job-level recovery budget per cell run (matches `bts exec`'s
/// recovery-capable siblings and the oracle suites).
const RECOVERY_ATTEMPTS: u32 = 3;
/// The `turbulence = "slow"` axis: worker 0 is delayed this much per
/// task from its third task on. Delay-only (no fault rules): injected
/// latency must never change the statistic, and fault rules re-fire on
/// every recovery attempt, which would exhaust the budget here.
const SLOW_DELAY: Duration = Duration::from_millis(3);

/// A parsed suite: run parameters plus the grid axes in declaration
/// order. Singleton axes are fixed values; multi-valued axes multiply
/// the cell count.
#[derive(Debug, Clone)]
pub struct SuiteSpec {
    pub name: String,
    /// Repetitions per cell (all must produce bit-identical `output`).
    pub reps: usize,
    /// Samples per synthetic dataset (shared by every cell).
    pub samples: usize,
    pub axes: Vec<(String, Vec<Json>)>,
}

impl SuiteSpec {
    pub fn parse(text: &str) -> Result<SuiteSpec> {
        let doc = TomlDoc::parse(text)?;
        for (name, _) in &doc.sections {
            if !matches!(name.as_str(), "suite" | "factors" | "grid") {
                return Err(Error::Config(format!(
                    "unknown section [{name}]; want [suite], [factors], \
                     [grid]"
                )));
            }
        }

        let mut spec = SuiteSpec {
            name: "suite".into(),
            reps: 2,
            samples: 24,
            axes: Vec::new(),
        };
        for (key, value) in doc.section("suite").unwrap_or(&[]) {
            match key.as_str() {
                "name" => match value {
                    Json::Str(v) => spec.name = v.clone(),
                    _ => {
                        return Err(Error::Config(
                            "suite.name must be a string".into(),
                        ))
                    }
                },
                "reps" => {
                    spec.reps = positive_int(value, "suite.reps")?
                }
                "samples" => {
                    spec.samples = positive_int(value, "suite.samples")?
                }
                other => {
                    return Err(Error::Config(format!(
                        "unknown key `{other}` in [suite]; want name, \
                         reps, samples"
                    )))
                }
            }
        }

        let factors = doc.section("factors").unwrap_or(&[]);
        let grid = doc.section("grid").ok_or_else(|| {
            Error::Config("grid file has no [grid] section".into())
        })?;
        if grid.is_empty() {
            return Err(Error::Config("[grid] has no axes".into()));
        }
        for (key, value) in grid {
            if !GRID_KEYS.contains(&key.as_str()) {
                return Err(Error::Config(format!(
                    "unknown grid key `{key}`; want one of {}",
                    GRID_KEYS.join(", ")
                )));
            }
            let value = resolve_factor(value, factors)?;
            let values = match value {
                Json::Arr(items) => items,
                scalar => vec![scalar],
            };
            // Eager validation: every axis value must parse as its
            // knob *before* any cell runs, so a bad value at the end
            // of the grid can't waste the front of it.
            let mut probe = CellCfg::default();
            for v in &values {
                probe.apply(key, v)?;
            }
            spec.axes.push((key.clone(), values));
        }
        Ok(spec)
    }

    /// Cross product of the axes, declaration order outermost-first.
    pub fn cells(&self) -> Vec<Vec<(String, Json)>> {
        let mut out: Vec<Vec<(String, Json)>> = vec![Vec::new()];
        for (key, values) in &self.axes {
            let mut next = Vec::with_capacity(out.len() * values.len());
            for partial in &out {
                for v in values {
                    let mut cell = partial.clone();
                    cell.push((key.clone(), v.clone()));
                    next.push(cell);
                }
            }
            out = next;
        }
        out
    }

    pub fn n_cells(&self) -> usize {
        self.axes.iter().map(|(_, v)| v.len()).product()
    }
}

/// Resolve a whole-value `"$name$"` factor reference against
/// `[factors]`; every other value passes through unchanged.
fn resolve_factor(value: &Json, factors: &[(String, Json)]) -> Result<Json> {
    let name = match value {
        Json::Str(v)
            if v.len() > 2 && v.starts_with('$') && v.ends_with('$') =>
        {
            &v[1..v.len() - 1]
        }
        other => return Ok(other.clone()),
    };
    factors
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v.clone())
        .ok_or_else(|| {
            Error::Config(format!(
                "grid references factor `${name}$` but [factors] has no \
                 `{name}`"
            ))
        })
}

fn positive_int(v: &Json, what: &str) -> Result<usize> {
    match v {
        Json::Num(n)
            if n.is_finite() && *n >= 1.0 && n.fract() == 0.0 =>
        {
            Ok(*n as usize)
        }
        other => Err(Error::Config(format!(
            "{what} must be a positive integer, got {}",
            other.to_string_pretty()
        ))),
    }
}

fn non_negative_int(v: &Json, what: &str) -> Result<usize> {
    match v {
        Json::Num(n)
            if n.is_finite() && *n >= 0.0 && n.fract() == 0.0 =>
        {
            Ok(*n as usize)
        }
        other => Err(Error::Config(format!(
            "{what} must be a non-negative integer, got {}",
            other.to_string_pretty()
        ))),
    }
}

fn on_off(v: &Json, what: &str) -> Result<bool> {
    match v {
        Json::Bool(b) => Ok(*b),
        Json::Str(t) if t == "on" || t == "true" => Ok(true),
        Json::Str(t) if t == "off" || t == "false" => Ok(false),
        other => Err(Error::Config(format!(
            "{what} must be on|off, got {}",
            other.to_string_pretty()
        ))),
    }
}

fn string_of<'a>(v: &'a Json, what: &str) -> Result<&'a str> {
    match v {
        Json::Str(t) => Ok(t),
        other => Err(Error::Config(format!(
            "{what} must be a string, got {}",
            other.to_string_pretty()
        ))),
    }
}

/// One cell's typed configuration. Defaults mirror `bts exec`'s flag
/// defaults (modulo `workers = 2` — suites run many small cells, and
/// the statistic is worker-count-invariant by contract).
#[derive(Debug, Clone)]
pub struct CellCfg {
    pub workload: Workload,
    pub tcp: bool,
    pub cache_mb: usize,
    pub affinity: bool,
    pub speculate: bool,
    pub straggler_pct: f64,
    pub batch: bool,
    pub slow: bool,
    pub reduce_tasks: usize,
    pub partitioner: Partitioner,
    pub workers: usize,
}

impl Default for CellCfg {
    fn default() -> Self {
        CellCfg {
            workload: Workload::Eaglet,
            tcp: false,
            cache_mb: 0,
            affinity: false,
            speculate: false,
            straggler_pct: 95.0,
            batch: true,
            slow: false,
            reduce_tasks: 1,
            partitioner: Partitioner::Hash,
            workers: 2,
        }
    }
}

impl CellCfg {
    pub fn parse(cell: &[(String, Json)]) -> Result<CellCfg> {
        let mut cfg = CellCfg::default();
        for (key, value) in cell {
            cfg.apply(key, value)?;
        }
        Ok(cfg)
    }

    /// Apply one axis value. Shared by cell construction and the
    /// parse-time eager validation in [`SuiteSpec::parse`].
    fn apply(&mut self, key: &str, value: &Json) -> Result<()> {
        match key {
            "workload" => {
                let t = string_of(value, "workload")?;
                self.workload = Workload::parse(t).ok_or_else(|| {
                    Error::Config(format!("unknown workload {t}"))
                })?;
            }
            "transport" => {
                self.tcp = match string_of(value, "transport")? {
                    "inproc" => false,
                    "tcp" => true,
                    other => {
                        return Err(Error::Config(format!(
                            "bad transport {other}; want inproc|tcp"
                        )))
                    }
                };
            }
            "cache-mb" => {
                self.cache_mb = non_negative_int(value, "cache-mb")?
            }
            "affinity" => self.affinity = on_off(value, "affinity")?,
            "speculate" => self.speculate = on_off(value, "speculate")?,
            "straggler-pct" => {
                let pct = match value {
                    Json::Num(n) => *n,
                    other => {
                        return Err(Error::Config(format!(
                            "straggler-pct must be a number, got {}",
                            other.to_string_pretty()
                        )))
                    }
                };
                if !pct.is_finite() || pct <= 0.0 || pct > 100.0 {
                    return Err(Error::Config(format!(
                        "bad straggler-pct {pct}; want a percentile in \
                         (0, 100]"
                    )));
                }
                self.straggler_pct = pct;
            }
            "batch" => self.batch = on_off(value, "batch")?,
            "turbulence" => {
                self.slow = match string_of(value, "turbulence")? {
                    "off" => false,
                    "slow" => true,
                    other => {
                        return Err(Error::Config(format!(
                            "bad turbulence {other}; want off|slow"
                        )))
                    }
                };
            }
            "reduce-tasks" => {
                self.reduce_tasks =
                    positive_int(value, "reduce-tasks")?
            }
            "partitioner" => {
                let t = string_of(value, "partitioner")?;
                self.partitioner =
                    Partitioner::parse(t).ok_or_else(|| {
                        Error::Config(format!(
                            "bad partitioner {t}; want hash|skew"
                        ))
                    })?;
            }
            "workers" => {
                self.workers = positive_int(value, "workers")?
            }
            other => {
                return Err(Error::Config(format!(
                    "unknown grid key `{other}`"
                )))
            }
        }
        Ok(())
    }

    /// The `ExecConfig` this cell runs — `bts exec`'s defaults (seed,
    /// kneepoint cap, scheduler wiring) with the cell's axes applied,
    /// which is what makes suite cells diffable against direct exec
    /// runs.
    fn exec_config(&self, remote: Option<RemoteWorkers>) -> ExecConfig {
        let knee =
            kneepoint_bytes(self.workload, &CacheConfig::sandy_bridge());
        let base = ExecConfig::default();
        ExecConfig {
            sizing: TaskSizing::Kneepoint(knee.min(256 * 1024)),
            workers: if self.tcp { 1 } else { self.workers },
            remote,
            cache_mb: self.cache_mb,
            affinity: self.affinity,
            sched: SchedConfig {
                dynamic: self.speculate,
                speculate: self.speculate,
                straggler_pct: self.straggler_pct,
                ..Default::default()
            },
            reduce_tasks: self.reduce_tasks,
            partitioner: self.partitioner,
            batch_dispatch: self.batch,
            turbulence: self.slow.then(|| {
                Arc::new(
                    Turbulence::new(base.seed).slow_from(0, 2, SLOW_DELAY),
                )
            }),
            ..base
        }
    }
}

/// Human label for a cell: its axis values in declaration order.
pub fn cell_label(cell: &[(String, Json)]) -> String {
    cell.iter()
        .map(|(k, v)| format!("{k}={}", scalar_text(v)))
        .collect::<Vec<_>>()
        .join(" ")
}

fn scalar_text(v: &Json) -> String {
    match v {
        Json::Str(t) => t.clone(),
        Json::Bool(b) => b.to_string(),
        Json::Num(n) if n.fract() == 0.0 && n.abs() < 1e15 => {
            format!("{}", *n as i64)
        }
        other => other.to_string_pretty(),
    }
}

/// Run every cell of `spec` and return one JSON row per cell, in cell
/// order. Hard-errors if any cell's repetitions disagree on `output`.
pub fn run_suite(
    spec: &SuiteSpec,
    backend: Arc<Backend>,
) -> Result<Vec<Json>> {
    let params = backend.manifest().params.clone();
    let cells = spec.cells();
    let mut rows = Vec::with_capacity(cells.len());
    for (ci, cell) in cells.iter().enumerate() {
        let cfg = CellCfg::parse(cell)?;
        let ds = build_small(cfg.workload, &params, spec.samples);
        let mut outputs: Vec<Json> = Vec::new();
        let mut last: Option<ExecResult> = None;
        for _ in 0..spec.reps {
            let r = run_cell(ds.as_ref(), backend.clone(), &cfg)?;
            outputs.push(r.output.to_json());
            last = Some(r);
        }
        if outputs.windows(2).any(|w| w[0] != w[1]) {
            return Err(Error::Scheduler(format!(
                "suite cell {ci} ({}) produced diverging outputs \
                 across {} repetitions — determinism contract broken",
                cell_label(cell),
                spec.reps
            )));
        }
        let r = last.expect("reps >= 1");
        rows.push(cell_row(spec, ci, cell, &cfg, &r));
    }
    Ok(rows)
}

/// One cell run: in-proc directly; TCP cells bind a fresh loopback
/// listener and run [`TCP_REMOTE_SLOTS`] full `bts worker` sessions on
/// threads, exactly like the transport oracle tests.
fn run_cell(
    ds: &dyn Dataset,
    backend: Arc<Backend>,
    cfg: &CellCfg,
) -> Result<ExecResult> {
    if !cfg.tcp {
        let ec = cfg.exec_config(None);
        return run_cluster_with_recovery(
            ds,
            backend,
            &ec,
            RECOVERY_ATTEMPTS,
        );
    }
    let remote = RemoteWorkers::bind("127.0.0.1:0", TCP_REMOTE_SLOTS)?;
    let addr = remote.addr();
    let workers: Vec<_> = (0..TCP_REMOTE_SLOTS)
        .map(|_| {
            let addr = addr.clone();
            let backend = backend.clone();
            thread::spawn(move || {
                run_worker(&addr, backend, &RemoteWorkerOpts::default())
            })
        })
        .collect();
    let ec = cfg.exec_config(Some(remote));
    let result =
        run_cluster_with_recovery(ds, backend, &ec, RECOVERY_ATTEMPTS);
    for handle in workers {
        match handle.join() {
            Ok(Ok(_)) => {}
            Ok(Err(e)) => return result.and(Err(e)),
            Err(_) => {
                return result.and(Err(Error::Scheduler(
                    "suite TCP worker thread panicked".into(),
                )))
            }
        }
    }
    result
}

/// One `BENCH_suite.json` row: the full exec counter record, the
/// cell's axis values (dashes → underscores, normalized workload and
/// transport always present), and the `output` subtree CI diffs.
fn cell_row(
    spec: &SuiteSpec,
    ci: usize,
    cell: &[(String, Json)],
    cfg: &CellCfg,
    r: &ExecResult,
) -> Json {
    let mut row = match r.metrics_json() {
        Json::Obj(map) => map,
        _ => unreachable!("metrics_json is always an object"),
    };
    row.insert("suite".into(), s(&spec.name));
    row.insert("cell".into(), num(ci as f64));
    row.insert("label".into(), s(&cell_label(cell)));
    row.insert("reps".into(), num(spec.reps as f64));
    row.insert("samples".into(), num(spec.samples as f64));
    for (key, value) in cell {
        row.insert(key.replace('-', "_"), value.clone());
    }
    row.insert("workload".into(), s(cfg.workload.name()));
    row.insert(
        "transport".into(),
        s(if cfg.tcp { "tcp" } else { "inproc" }),
    );
    row.insert("output".into(), r.output.to_json());
    Json::Obj(row)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ModelParams;
    use crate::exec::run_cluster;

    fn native() -> Arc<Backend> {
        Arc::new(Backend::native(ModelParams::default()))
    }

    #[test]
    fn expands_the_cross_product_in_declaration_order() {
        let spec = SuiteSpec::parse(
            r#"
            [suite]
            name = "order"
            reps = 3
            samples = 12

            [grid]
            workload = ["seqaddr", "ssag"]
            cache-mb = [0, 8]
            speculate = "off"
            "#,
        )
        .unwrap();
        assert_eq!(spec.name, "order");
        assert_eq!(spec.reps, 3);
        assert_eq!(spec.samples, 12);
        assert_eq!(spec.n_cells(), 4);
        let labels: Vec<String> =
            spec.cells().iter().map(|c| cell_label(c)).collect();
        // first [grid] key is the outermost loop
        assert_eq!(
            labels,
            [
                "workload=seqaddr cache-mb=0 speculate=off",
                "workload=seqaddr cache-mb=8 speculate=off",
                "workload=ssag cache-mb=0 speculate=off",
                "workload=ssag cache-mb=8 speculate=off",
            ]
        );
        let cfg = CellCfg::parse(&spec.cells()[3]).unwrap();
        assert_eq!(cfg.workload, Workload::Ssag);
        assert_eq!(cfg.cache_mb, 8);
        assert!(!cfg.speculate);
    }

    #[test]
    fn factor_sentinels_resolve_against_the_factors_table() {
        let spec = SuiteSpec::parse(
            r#"
            [suite]
            name = "factored"

            [factors]
            caches = [0, 8, 16]
            deep-fanout = 4

            [grid]
            cache-mb = "$caches$"
            reduce-tasks = "$deep-fanout$"
            "#,
        )
        .unwrap();
        assert_eq!(spec.n_cells(), 3);
        assert_eq!(spec.axes[0].1.len(), 3);
        let cfg = CellCfg::parse(&spec.cells()[2]).unwrap();
        assert_eq!(cfg.cache_mb, 16);
        assert_eq!(cfg.reduce_tasks, 4);

        let err = SuiteSpec::parse(
            "[grid]\ncache-mb = \"$missing$\"\n",
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("no `missing`"),
            "wrong error: {err}"
        );
    }

    #[test]
    fn unknown_sections_keys_and_values_are_rejected_up_front() {
        for (text, needle) in [
            ("[gird]\nworkload = \"eaglet\"\n", "unknown section"),
            ("[suite]\nrepz = 2\n[grid]\nbatch = \"on\"\n", "unknown key"),
            ("[grid]\nworkloads = [\"eaglet\"]\n", "unknown grid key"),
            ("[suite]\nname = \"x\"\n", "no [grid]"),
            ("[grid]\ncache-mb = -1\n", "non-negative"),
            ("[grid]\ncache-mb = 1.5\n", "non-negative integer"),
            ("[grid]\nreduce-tasks = 0\n", "positive integer"),
            ("[grid]\nworkers = 0\n", "positive integer"),
            ("[grid]\nstraggler-pct = 0\n", "(0, 100]"),
            ("[grid]\nstraggler-pct = 101\n", "(0, 100]"),
            ("[grid]\nworkload = \"netflix\"\n", "unknown workload"),
            ("[grid]\ntransport = \"udp\"\n", "inproc|tcp"),
            ("[grid]\nturbulence = \"storm\"\n", "off|slow"),
            ("[grid]\npartitioner = \"round\"\n", "hash|skew"),
            ("[grid]\naffinity = 1\n", "on|off"),
            ("[suite]\nreps = 0\n[grid]\nbatch = \"on\"\n", "positive"),
        ] {
            let err = SuiteSpec::parse(text).unwrap_err();
            assert!(
                err.to_string().contains(needle),
                "`{text}`: expected `{needle}` in `{err}`"
            );
        }
    }

    /// A tiny in-proc suite: rows carry the axes, the counters, and an
    /// `output` subtree bit-identical to a direct exec-default run of
    /// the same workload — the equivalence CI's suite smoke diffs at
    /// larger scale.
    #[test]
    fn suite_rows_match_direct_exec_runs_bit_for_bit() {
        let spec = SuiteSpec::parse(
            r#"
            [suite]
            name = "unit-smoke"
            reps = 2
            samples = 10

            [grid]
            workload = ["seqaddr", "ssag"]
            cache-mb = [0, 8]
            "#,
        )
        .unwrap();
        let rows = run_suite(&spec, native()).unwrap();
        assert_eq!(rows.len(), 4);
        for (ci, row) in rows.iter().enumerate() {
            assert_eq!(row.req_usize("cell").unwrap(), ci);
            assert_eq!(row.req_str("suite").unwrap(), "unit-smoke");
            assert_eq!(row.req_str("transport").unwrap(), "inproc");
            assert!(row.req_usize("cache_mb").is_ok());
            assert!(row.get("report").is_some(), "missing counters");
            let w = Workload::parse(row.req_str("workload").unwrap())
                .unwrap();
            // direct run with the cell's own config = the exec oracle
            let cfg = CellCfg {
                workload: w,
                cache_mb: row.req_usize("cache_mb").unwrap(),
                ..CellCfg::default()
            };
            let ds = build_small(
                w,
                &ModelParams::default(),
                spec.samples,
            );
            let direct =
                run_cluster(ds.as_ref(), native(), &cfg.exec_config(None))
                    .unwrap();
            assert_eq!(
                *row.get("output").unwrap(),
                direct.output.to_json(),
                "cell {ci} diverged from its direct exec run"
            );
        }
    }

    /// The TCP transport axis: a tcp cell's output equals the inproc
    /// cell's output on the same workload, through the full remote
    /// worker session path.
    #[test]
    fn tcp_cells_match_inproc_cells_bit_for_bit() {
        let spec = SuiteSpec::parse(
            r#"
            [suite]
            name = "tcp-smoke"
            reps = 1
            samples = 8

            [grid]
            transport = ["inproc", "tcp"]
            workload = "ssag"
            turbulence = "slow"
            "#,
        )
        .unwrap();
        let rows = run_suite(&spec, native()).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].req_str("transport").unwrap(), "inproc");
        assert_eq!(rows[1].req_str("transport").unwrap(), "tcp");
        assert_eq!(
            rows[0].get("output").unwrap(),
            rows[1].get("output").unwrap(),
            "transport changed the statistic"
        );
    }
}
