//! A tiny TOML-subset parser for suite grid files.
//!
//! The suite runner needs exactly the fragment of TOML a grid spec
//! uses — `[section]` headers, `key = value` pairs, `#` comments, and
//! scalar or single-line-array values — and the container ships no
//! external crates, so this hand-rolled reader covers that fragment
//! and nothing more. Values land as [`Json`] (the crate's common
//! dynamic value), sections and keys keep their declaration order
//! (axis order in the grid is the cross-product nesting order).
//!
//! Supported values:
//!
//! * basic strings: `"eaglet"` with `\\ \" \n \t` escapes
//! * booleans: `true` / `false`
//! * numbers: anything `f64::from_str` accepts (`8`, `0.5`, `-1`)
//! * single-line arrays of the above: `[1, 2, 4]`, `["a", "b"]`
//!
//! Anything outside the fragment — multi-line arrays, inline tables,
//! dotted keys, dates — is a parse error naming the line, not a silent
//! skip: a grid file that doesn't mean what it says must not run.

use crate::error::{Error, Result};
use crate::util::json::Json;

/// One parsed grid file: sections in declaration order, each holding
/// its `key = value` pairs in declaration order.
#[derive(Debug, Clone)]
pub struct TomlDoc {
    pub sections: Vec<(String, Vec<(String, Json)>)>,
}

impl TomlDoc {
    /// The pairs of `[name]`, if the section is present.
    pub fn section(&self, name: &str) -> Option<&[(String, Json)]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, pairs)| pairs.as_slice())
    }

    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut sections: Vec<(String, Vec<(String, Json)>)> = Vec::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| {
                    bad(lineno, "unterminated [section] header")
                })?;
                let name = name.trim();
                if name.is_empty() || !name.chars().all(is_key_char) {
                    return Err(bad(lineno, "bad section name"));
                }
                if sections.iter().any(|(n, _)| n == name) {
                    return Err(bad(
                        lineno,
                        &format!("duplicate section [{name}]"),
                    ));
                }
                sections.push((name.to_string(), Vec::new()));
                continue;
            }
            let (key, value) = split_pair(&line, lineno)?;
            let section = sections.last_mut().ok_or_else(|| {
                bad(lineno, "key before any [section] header")
            })?;
            if section.1.iter().any(|(k, _)| *k == key) {
                return Err(bad(
                    lineno,
                    &format!("duplicate key `{key}` in [{}]", section.0),
                ));
            }
            section.1.push((key, parse_value(&value, lineno)?));
        }
        Ok(TomlDoc { sections })
    }
}

fn bad(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("grid line {lineno}: {msg}"))
}

fn is_key_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '-'
}

/// Drop a `#` comment, but only outside a quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_str => escaped = true,
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn split_pair(line: &str, lineno: usize) -> Result<(String, String)> {
    let eq = line
        .find('=')
        .ok_or_else(|| bad(lineno, "expected `key = value`"))?;
    let key = line[..eq].trim();
    let value = line[eq + 1..].trim();
    if key.is_empty() || !key.chars().all(is_key_char) {
        return Err(bad(lineno, &format!("bad key `{key}`")));
    }
    if value.is_empty() {
        return Err(bad(lineno, &format!("`{key}` has no value")));
    }
    Ok((key.to_string(), value.to_string()))
}

fn parse_value(text: &str, lineno: usize) -> Result<Json> {
    if let Some(body) = text.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or_else(|| {
            bad(lineno, "arrays must open and close on one line")
        })?;
        let mut out = Vec::new();
        for item in split_array_items(body, lineno)? {
            out.push(parse_scalar(&item, lineno)?);
        }
        if out.is_empty() {
            return Err(bad(lineno, "empty axis array"));
        }
        return Ok(Json::Arr(out));
    }
    parse_scalar(text, lineno)
}

/// Split an array body on commas that sit outside quoted strings.
fn split_array_items(body: &str, lineno: usize) -> Result<Vec<String>> {
    let mut items = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    let mut escaped = false;
    for c in body.chars() {
        if escaped {
            cur.push(c);
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_str => {
                cur.push(c);
                escaped = true;
            }
            '"' => {
                cur.push(c);
                in_str = !in_str;
            }
            ',' if !in_str => {
                items.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if in_str {
        return Err(bad(lineno, "unterminated string in array"));
    }
    items.push(cur);
    let items: Vec<String> =
        items.into_iter().map(|s| s.trim().to_string()).collect();
    if items.iter().any(|s| s.is_empty()) {
        return Err(bad(lineno, "empty item in array (trailing comma?)"));
    }
    Ok(items)
}

fn parse_scalar(text: &str, lineno: usize) -> Result<Json> {
    if let Some(body) = text.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| bad(lineno, "unterminated string"))?;
        return Ok(Json::Str(unescape(body, lineno)?));
    }
    match text {
        "true" => return Ok(Json::Bool(true)),
        "false" => return Ok(Json::Bool(false)),
        _ => {}
    }
    text.parse::<f64>().map(Json::Num).map_err(|_| {
        bad(lineno, &format!("unsupported value `{text}`"))
    })
}

fn unescape(body: &str, lineno: usize) -> Result<String> {
    let mut out = String::with_capacity(body.len());
    let mut chars = body.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            if c == '"' {
                return Err(bad(lineno, "unescaped quote inside string"));
            }
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('\\') => out.push('\\'),
            Some('"') => out.push('"'),
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            other => {
                return Err(bad(
                    lineno,
                    &format!("bad escape `\\{}`", other.unwrap_or(' ')),
                ))
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn n(v: f64) -> Json {
        Json::Num(v)
    }

    #[test]
    fn parses_sections_keys_and_all_value_shapes() {
        let doc = TomlDoc::parse(
            r#"
            # a grid
            [suite]
            name = "smoke"   # trailing comment
            reps = 2
            deep = true

            [grid]
            workload = ["seqaddr", "ssag"]
            cache-mb = [0, 8]
            frac = 0.5
            note = "has # hash and \"quote\""
            "#,
        )
        .unwrap();
        assert_eq!(doc.sections.len(), 2);
        let suite = doc.section("suite").unwrap();
        assert_eq!(suite[0], ("name".into(), Json::Str("smoke".into())));
        assert_eq!(suite[1], ("reps".into(), n(2.0)));
        assert_eq!(suite[2], ("deep".into(), Json::Bool(true)));
        let grid = doc.section("grid").unwrap();
        assert_eq!(
            grid[0].1,
            Json::Arr(vec![
                Json::Str("seqaddr".into()),
                Json::Str("ssag".into())
            ])
        );
        assert_eq!(grid[1].1, Json::Arr(vec![n(0.0), n(8.0)]));
        assert_eq!(grid[2].1, n(0.5));
        assert_eq!(
            grid[3].1,
            Json::Str("has # hash and \"quote\"".into())
        );
        assert!(doc.section("missing").is_none());
    }

    #[test]
    fn keys_keep_declaration_order() {
        let doc =
            TomlDoc::parse("[g]\nb = 1\na = 2\nc = 3\n").unwrap();
        let keys: Vec<&str> = doc.section("g").unwrap()
            .iter()
            .map(|(k, _)| k.as_str())
            .collect();
        assert_eq!(keys, ["b", "a", "c"]);
    }

    #[test]
    fn malformed_grids_are_errors_not_silent_skips() {
        for (text, what) in [
            ("key = 1\n", "key before any section"),
            ("[s]\nkey = 1\nkey = 2\n", "duplicate key"),
            ("[s]\n[s]\n", "duplicate section"),
            ("[s\nkey = 1\n", "unterminated header"),
            ("[s]\nkey =\n", "missing value"),
            ("[s]\nkey 1\n", "missing equals"),
            ("[s]\nkey = [1,\n2]\n", "multi-line array"),
            ("[s]\nkey = []\n", "empty array"),
            ("[s]\nkey = [1,,2]\n", "empty item"),
            ("[s]\nkey = \"open\n", "unterminated string"),
            ("[s]\nkey = 1970-01-01\n", "dates unsupported"),
            ("[s]\nbad.dot = 1\n", "dotted key"),
        ] {
            let err = TomlDoc::parse(text).unwrap_err();
            assert!(
                matches!(err, Error::Config(ref m) if m.contains("line")),
                "{what}: wrong error {err:?}"
            );
        }
    }
}
