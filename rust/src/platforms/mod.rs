//! Platform models: BTS itself plus the comparison platforms of Table 1.
//!
//! BTS is *fully implemented* in this crate (scheduler + dfs + runtime);
//! Hadoop variants are overhead models calibrated once against the
//! thesis's own Figures 5–6 (DESIGN.md §6). Everything downstream —
//! the Fig 10/11 crossovers, SLO behaviour, elasticity — emerges from
//! the event model plus these constants.

pub mod spec;

pub use spec::{PlatformKind, PlatformSpec, SizingKind};

/// All platforms of Table 1 plus the three BashReduce sizing arms
/// (§4.1.3) and bare Linux (the Fig 6 baseline).
pub fn all_platforms() -> Vec<PlatformSpec> {
    vec![
        PlatformSpec::native_linux(),
        PlatformSpec::bts(),
        PlatformSpec::blt(),
        PlatformSpec::btt(),
        PlatformSpec::vanilla_hadoop(),
        PlatformSpec::job_level_hadoop(),
        PlatformSpec::lite_hadoop(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inventory_matches_table1() {
        let names: Vec<&str> =
            all_platforms().iter().map(|p| p.name).collect();
        for want in ["native-linux", "bts", "blt", "btt", "vanilla-hadoop", "job-level-hadoop", "lite-hadoop"] {
            assert!(names.contains(&want), "missing {want}");
        }
    }
}
