//! `PlatformSpec`: the Table-1 axes plus the calibrated overhead
//! constants.
//!
//! Calibration policy (DESIGN.md §6) — constants are pinned to the
//! thesis's own measurements and never re-fit per figure:
//!   * Fig 5: hello-world startup, 72 slots — VH ≈ 4× BashReduce;
//!     disabling task monitoring removes ~21% of VH's startup.
//!   * Fig 6: per-task runtime overhead vs native Linux — task
//!     monitoring ≈ +20%/task; bypassing HDFS temp files is the largest
//!     gain; BashReduce keeps ~12% scheduling overhead; native Linux
//!     still pays component fork/exec.
//!   * §4.1.3: VH uses an HDFS replication factor of N-2 and one map
//!     slot per core; JLH additionally disables speculative execution;
//!     LH fixes intermediate files (results incorrect — benchmark only).

/// Task-sizing policy a platform runs with (§4.1.3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SizingKind {
    /// offline kneepoint (BTS)
    Kneepoint,
    /// all samples on a node in one file (BLT; Hadoop's regime too)
    Large,
    /// one sample per task (BTT)
    Tiniest,
    /// fixed split size in bytes (Hadoop's block-driven splits)
    Fixed(usize),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlatformKind {
    NativeLinux,
    BashReduce,
    Hadoop,
}

#[derive(Debug, Clone)]
pub struct PlatformSpec {
    pub name: &'static str,
    pub kind: PlatformKind,
    // ---- Table 1 axes -------------------------------------------------
    pub task_level_recovery: bool,
    pub full_dfs: bool,
    pub java: bool,
    // ---- startup (Fig 5) ----------------------------------------------
    /// One-time job startup: base + per-slot (TCP handshakes, data
    /// staging, TaskTracker registration, ...).
    pub startup_base_s: f64,
    pub startup_per_slot_s: f64,
    // ---- per-task overheads (Fig 6) ------------------------------------
    /// Scheduling/dispatch cost per task.
    pub sched_per_task_s: f64,
    /// Software-component launch per task (JVM start for java platforms,
    /// fork/exec for the rest).
    pub launch_per_task_s: f64,
    /// Task-level monitoring/heartbeat cost per task (0 when disabled).
    pub monitor_per_task_s: f64,
    /// Distributed-FS fixed cost per task (intermediate temp-file
    /// create + replication round trips on HDFS; 0 on the local FS).
    /// This is what makes HDFS the dominant per-task cost even for
    /// 1-sample tasks (Fig 6's experiment).
    pub fs_per_task_s: f64,
    /// Distributed-FS penalty per MiB of task I/O (intermediate temp
    /// files on HDFS; 0 when the platform uses the local FS).
    pub fs_per_mib_s: f64,
    // ---- behaviour ------------------------------------------------------
    pub sizing: SizingKind,
    /// Speculative execution enabled (VH only; costs extra network).
    pub speculative: bool,
}

impl PlatformSpec {
    /// Total startup for a cluster with `slots` map slots.
    pub fn startup_s(&self, slots: usize) -> f64 {
        self.startup_base_s + self.startup_per_slot_s * slots as f64
    }

    /// Per-task overhead excluding compute, for a task of `mib` input.
    pub fn per_task_overhead_s(&self, mib: f64) -> f64 {
        self.sched_per_task_s
            + self.launch_per_task_s
            + self.monitor_per_task_s
            + self.fs_per_task_s
            + self.fs_per_mib_s * mib
    }

    // ---- presets (calibration constants live here, nowhere else) -------

    pub fn native_linux() -> Self {
        PlatformSpec {
            name: "native-linux",
            kind: PlatformKind::NativeLinux,
            task_level_recovery: false,
            full_dfs: false,
            java: false,
            startup_base_s: 0.0,
            startup_per_slot_s: 0.0,
            sched_per_task_s: 0.0,
            // fork/exec + interpreter start of one software component
            // (MERLIN/Perl-scale, not /bin/true)
            launch_per_task_s: 0.022,
            monitor_per_task_s: 0.0,
            fs_per_task_s: 0.0,
            fs_per_mib_s: 0.0,
            sizing: SizingKind::Tiniest,
            speculative: false,
        }
    }

    fn bashreduce(name: &'static str, sizing: SizingKind) -> Self {
        PlatformSpec {
            name,
            kind: PlatformKind::BashReduce,
            task_level_recovery: false,
            full_dfs: false,
            java: false,
            // nc6 pipe setup + data staging per slot: ≈13 s at 72
            // slots — VH's ≈52 s is 4× this (Fig 5)
            startup_base_s: 2.0,
            startup_per_slot_s: 0.15,
            // "BashReduce still incurred 12% overhead due to scheduling"
            // relative to native Linux per-task cost
            sched_per_task_s: 0.0026,
            launch_per_task_s: 0.022,
            monitor_per_task_s: 0.0,
            fs_per_task_s: 0.0,
            fs_per_mib_s: 0.0,
            sizing,
            speculative: false,
        }
    }

    /// BashReduce with Task Sizing — the thesis's platform.
    pub fn bts() -> Self {
        Self::bashreduce("bts", SizingKind::Kneepoint)
    }

    /// BashReduce with Large Tasks.
    pub fn blt() -> Self {
        Self::bashreduce("blt", SizingKind::Large)
    }

    /// BashReduce with Tiniest Tasks.
    pub fn btt() -> Self {
        Self::bashreduce("btt", SizingKind::Tiniest)
    }

    pub fn vanilla_hadoop() -> Self {
        PlatformSpec {
            name: "vanilla-hadoop",
            kind: PlatformKind::Hadoop,
            task_level_recovery: true,
            full_dfs: true,
            java: true,
            // 4× BashReduce startup at 72 slots ≈ 52 s (Fig 5); the
            // monitoring share of startup is ~21% (removed in JLH below)
            startup_base_s: 8.0,
            startup_per_slot_s: 0.60,
            sched_per_task_s: 0.010,
            // JVM start amortized across tasks via Hadoop's JVM reuse
            // (the big JVM cost shows up in *startup*, Fig 5)
            launch_per_task_s: 0.010,
            monitor_per_task_s: 0.012, // "20% degradation per task"
            fs_per_task_s: 0.020, // HDFS temp-file create + replication
            fs_per_mib_s: 0.012,  // HDFS volume cost
            sizing: SizingKind::Large,
            speculative: true,
        }
    }

    pub fn job_level_hadoop() -> Self {
        PlatformSpec {
            name: "job-level-hadoop",
            kind: PlatformKind::Hadoop,
            task_level_recovery: false,
            full_dfs: true,
            java: true,
            // VH minus the monitoring service (-21% startup)
            startup_base_s: 6.5,
            startup_per_slot_s: 0.47,
            sched_per_task_s: 0.010,
            launch_per_task_s: 0.010,
            monitor_per_task_s: 0.0,
            fs_per_task_s: 0.020,
            fs_per_mib_s: 0.012,
            sizing: SizingKind::Large,
            speculative: false,
        }
    }

    /// Benchmark-only: fixes intermediate files (incorrect results) to
    /// expose the floor of the Hadoop/JVM stack.
    pub fn lite_hadoop() -> Self {
        PlatformSpec {
            name: "lite-hadoop",
            kind: PlatformKind::Hadoop,
            task_level_recovery: false,
            full_dfs: false, // intermediate HDFS files avoided
            java: true,
            // startup stays Hadoop-heavy ("LH suffered from high startup
            // costs when job sizes were small, essentially matching VH")
            startup_base_s: 6.5,
            startup_per_slot_s: 0.47,
            sched_per_task_s: 0.010,
            // JVM-in-the-loop component start (no reuse for the legacy
            // pipeline's non-Java components)
            launch_per_task_s: 0.016,
            monitor_per_task_s: 0.0,
            fs_per_task_s: 0.0,
            fs_per_mib_s: 0.0,
            sizing: SizingKind::Large,
            speculative: false,
        }
    }

    /// BTS with the system-level monitoring add-on of §4.2.2 ("BTS with
    /// monitoring suffered a 21% slowdown on MB-sized jobs ... runtime
    /// overhead caused an additional 15%").
    pub fn bts_with_monitoring() -> Self {
        let mut p = Self::bts();
        p.name = "bts+monitor";
        p.startup_base_s *= 1.18;
        p.startup_per_slot_s *= 1.25;
        p.monitor_per_task_s = 0.0007;
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_startup_ratios_hold() {
        // hello-world startup at 72 slots, normalized to BashReduce
        let br = PlatformSpec::bts().startup_s(72);
        let vh = PlatformSpec::vanilla_hadoop().startup_s(72);
        let jlh = PlatformSpec::job_level_hadoop().startup_s(72);
        let ratio_vh = vh / br;
        assert!(
            (3.4..=4.6).contains(&ratio_vh),
            "VH/BR startup ratio {ratio_vh} should be ≈4 (Fig 5)"
        );
        let monitor_share = (vh - jlh) / vh;
        assert!(
            (0.15..=0.27).contains(&monitor_share),
            "monitoring share of VH startup {monitor_share} should be ≈21%"
        );
    }

    #[test]
    fn fig6_per_task_ordering_holds() {
        // per-task overhead on a 2.5 MiB task: VH > JLH > LH > BTS > native
        let mib = 2.5;
        let vh = PlatformSpec::vanilla_hadoop().per_task_overhead_s(mib);
        let jlh = PlatformSpec::job_level_hadoop().per_task_overhead_s(mib);
        let lh = PlatformSpec::lite_hadoop().per_task_overhead_s(mib);
        let bts = PlatformSpec::bts().per_task_overhead_s(mib);
        let native = PlatformSpec::native_linux().per_task_overhead_s(mib);
        assert!(vh > jlh && jlh > lh && lh > bts && bts > native);
        // monitoring ≈ +20% of VH's per-task overhead
        let share = (vh - jlh) / vh;
        assert!((0.1..=0.3).contains(&share), "monitor share {share}");
        // HDFS bypass is the largest single gain (JLH -> LH)
        assert!((jlh - lh) > (lh - bts), "HDFS should dominate");
    }

    #[test]
    fn bts_overhead_small_vs_task_time() {
        // a kneepoint EAGLET task (~2.5 MB input) computes for ~1.3 s
        // (0.52 s/MiB); BTS platform overhead — even with all 6
        // component launches — must stay a small fraction of that
        let bts = PlatformSpec::bts();
        let o = bts.per_task_overhead_s(2.5) + bts.launch_per_task_s * 5.0;
        let compute = 2.5 * 0.52;
        assert!(
            o / compute < 0.15,
            "BTS per-task overhead {o}s is {:.0}% of task compute",
            o / compute * 100.0
        );
        // ...and scheduling proper stays around the thesis's 12% of the
        // native per-task cost
        let native = PlatformSpec::native_linux().per_task_overhead_s(2.5);
        let sched_share = (bts.per_task_overhead_s(2.5) - native) / native;
        assert!(
            (0.05..=0.20).contains(&sched_share),
            "sched share {sched_share}"
        );
    }

    #[test]
    fn monitoring_addon_costs() {
        let b = PlatformSpec::bts();
        let m = PlatformSpec::bts_with_monitoring();
        assert!(m.startup_s(72) > b.startup_s(72) * 1.15);
        assert!(m.monitor_per_task_s > 0.0);
    }
}
