//! Workload bindings: turn a `Workload` tag into a generated dataset at
//! a target size, and calibrate the simulator's compute constant from
//! *measured* PJRT execution of the real kernels.

pub mod calibration;

pub use calibration::{default_compute_s_per_mib, measure_compute_s_per_mib};

use crate::data::eaglet::{EagletConfig, EagletDataset};
use crate::data::netflix::{NetflixConfig, NetflixDataset};
use crate::data::seqaddr::{SeqAddrConfig, SeqAddrDataset};
use crate::data::ssag::{SsagConfig, SsagDataset};
use crate::data::{Dataset, ModelParams, Workload};

/// Original-dataset sizes from the thesis (§4.1.1): the bi-polar study's
/// 400 families and a Netflix slice at `movies` samples. The series
/// workloads (Pan et al. 2021, Politis 2021) default to enough series
/// that every compiled bucket size gets exercised.
pub const EAGLET_BASE_FAMILIES: usize = 400;
pub const NETFLIX_BASE_MOVIES: usize = 2000;
pub const SEQADDR_BASE_SERIES: usize = 1024;
pub const SSAG_BASE_SERIES: usize = 1024;

/// Build a dataset for `workload`, optionally scaled up to roughly
/// `target_bytes` with statistically-similar synthetic samples
/// (§4.1.1.1: "As we scaled our experiments we simulated data from the
/// original computation").
pub fn build(
    workload: Workload,
    params: &ModelParams,
    target_bytes: Option<usize>,
) -> Box<dyn Dataset> {
    match workload {
        Workload::Eaglet => {
            let base = EagletDataset::generate(
                params,
                EagletConfig {
                    families: EAGLET_BASE_FAMILIES,
                    ..Default::default()
                },
            );
            Box::new(match target_bytes {
                Some(t) if t > base.total_bytes() => base.scaled_to(t),
                _ => base,
            })
        }
        Workload::NetflixHi | Workload::NetflixLo => {
            let base = NetflixDataset::generate(
                params,
                NetflixConfig {
                    movies: NETFLIX_BASE_MOVIES,
                    high_confidence: workload == Workload::NetflixHi,
                    ..Default::default()
                },
            );
            Box::new(match target_bytes {
                Some(t) if t > base.total_bytes() => base.scaled_to(t),
                _ => base,
            })
        }
        Workload::SeqAddr => {
            let base = SeqAddrDataset::generate(
                params,
                SeqAddrConfig {
                    series: SEQADDR_BASE_SERIES,
                    ..Default::default()
                },
            );
            Box::new(match target_bytes {
                Some(t) if t > base.total_bytes() => base.scaled_to(t),
                _ => base,
            })
        }
        Workload::Ssag => {
            let base = SsagDataset::generate(
                params,
                SsagConfig {
                    series: SSAG_BASE_SERIES,
                    ..Default::default()
                },
            );
            Box::new(match target_bytes {
                Some(t) if t > base.total_bytes() => base.scaled_to(t),
                _ => base,
            })
        }
    }
}

/// A smaller build for tests and examples that cannot afford staging
/// hundreds of MB.
pub fn build_small(
    workload: Workload,
    params: &ModelParams,
    samples: usize,
) -> Box<dyn Dataset> {
    match workload {
        Workload::Eaglet => Box::new(EagletDataset::generate(
            params,
            EagletConfig { families: samples, ..Default::default() },
        )),
        Workload::NetflixHi | Workload::NetflixLo => {
            Box::new(NetflixDataset::generate(
                params,
                NetflixConfig {
                    movies: samples,
                    high_confidence: workload == Workload::NetflixHi,
                    ..Default::default()
                },
            ))
        }
        Workload::SeqAddr => Box::new(SeqAddrDataset::generate(
            params,
            SeqAddrConfig { series: samples, ..Default::default() },
        )),
        Workload::Ssag => Box::new(SsagDataset::generate(
            params,
            SsagConfig { series: samples, ..Default::default() },
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_small_respects_workload_tag() {
        let p = ModelParams::default();
        for w in Workload::ALL {
            let ds = build_small(w, &p, 10);
            assert_eq!(ds.workload(), w);
            assert_eq!(ds.metas().len(), 10);
            assert!(ds.total_bytes() > 0);
        }
    }

    #[test]
    fn build_scales_to_target() {
        let p = ModelParams::default();
        let small = build(Workload::NetflixLo, &p, None);
        let target = small.total_bytes() * 2;
        let big = build(Workload::NetflixLo, &p, Some(target));
        assert!(big.total_bytes() >= target);
    }
}
