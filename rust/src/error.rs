//! Crate-wide error type. Library APIs return `bts::Result<T>`;
//! binaries/examples convert to `anyhow` at the edge.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),

    #[error("json error: {0}")]
    Json(#[from] crate::util::json::JsonError),

    #[error("config error: {0}")]
    Config(String),

    #[error("data error: {0}")]
    Data(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("scheduler error: {0}")]
    Scheduler(String),

    #[error("dfs error: {0}")]
    Dfs(String),

    #[error("job failed after {attempts} attempts: {cause}")]
    JobFailed { attempts: u32, cause: String },

    #[error("protocol error: {0}")]
    Protocol(String),

    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<anyhow::Error> for Error {
    fn from(e: anyhow::Error) -> Self {
        Error::Other(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_variants() {
        let e = Error::Config("bad cluster".into());
        assert_eq!(e.to_string(), "config error: bad cluster");
        let e = Error::JobFailed { attempts: 3, cause: "node died".into() };
        assert!(e.to_string().contains("3 attempts"));
    }

    #[test]
    fn converts_io() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "x");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
