//! Crate-wide error type. Library APIs return `bts::Result<T>`;
//! binaries and examples bubble the same type to `main`.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`/`anyhow`): the
//! offline vendor set carries no proc-macro crates, and the variant
//! list is small and stable enough that the explicit impls double as
//! documentation of every failure domain.

use std::fmt;

use crate::util::json::JsonError;

#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),
    Xla(String),
    Json(JsonError),
    Config(String),
    Data(String),
    Artifact(String),
    Scheduler(String),
    /// A job was refused at service admission (deadline infeasible).
    Admission(String),
    /// A job was load-shed by the federation front-door; carries the
    /// Retry-After backoff hint from the `Shed` wire frame.
    Shed { retry_after_s: f64, reason: String },
    Dfs(String),
    JobFailed { attempts: u32, cause: String },
    Protocol(String),
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Json(e) => write!(f, "json error: {e}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Scheduler(m) => write!(f, "scheduler error: {m}"),
            Error::Admission(m) => write!(f, "admission rejected: {m}"),
            Error::Shed { retry_after_s, reason } => write!(
                f,
                "load shed: {reason} (retry after {retry_after_s:.1}s)"
            ),
            Error::Dfs(m) => write!(f, "dfs error: {m}"),
            Error::JobFailed { attempts, cause } => {
                write!(f, "job failed after {attempts} attempts: {cause}")
            }
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<JsonError> for Error {
    fn from(e: JsonError) -> Self {
        Error::Json(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_variants() {
        let e = Error::Config("bad cluster".into());
        assert_eq!(e.to_string(), "config error: bad cluster");
        let e = Error::JobFailed { attempts: 3, cause: "node died".into() };
        assert!(e.to_string().contains("3 attempts"));
        let e = Error::Shed {
            retry_after_s: 2.25,
            reason: "shard 0 saturated".into(),
        };
        assert_eq!(
            e.to_string(),
            "load shed: shard 0 saturated (retry after 2.2s)"
        );
    }

    #[test]
    fn converts_io() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "x");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }

    #[test]
    fn json_error_is_chained_as_source() {
        use std::error::Error as _;
        let je = JsonError { msg: "boom".into(), pos: 3 };
        let e: Error = je.into();
        assert!(e.source().is_some());
        assert!(e.to_string().contains("boom"));
    }
}
