//! Configuration system: a TOML-subset file format plus `--key=value`
//! CLI overrides, resolving to a [`JobConfig`] + cluster/workload
//! selection. The same `Config` drives `bts run`, the net leader, and
//! the figure generators.
//!
//! Accepted file syntax (a strict TOML subset — enough for flat
//! platform configs without pulling a dependency):
//!
//! ```toml
//! [job]
//! workload = "eaglet"      # eaglet | netflix_hi | netflix_lo
//! sizing = "kneepoint"     # kneepoint | tiniest | large | <bytes>
//! workers = 6
//! seed = 42
//!
//! [dfs]
//! data_nodes = 4
//! adaptive_rf = true
//! lan = false
//! ```

use crate::coordinator::JobConfig;
use crate::data::Workload;
use crate::dfs::LatencyModel;
use crate::error::{Error, Result};
use crate::kneepoint::TaskSizing;

/// Resolved configuration. Field names mirror the file keys
/// (`section.key`).
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    pub workload: Workload,
    /// "kneepoint" resolves via the offline profiler at run time;
    /// explicit bytes pin the task size.
    pub sizing: SizingChoice,
    pub workers: usize,
    pub data_nodes: usize,
    pub adaptive_rf: bool,
    /// Use the LAN latency model on the data nodes (true) or the
    /// in-memory fast path (false).
    pub lan: bool,
    pub monitoring: bool,
    pub prefetch_k: usize,
    pub seed: u64,
    /// Scale the dataset to roughly this many bytes (None = original).
    pub job_bytes: Option<usize>,
    /// SLO bound in seconds (planner / reporting only).
    pub slo_s: Option<f64>,
    pub platform: String,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SizingChoice {
    Kneepoint,
    Tiniest,
    Large,
    FixedBytes(usize),
}

impl Default for Config {
    fn default() -> Self {
        Config {
            workload: Workload::Eaglet,
            sizing: SizingChoice::Kneepoint,
            workers: 4,
            data_nodes: 4,
            adaptive_rf: true,
            lan: false,
            monitoring: false,
            prefetch_k: 8,
            seed: 0xB75,
            job_bytes: None,
            slo_s: None,
            platform: "bts".into(),
        }
    }
}

impl Config {
    /// Parse a config file (see module docs for the accepted subset).
    pub fn from_toml(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) =
                line.strip_prefix('[').and_then(|s| s.strip_suffix(']'))
            {
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let full = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            cfg.set(&full, value.trim())?;
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Config> {
        Self::from_toml(&std::fs::read_to_string(path)?)
    }

    /// Apply one `key=value` override (CLI `--set job.workers=8`, or the
    /// short keys without a section).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let v = unquote(value);
        let short = key.rsplit('.').next().unwrap_or(key);
        match short {
            "workload" => {
                self.workload = Workload::parse(v).ok_or_else(|| {
                    Error::Config(format!("unknown workload {v}"))
                })?;
            }
            "sizing" => {
                self.sizing = match v {
                    "kneepoint" => SizingChoice::Kneepoint,
                    "tiniest" => SizingChoice::Tiniest,
                    "large" => SizingChoice::Large,
                    n => SizingChoice::FixedBytes(parse_bytes(n)?),
                };
            }
            "workers" => self.workers = parse_num(v)? as usize,
            "data_nodes" => self.data_nodes = parse_num(v)? as usize,
            "adaptive_rf" => self.adaptive_rf = parse_bool(v)?,
            "lan" => self.lan = parse_bool(v)?,
            "monitoring" => self.monitoring = parse_bool(v)?,
            "prefetch_k" => self.prefetch_k = parse_num(v)? as usize,
            "seed" => self.seed = parse_num(v)? as u64,
            "job_bytes" | "job_size" => {
                self.job_bytes = Some(parse_bytes(v)?)
            }
            "slo_s" => {
                self.slo_s = Some(v.parse().map_err(|_| {
                    Error::Config(format!("bad slo_s: {v}"))
                })?)
            }
            "platform" => self.platform = v.to_string(),
            other => {
                return Err(Error::Config(format!("unknown key {other}")))
            }
        }
        Ok(())
    }

    /// Resolve to a coordinator `JobConfig`; `kneepoint_bytes` supplies
    /// the profiled knee when sizing is `Kneepoint`.
    pub fn to_job_config(&self, kneepoint_bytes: usize) -> JobConfig {
        let sizing = match self.sizing {
            SizingChoice::Kneepoint => TaskSizing::Kneepoint(kneepoint_bytes),
            SizingChoice::Tiniest => TaskSizing::Tiniest,
            SizingChoice::Large => {
                TaskSizing::LargeSn { workers: self.workers }
            }
            SizingChoice::FixedBytes(b) => TaskSizing::Fixed(b),
        };
        JobConfig {
            sizing,
            workers: self.workers,
            data_nodes: self.data_nodes,
            latency: if self.lan {
                LatencyModel::lan()
            } else {
                LatencyModel::none()
            },
            adaptive_rf: self.adaptive_rf,
            prefetch_k: self.prefetch_k,
            monitoring: self.monitoring,
            seed: self.seed,
            platform: self.platform.clone(),
            ..JobConfig::default()
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // no '#' inside our quoted strings contain # rarely; honor quotes
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> &str {
    v.strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .unwrap_or(v)
}

fn parse_bool(v: &str) -> Result<bool> {
    match v {
        "true" | "yes" | "1" => Ok(true),
        "false" | "no" | "0" => Ok(false),
        _ => Err(Error::Config(format!("bad bool: {v}"))),
    }
}

fn parse_num(v: &str) -> Result<i64> {
    v.replace('_', "")
        .parse()
        .map_err(|_| Error::Config(format!("bad number: {v}")))
}

/// Accept raw bytes or human sizes: `1536`, `24kb`, `2.5mb`, `1gb`, `1tb`.
pub fn parse_bytes(v: &str) -> Result<usize> {
    let s = v.to_ascii_lowercase();
    let (num, mult) = if let Some(n) = s.strip_suffix("tb") {
        (n, 1u64 << 40)
    } else if let Some(n) = s.strip_suffix("gb") {
        (n, 1 << 30)
    } else if let Some(n) = s.strip_suffix("mb") {
        (n, 1 << 20)
    } else if let Some(n) = s.strip_suffix("kb") {
        (n, 1 << 10)
    } else {
        (s.as_str(), 1)
    };
    let f: f64 = num
        .trim()
        .parse()
        .map_err(|_| Error::Config(format!("bad size: {v}")))?;
    if f < 0.0 {
        return Err(Error::Config(format!("negative size: {v}")));
    }
    Ok((f * mult as f64) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_round_trips_through_job_config() {
        let c = Config::default();
        let jc = c.to_job_config(1024 * 1024);
        assert_eq!(jc.workers, c.workers);
        assert_eq!(jc.sizing, TaskSizing::Kneepoint(1024 * 1024));
    }

    #[test]
    fn parses_full_file() {
        let text = r#"
# cluster setup
[job]
workload = "netflix_hi"
sizing = "1mb"          # the thesis's Netflix knee
workers = 6
seed = 7

[dfs]
data_nodes = 8
adaptive_rf = false
lan = true
"#;
        let c = Config::from_toml(text).unwrap();
        assert_eq!(c.workload, Workload::NetflixHi);
        assert_eq!(c.sizing, SizingChoice::FixedBytes(1 << 20));
        assert_eq!(c.workers, 6);
        assert_eq!(c.seed, 7);
        assert_eq!(c.data_nodes, 8);
        assert!(!c.adaptive_rf);
        assert!(c.lan);
    }

    #[test]
    fn named_sizings_parse() {
        for (s, want) in [
            ("kneepoint", SizingChoice::Kneepoint),
            ("tiniest", SizingChoice::Tiniest),
            ("large", SizingChoice::Large),
        ] {
            let mut c = Config::default();
            c.set("sizing", s).unwrap();
            assert_eq!(c.sizing, want);
        }
    }

    #[test]
    fn human_sizes() {
        assert_eq!(parse_bytes("2.5mb").unwrap(), (2.5 * 1048576.0) as usize);
        assert_eq!(parse_bytes("24kb").unwrap(), 24 * 1024);
        assert_eq!(parse_bytes("1tb").unwrap(), 1 << 40);
        assert_eq!(parse_bytes("512").unwrap(), 512);
        assert!(parse_bytes("alot").is_err());
    }

    #[test]
    fn bad_inputs_error_cleanly() {
        assert!(Config::from_toml("workers 6").is_err());
        let mut c = Config::default();
        assert!(c.set("workload", "hbase").is_err());
        assert!(c.set("no_such_key", "1").is_err());
        assert!(c.set("adaptive_rf", "maybe").is_err());
    }

    #[test]
    fn comments_and_quotes() {
        let c = Config::from_toml(
            "workload = \"eaglet\" # the genetic study\nworkers = 12\n",
        )
        .unwrap();
        assert_eq!(c.workload, Workload::Eaglet);
        assert_eq!(c.workers, 12);
    }
}
