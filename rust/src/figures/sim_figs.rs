//! Event-simulator figures: everything in §4.2 that needed the 72-core
//! testbed (Figs 4, 8, 10–16, the heterogeneity study, the headline).

use super::Ctx;
use crate::data::Workload;
use crate::platforms::{PlatformSpec, SizingKind};
use crate::sim::{
    default_params, simulate, sweep_reduce_tasks, Cluster, HardwareType,
    SimParams, VIRT_SLOWDOWN,
};
use crate::util::render_table;

const MB: usize = 1024 * 1024;
const GB: usize = 1024 * MB;

/// Build SimParams once per workload and retarget job size cheaply (the
/// penalty curve and knee do not depend on job size).
fn base_params(ctx: &Ctx, w: Workload) -> SimParams {
    default_params(w, 256 * MB, ctx.compute_s_per_mib(w))
}

fn at_size(base: &SimParams, job_bytes: usize) -> SimParams {
    SimParams { job_bytes, ..base.clone() }
}

fn c72() -> Cluster {
    Cluster::homogeneous(HardwareType::TypeII, 6)
}

/// Fig 4: kneepoint sizing vs the 24 MB large-task baseline vs tiniest,
/// with and without the outlier samples.
pub fn fig4(ctx: &Ctx) -> String {
    let cluster = c72();
    let base = base_params(ctx, Workload::Eaglet);
    // ~30 subsamples per family over the 230MB study ⇒ 6.9GB of task work
    let job = 6_900 * MB;
    let mut rows = Vec::new();
    let mut summaries = Vec::new();
    for outliers in [false, true] {
        let p = SimParams { outliers, ..at_size(&base, job) };
        let mut spec24 = PlatformSpec::bts();
        spec24.sizing = SizingKind::Fixed(24 * MB);
        let t24 = simulate(&spec24, &cluster, &p).throughput_mbs;
        let knee = simulate(&PlatformSpec::bts(), &cluster, &p);
        let tiny = simulate(&PlatformSpec::btt(), &cluster, &p);
        for (name, r) in [
            ("24MB large (baseline)", t24),
            ("kneepoint (BTS)", knee.throughput_mbs),
            ("tiniest (BTT)", tiny.throughput_mbs),
        ] {
            rows.push(vec![
                if outliers { "with outliers" } else { "no outliers" }
                    .to_string(),
                name.to_string(),
                format!("{r:.1}"),
                format!("{:+.0}%", (r / t24 - 1.0) * 100.0),
            ]);
        }
        summaries.push((
            outliers,
            (knee.throughput_mbs / t24 - 1.0) * 100.0,
            (tiny.throughput_mbs / t24 - 1.0) * 100.0,
        ));
    }
    format!(
        "{}\nkneepoint gain: {:+.0}% (no outliers), {:+.0}% (with outliers)\n\
         paper: kneepoint +15% without outliers, +23% with; tiniest -8%;\n\
         paper: outliers themselves cost 2.4x; task sizing helps more under\n\
         paper: the heterogeneous (outlier) workload but cannot erase it\n",
        render_table(
            "Fig 4 — kneepoint algorithm vs 24MB large tasks (EAGLET, 72 cores)",
            &["dataset", "sizing", "MB/s", "vs 24MB"],
            &rows,
        ),
        summaries[0].1,
        summaries[1].1,
    )
}

/// Fig 8: the three BashReduce configurations on both workloads,
/// original dataset sizes, 72 cores.
pub fn fig8(ctx: &Ctx) -> String {
    let cluster = c72();
    let mut rows = Vec::new();
    let mut margins = Vec::new();
    for (w, job, label) in [
        (Workload::Eaglet, 6_900 * MB, "EAGLET (230MB x30)"),
        (Workload::NetflixHi, 2 * GB, "Netflix high-conf (2GB)"),
        (Workload::NetflixLo, 2 * GB, "Netflix low-conf (2GB)"),
    ] {
        let p = at_size(&base_params(ctx, w), job);
        let bts = simulate(&PlatformSpec::bts(), &cluster, &p);
        let blt = simulate(&PlatformSpec::blt(), &cluster, &p);
        let btt = simulate(&PlatformSpec::btt(), &cluster, &p);
        for (name, r) in
            [("BTS", &bts), ("BLT", &blt), ("BTT", &btt)]
        {
            rows.push(vec![
                label.to_string(),
                name.to_string(),
                format!("{:.1}", r.throughput_mbs),
                format!("{}", r.tasks),
                format!("{:.2}", r.total_s),
            ]);
        }
        let runner_up = blt.throughput_mbs.max(btt.throughput_mbs);
        margins.push((
            label,
            (bts.throughput_mbs / blt.throughput_mbs - 1.0) * 100.0,
            (bts.throughput_mbs / runner_up - 1.0) * 100.0,
        ));
    }
    let mut tail = String::new();
    for (label, vs_blt, vs_best) in margins {
        tail.push_str(&format!(
            "{label}: BTS {vs_blt:+.0}% vs BLT, {vs_best:+.0}% vs runner-up\n"
        ));
    }
    format!(
        "{}\n{tail}paper: BTS 10-90% over BLT and 26-32% over BTT on EAGLET;\n\
         paper: Netflix favors BTT more (fewer components) — BTS still wins,\n\
         paper: typically beating its closest competitor by ~17%\n",
        render_table(
            "Fig 8 — BTS vs BLT vs BTT, 72 cores, original datasets",
            &["workload", "config", "MB/s", "tasks", "total s"],
            &rows,
        )
    )
}

/// Fig 10: throughput of BTS vs VH/JLH across job sizes, plus the
/// monitoring-enabled BTS arm.
pub fn fig10(ctx: &Ctx) -> String {
    let cluster = c72();
    let base = base_params(ctx, Workload::Eaglet);
    let mut rows = Vec::new();
    let mut small_speedups = (0.0, 0.0);
    for job in [12 * MB, 91 * MB, 230 * MB, GB, 4 * GB, 16 * GB] {
        let p = at_size(&base, job);
        let bts = simulate(&PlatformSpec::bts(), &cluster, &p);
        let btsm =
            simulate(&PlatformSpec::bts_with_monitoring(), &cluster, &p);
        let vh = simulate(&PlatformSpec::vanilla_hadoop(), &cluster, &p);
        let jlh = simulate(&PlatformSpec::job_level_hadoop(), &cluster, &p);
        if job == 12 * MB {
            small_speedups = (
                vh.total_s / bts.total_s,
                jlh.total_s / bts.total_s,
            );
        }
        rows.push(vec![
            human(job),
            format!("{:.1}", bts.throughput_mbs),
            format!("{:.1}", btsm.throughput_mbs),
            format!("{:.1}", vh.throughput_mbs),
            format!("{:.1}", jlh.throughput_mbs),
            format!("{:.1}x", vh.total_s / bts.total_s),
            format!("{:.1}x", jlh.total_s / bts.total_s),
        ]);
    }
    format!(
        "{}\n12MB job: BTS speeds up VH {:.1}x, JLH {:.1}x\n\
         paper: ~5x over VH and 3.7x over JLH at 12MB, shrinking as VH\n\
         paper: amortizes startup; BTS+monitoring loses 21% on MB jobs and\n\
         paper: 15% on GB jobs yet stays 2.5x/1.5x ahead of JLH\n",
        render_table(
            "Fig 10 — BTS vs Hadoop setups (EAGLET, type 2, 72 cores)",
            &[
                "job", "BTS MB/s", "BTS+mon MB/s", "VH MB/s", "JLH MB/s",
                "VH/BTS", "JLH/BTS",
            ],
            &rows,
        ),
        small_speedups.0,
        small_speedups.1,
    )
}

/// Fig 11: absolute running time vs job size (log-log in the paper).
pub fn fig11(ctx: &Ctx) -> String {
    let cluster = c72();
    let base = base_params(ctx, Workload::Eaglet);
    let mut rows = Vec::new();
    let mut marks = (0.0, 0.0, 0.0);
    for job in [
        12 * MB,
        91 * MB,
        230 * MB,
        1100 * MB,
        8 * GB,
        64 * GB,
        GB * 1024,
    ] {
        let p = at_size(&base, job);
        let bts = simulate(&PlatformSpec::bts(), &cluster, &p);
        let vh = simulate(&PlatformSpec::vanilla_hadoop(), &cluster, &p);
        let lh = simulate(&PlatformSpec::lite_hadoop(), &cluster, &p);
        if job == 91 * MB {
            marks.0 = bts.total_s;
        }
        if job == 230 * MB {
            marks.1 = bts.total_s;
        }
        if job == GB * 1024 {
            marks.2 = lh.total_s / bts.total_s;
        }
        rows.push(vec![
            human(job),
            format!("{:.1}", bts.total_s),
            format!("{:.1}", vh.total_s),
            format!("{:.1}", lh.total_s),
        ]);
    }
    format!(
        "{}\n91MB on BTS: {:.0}s; 230MB: {:.0}s; LH/BTS at 1TB: {:.2}x\n\
         paper: 91MB in 40s (150s on VH); 230MB in 68s; LH tracks VH on\n\
         paper: small jobs (startup) and approaches BTS at scale, but BTS\n\
         paper: keeps a 25% throughput lead even at 1TB (note log-log)\n",
        render_table(
            "Fig 11 — running time vs job size (EAGLET, 72 cores)",
            &["job", "BTS s", "VH s", "LH s"],
            &rows,
        ),
        marks.0,
        marks.1,
        marks.2,
    )
}

/// Fig 12: EAGLET on BTS as the core count changes; network utilization.
pub fn fig12(ctx: &Ctx) -> String {
    let base = base_params(ctx, Workload::Eaglet);
    let mut rows = Vec::new();
    let mut util72 = 0.0;
    for job in [32 * MB, 230 * MB, 2 * GB, 16 * GB, 128 * GB, GB * 1024] {
        let p = at_size(&base, job);
        let mut row = vec![human(job)];
        for nodes in [1, 3, 6] {
            let cluster = Cluster::homogeneous(HardwareType::TypeII, nodes);
            let r = simulate(&PlatformSpec::bts(), &cluster, &p);
            row.push(format!("{:.1}", r.throughput_mbs));
            if nodes == 6 && job == GB * 1024 {
                util72 = r.network_utilization;
            }
        }
        rows.push(row);
    }
    format!(
        "{}\n72-core network utilization at 1TB: {:.0}%\n\
         paper: linear scaling up to 1TB on a 1Gb/s network; the 72-core\n\
         paper: test ran at 45% of network capacity; regions where 72-core\n\
         paper: equals 36-core reflect startup costs on small jobs\n",
        render_table(
            "Fig 12 — EAGLET on BTS as cores scale (MB/s)",
            &["job", "12 cores", "36 cores", "72 cores"],
            &rows,
        ),
        util72 * 100.0,
    )
}

/// Fig 13: throughput under SLOs relative to unconstrained peak.
pub fn fig13(ctx: &Ctx) -> String {
    let jobs: Vec<usize> = [4, 16, 64, 230, 1024, 4096, 16384, 65536]
        .iter()
        .map(|mb| mb * MB)
        .collect();
    let cores = [12, 36, 72];
    let mut rows = Vec::new();
    let mut marks = (0.0, 0.0);
    for (label, slo_s) in [
        ("30 s", 30.0),
        ("1 min", 60.0),
        ("2 min", 120.0),
        ("5 min", 300.0),
        ("10 min", 600.0),
        ("1 hour", 3600.0),
    ] {
        let plan = crate::slo::best_under_slo(
            Workload::Eaglet,
            slo_s,
            &cores,
            &jobs,
            ctx.compute_s_per_mib(Workload::Eaglet),
        );
        match plan {
            Some(p) => {
                if label == "2 min" {
                    marks.0 = p.frac_of_peak;
                }
                if label == "5 min" {
                    marks.1 = p.frac_of_peak;
                }
                rows.push(vec![
                    label.to_string(),
                    format!("{}", p.best.cores),
                    human(p.best.job_bytes),
                    format!("{:.1}", p.best.total_s),
                    format!("{:.1}", p.best.throughput_mbs),
                    format!("{:.0}%", p.frac_of_peak * 100.0),
                ]);
            }
            None => rows.push(vec![
                label.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "infeasible".into(),
            ]),
        }
    }
    format!(
        "{}\n2-minute SLO achieves {:.0}% of peak; 5-minute {:.0}%\n\
         paper: 2min SLO → 50% of peak throughput; 5min → 83%; 72 cores\n\
         paper: only win for the 2- and 5-minute bounds (startup costs)\n",
        render_table(
            "Fig 13 — best configuration under a fixed running-time bound",
            &["SLO", "cores", "job", "time s", "MB/s", "of peak"],
            &rows,
        ),
        marks.0 * 100.0,
        marks.1 * 100.0,
    )
}

/// Fig 14: Netflix scaling on virtualized Type-3 Opterons.
pub fn fig14(ctx: &Ctx) -> String {
    let base = base_params(ctx, Workload::NetflixHi);
    let job = 2 * GB;
    let p = at_size(&base, job);
    let mut rows = Vec::new();
    let mut tp = Vec::new();
    for nodes in [1, 2, 3, 4] {
        let virt = Cluster::homogeneous(HardwareType::TypeIII, nodes);
        let r = simulate(&PlatformSpec::bts(), &virt, &p);
        tp.push(r.throughput_mbs);
        rows.push(vec![
            format!("{}", virt.total_cores()),
            format!("{:.1}", r.throughput_mbs),
            format!("{:.1}", r.total_s),
        ]);
    }
    // virtualization cost vs a would-be bare-metal type 3
    let linear = tp
        .iter()
        .enumerate()
        .skip(1)
        .all(|(i, t)| *t > tp[0] * (i as f64 + 1.0) * 0.6);
    format!(
        "{}\nscaling {} (virtualization slowdown modeled at {:.0}%)\n\
         paper: linear improvement for Netflix as type-3 cores scale; 16%\n\
         paper: slowdown vs bare-metal type 2 across both workloads;\n\
         paper: re-profiled knees on this hardware: EAGLET 1.2MB, Netflix 1MB\n",
        render_table(
            "Fig 14 — Netflix on virtualized Type-3 hardware",
            &["cores", "MB/s", "total s"],
            &rows,
        ),
        if linear { "≈ linear" } else { "sub-linear" },
        VIRT_SLOWDOWN * 100.0,
    )
}

/// Fig 15: Netflix throughput as job size grows.
pub fn fig15(ctx: &Ctx) -> String {
    let cluster = Cluster::homogeneous(HardwareType::TypeIII, 2);
    let mut rows = Vec::new();
    for (w, label) in [
        (Workload::NetflixHi, "high confidence"),
        (Workload::NetflixLo, "low confidence"),
    ] {
        let base = base_params(ctx, w);
        for job in [32 * MB, 256 * MB, 2 * GB, 16 * GB] {
            let r =
                simulate(&PlatformSpec::bts(), &cluster, &at_size(&base, job));
            rows.push(vec![
                label.to_string(),
                human(job),
                format!("{:.1}", r.throughput_mbs),
                format!("{:.1}", r.total_s),
            ]);
        }
    }
    format!(
        "{}\npaper: throughput rises with job size as startup amortizes, then\n\
         paper: flattens; low-confidence (smaller subsamples) runs faster\n",
        render_table(
            "Fig 15 — Netflix throughput vs job size (type 3)",
            &["confidence", "job", "MB/s", "total s"],
            &rows,
        )
    )
}

/// Fig 16: reduce-task sweep — EAGLET sees immediate diminishing
/// returns; Netflix gains before communication costs win.
pub fn fig16(ctx: &Ctx) -> String {
    let cluster = c72();
    let platform = PlatformSpec::bts();
    let rs = [1usize, 2, 4, 8, 16, 32, 64];
    let mut rows = Vec::new();
    let mut best = (1usize, 1usize);
    for (w, job, label) in [
        (Workload::Eaglet, 2 * GB, "EAGLET"),
        (Workload::NetflixHi, 2 * GB, "Netflix"),
    ] {
        let base = base_params(ctx, w);
        let sweep =
            sweep_reduce_tasks(&base.reduce, job, &cluster, &platform, &rs);
        let best_r = sweep
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
            .0;
        if w == Workload::Eaglet {
            best.0 = best_r;
        } else {
            best.1 = best_r;
        }
        for (r, total_s, net_bytes) in sweep {
            rows.push(vec![
                label.to_string(),
                format!("{r}"),
                format!("{:.3}", total_s),
                format!("{:.1}", net_bytes / MB as f64),
            ]);
        }
    }
    format!(
        "{}\nbest reduce-task count: EAGLET r={}, Netflix r={}\n\
         paper: EAGLET is compute-intensive — adding reduce tasks quickly\n\
         paper: exhibits diminishing returns; Netflix can speed up at the\n\
         paper: reduce stage; network demand grows with reduce tasks\n",
        render_table(
            "Fig 16 — reduce-phase time and network demand vs reduce tasks",
            &["workload", "r", "shuffle+reduce s", "net MB"],
            &rows,
        ),
        best.0,
        best.1,
    )
}

/// §4.2.4: one slow node in the cluster.
pub fn hetero(ctx: &Ctx) -> String {
    let base = base_params(ctx, Workload::Eaglet);
    let hetero = Cluster::heterogeneous(1, 4); // 1 slow type-1 node
    let homo = Cluster::homogeneous(HardwareType::TypeIII, 4);
    let mut rows = Vec::new();
    let mut ratios = Vec::new();
    for job in [8 * MB, 64 * MB, 512 * MB, 4 * GB] {
        let p = at_size(&base, job);
        let th = simulate(&PlatformSpec::bts(), &hetero, &p);
        let to = simulate(&PlatformSpec::bts(), &homo, &p);
        let ratio = th.total_s / to.total_s;
        ratios.push((job, ratio));
        rows.push(vec![
            human(job),
            format!("{:.1}", th.total_s),
            format!("{:.1}", to.total_s),
            format!("{:.2}x", ratio),
        ]);
    }
    format!(
        "{}\nslowdown shrinks from {:.2}x (small) to {:.2}x (large)\n\
         paper: slow nodes cause proportional slowdown on MB jobs; on larger\n\
         paper: jobs the round-robin scheduler skips busy slow cores and the\n\
         paper: loss spreads across the fast cores\n",
        render_table(
            "§4.2.4 — heterogeneous cluster: 1 slow node vs homogeneous",
            &["job", "hetero s", "homo s", "slowdown"],
            &rows,
        ),
        ratios.first().unwrap().1,
        ratios.last().unwrap().1,
    )
}

/// Headline claims from the abstract/conclusion, checked in one place.
pub fn headline(ctx: &Ctx) -> String {
    let cluster = c72();
    let e = base_params(ctx, Workload::Eaglet);
    let n = base_params(ctx, Workload::NetflixHi);

    let e230 = at_size(&e, 230 * MB);
    let n2g = at_size(&n, 2 * GB);
    let vs = |p: &SimParams, a: PlatformSpec, b: PlatformSpec| {
        simulate(&b, &cluster, p).total_s / simulate(&a, &cluster, p).total_s
    };
    let eaglet_vs_vh = vs(
        &e230,
        PlatformSpec::bts(),
        PlatformSpec::vanilla_hadoop(),
    );
    let netflix_vs_vh =
        vs(&n2g, PlatformSpec::bts(), PlatformSpec::vanilla_hadoop());
    let small = at_size(&e, 12 * MB);
    let small_vs_vh = vs(
        &small,
        PlatformSpec::bts(),
        PlatformSpec::vanilla_hadoop(),
    );
    let tb = at_size(&e, GB * 1024);
    let tb_vs_lh =
        vs(&tb, PlatformSpec::bts(), PlatformSpec::lite_hadoop());
    // per-12-core-node throughput on a type-2 node, large EAGLET job
    let one_node = Cluster::homogeneous(HardwareType::TypeII, 1);
    let tput = simulate(&PlatformSpec::bts(), &one_node, &at_size(&e, 2 * GB))
        .throughput_mbs;
    let rows = vec![
        vec![
            "EAGLET 230MB: BTS vs VH".to_string(),
            format!("{eaglet_vs_vh:.1}x"),
            "3x".to_string(),
        ],
        vec![
            "Netflix 2GB: BTS vs VH".to_string(),
            format!("{netflix_vs_vh:.1}x"),
            "2.5x".to_string(),
        ],
        vec![
            "small (12MB) jobs: BTS vs VH".to_string(),
            format!("{small_vs_vh:.1}x"),
            "12x (minutes-scale jobs)".to_string(),
        ],
        vec![
            "1TB: BTS vs lite Hadoop".to_string(),
            format!("{:.0}%", (tb_vs_lh - 1.0) * 100.0),
            "25%".to_string(),
        ],
        vec![
            "per-12-core-node throughput".to_string(),
            format!("{:.0} Mb/s", tput * 8.0),
            "117 Mb/s (CloudBurst: 24-60)".to_string(),
        ],
    ];
    format!(
        "{}\npaper: 'our improved platform performed 9X better than vanilla\n\
         paper: Hadoop' on short interactive workloads\n",
        render_table(
            "Headline claims — measured (simulated testbed) vs paper",
            &["claim", "ours", "paper"],
            &rows,
        )
    )
}

fn human(bytes: usize) -> String {
    if bytes >= GB {
        format!("{:.1}GB", bytes as f64 / GB as f64)
    } else {
        format!("{}MB", bytes / MB)
    }
}
