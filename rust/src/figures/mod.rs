//! Figure/table generators: one per table and figure in the thesis's
//! evaluation (§4) plus the §3 analysis figures. `bts repro` drives
//! these; each generator prints the same rows/series the paper reports
//! with a `paper:` annotation giving the published shape to compare
//! against (DESIGN.md §5 maps ids → modules → benches).

pub mod cache_figs;
pub mod platform_figs;
pub mod recovery_figs;
pub mod sim_figs;

use crate::data::Workload;
use crate::workloads::default_compute_s_per_mib;

/// Shared context: calibration constants (measured from the real
/// runtime when artifacts exist, else the recorded defaults).
#[derive(Debug, Clone)]
pub struct Ctx {
    pub eaglet_s_per_mib: f64,
    pub netflix_hi_s_per_mib: f64,
    pub netflix_lo_s_per_mib: f64,
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx {
            eaglet_s_per_mib: default_compute_s_per_mib(Workload::Eaglet),
            netflix_hi_s_per_mib: default_compute_s_per_mib(
                Workload::NetflixHi,
            ),
            netflix_lo_s_per_mib: default_compute_s_per_mib(
                Workload::NetflixLo,
            ),
        }
    }
}

impl Ctx {
    pub fn compute_s_per_mib(&self, w: Workload) -> f64 {
        match w {
            Workload::Eaglet => self.eaglet_s_per_mib,
            Workload::NetflixHi => self.netflix_hi_s_per_mib,
            Workload::NetflixLo => self.netflix_lo_s_per_mib,
            // Figures model the paper's three workloads; the new
            // kernels fall back to the recorded constants.
            Workload::SeqAddr | Workload::Ssag => {
                default_compute_s_per_mib(w)
            }
        }
    }

    /// The figure context always models the *paper's* workloads (the
    /// thesis-anchored constants in `workloads::calibration` — our
    /// Pallas kernels are ~80× lighter than the legacy MERLIN/Perl
    /// pipeline, and using their cost would flatten every crossover the
    /// paper reports). This constructor additionally *measures* the
    /// real kernels through PJRT as a health check and returns those
    /// numbers for reporting; `None` when artifacts are not built.
    pub fn calibrated() -> (Ctx, Option<[f64; 3]>) {
        let ctx = Ctx::default();
        let Ok(m) = crate::runtime::Manifest::load_default() else {
            return (ctx, None);
        };
        let m = std::sync::Arc::new(m);
        let p = m.params.clone();
        let mut measured = [0.0f64; 3];
        for (i, w) in [
            Workload::Eaglet,
            Workload::NetflixHi,
            Workload::NetflixLo,
        ]
        .into_iter()
        .enumerate()
        {
            let ds = crate::workloads::build_small(w, &p, 24);
            match crate::workloads::measure_compute_s_per_mib(
                m.clone(),
                ds.as_ref(),
                256 * 1024,
                4,
            ) {
                Ok(v) => measured[i] = v,
                Err(_) => return (ctx, None),
            }
        }
        (ctx, Some(measured))
    }
}

/// One reproducible artifact of the paper.
pub struct Figure {
    pub id: &'static str,
    pub title: &'static str,
    pub generate: fn(&Ctx) -> String,
}

/// The full registry, in paper order.
pub fn all() -> Vec<Figure> {
    vec![
        Figure {
            id: "table1",
            title: "Comparison chart of platforms",
            generate: platform_figs::table1,
        },
        Figure {
            id: "table2",
            title: "Types of hardware",
            generate: platform_figs::table2,
        },
        Figure {
            id: "fig2",
            title: "L2 misses/instr and AMAT across task sizes (EAGLET)",
            generate: cache_figs::fig2,
        },
        Figure {
            id: "fig3",
            title: "Task sizing algorithm (kneepoint detection demo)",
            generate: cache_figs::fig3,
        },
        Figure {
            id: "fig4",
            title: "Impact of the kneepoint algorithm on runtime",
            generate: sim_figs::fig4,
        },
        Figure {
            id: "fig5",
            title: "Startup overhead relative to BashReduce",
            generate: platform_figs::fig5,
        },
        Figure {
            id: "fig6",
            title: "Per-task runtime overhead relative to native Linux",
            generate: platform_figs::fig6,
        },
        Figure {
            id: "fig8",
            title: "BTS vs BLT vs BTT on both workloads",
            generate: sim_figs::fig8,
        },
        Figure {
            id: "fig9",
            title: "Netflix kneepoints across confidence levels",
            generate: cache_figs::fig9,
        },
        Figure {
            id: "fig10",
            title: "BTS speedup over VH and JLH vs job size",
            generate: sim_figs::fig10,
        },
        Figure {
            id: "fig11",
            title: "Running time vs job size (log-log), BTS vs VH vs LH",
            generate: sim_figs::fig11,
        },
        Figure {
            id: "fig12",
            title: "EAGLET on BTS as cores scale",
            generate: sim_figs::fig12,
        },
        Figure {
            id: "fig13",
            title: "Throughput under service level objectives",
            generate: sim_figs::fig13,
        },
        Figure {
            id: "fig14",
            title: "Netflix scaling on virtualized Type-3 hardware",
            generate: sim_figs::fig14,
        },
        Figure {
            id: "fig15",
            title: "Netflix throughput vs job size",
            generate: sim_figs::fig15,
        },
        Figure {
            id: "fig16",
            title: "Reduce-task scaling and network demand",
            generate: sim_figs::fig16,
        },
        Figure {
            id: "hetero",
            title: "Heterogeneous cluster (1 slow node of 5)",
            generate: sim_figs::hetero,
        },
        Figure {
            id: "recovery",
            title: "f_w failure analysis (job- vs task-level recovery)",
            generate: recovery_figs::recovery,
        },
        Figure {
            id: "headline",
            title: "Headline claims (abstract/conclusion)",
            generate: sim_figs::headline,
        },
    ]
}

pub fn by_id(id: &str) -> Option<Figure> {
    all().into_iter().find(|f| f.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_are_unique_and_lookup_works() {
        let figs = all();
        let mut ids: Vec<_> = figs.iter().map(|f| f.id).collect();
        ids.sort_unstable();
        let n = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), n);
        assert!(by_id("fig10").is_some());
        assert!(by_id("fig999").is_none());
    }

    #[test]
    fn every_generator_produces_output() {
        // Default (uncalibrated) ctx so this runs without artifacts.
        let ctx = Ctx::default();
        for f in all() {
            let out = (f.generate)(&ctx);
            assert!(
                out.len() > 100,
                "{} produced suspiciously short output",
                f.id
            );
            assert!(
                out.contains("paper:"),
                "{} must cite the paper's shape",
                f.id
            );
        }
    }
}
