//! §3.3 recovery analysis figure: expected failures per execution and
//! the breakeven monitoring overhead.

use super::Ctx;
use crate::coordinator::{expected_failures, RecoveryParams};
use crate::util::render_table;

pub fn recovery(_ctx: &Ctx) -> String {
    let base = RecoveryParams::thesis_example();
    let fw = expected_failures(&base);

    // Sweep cluster size: where does task-level recovery start paying,
    // assuming its measured ~21% monitoring overhead?
    let mut rows = Vec::new();
    for nodes in [10, 100, 1_000, 10_000, 30_000, 100_000] {
        let p = RecoveryParams { nodes, ..base.clone() };
        let f = expected_failures(&p);
        rows.push(vec![
            format!("{nodes}"),
            format!("{f:.4}"),
            if f > 0.21 { "task-level" } else { "job-level" }.to_string(),
        ]);
    }
    format!(
        "{}\nthesis example (N=100, P(w)=10min, mttf=4.3mo, phi=1.5): \
         f_w = {fw:.4}\n\
         paper: f_w = 0.0078 — monitoring must cost <1% to justify\n\
         paper: task-level recovery; clusters under ~30K nodes do not\n\
         paper: justify the observed 21% startup overhead\n",
        render_table(
            "§3.3 — expected failures per job execution vs cluster size",
            &["nodes", "f_w", "recovery that pays (at 21% monitor cost)"],
            &rows,
        )
    )
}
