//! Platform-overhead figures: Tables 1–2, Fig 5 (startup), Fig 6
//! (per-task runtime overhead).

use super::Ctx;
use crate::platforms::{all_platforms, PlatformSpec};
use crate::sim::HardwareType;
use crate::util::render_table;

/// Table 1: the platform comparison chart.
pub fn table1(_ctx: &Ctx) -> String {
    let yn = |b: bool| if b { "Yes" } else { "No" }.to_string();
    let rows: Vec<Vec<String>> = all_platforms()
        .iter()
        .map(|p| {
            vec![
                p.name.to_string(),
                match p.kind {
                    crate::platforms::PlatformKind::Hadoop => "Hadoop",
                    crate::platforms::PlatformKind::BashReduce => {
                        "Unix utilities"
                    }
                    crate::platforms::PlatformKind::NativeLinux => "Linux",
                }
                .to_string(),
                yn(p.task_level_recovery),
                yn(p.full_dfs),
                yn(p.java),
            ]
        })
        .collect();
    format!(
        "{}\npaper: VH yes/yes/yes; JLH no/yes/yes; LH no/no/yes; BashReduce no/no/no\n",
        render_table(
            "Table 1 — Comparison chart of platforms",
            &["codename", "core", "task-level failures", "full dist. FS", "java"],
            &rows,
        )
    )
}

/// Table 2: hardware types used across the experiments.
pub fn table2(_ctx: &Ctx) -> String {
    let rows: Vec<Vec<String>> = [
        HardwareType::TypeI,
        HardwareType::TypeII,
        HardwareType::TypeIII,
    ]
    .iter()
    .map(|h| {
        vec![
            h.name().to_string(),
            format!("{}", h.cores()),
            format!("{:.1}G", h.ghz()),
            format!("{}MB", h.l2_mb()),
            format!("{}GB", h.mem_gb()),
            if h.virtualized() { "Yes" } else { "No" }.to_string(),
        ]
    })
    .collect();
    format!(
        "{}\npaper: Type I/II Xeon 12c (2.0/2.3GHz, 15MB L2, 32GB); Type III\n\
         paper: Opteron 32c 2.3GHz 32MB 64GB, virtualized\n",
        render_table(
            "Table 2 — Types of hardware",
            &["type", "cores/node", "clock", "L2", "memory", "virtualized"],
            &rows,
        )
    )
}

/// Fig 5: hello-world startup per platform, normalized to BashReduce
/// (72 map slots, tasks complete in ms).
pub fn fig5(_ctx: &Ctx) -> String {
    let slots = 72;
    let base = PlatformSpec::bts().startup_s(slots);
    let specs = [
        PlatformSpec::vanilla_hadoop(),
        PlatformSpec::job_level_hadoop(),
        PlatformSpec::lite_hadoop(),
        PlatformSpec::bts(),
    ];
    let rows: Vec<Vec<String>> = specs
        .iter()
        .map(|p| {
            vec![
                p.name.to_string(),
                format!("{:.1}", p.startup_s(slots)),
                format!("{:.2}x", p.startup_s(slots) / base),
            ]
        })
        .collect();
    let vh = PlatformSpec::vanilla_hadoop().startup_s(slots);
    let jlh = PlatformSpec::job_level_hadoop().startup_s(slots);
    format!(
        "{}\nmonitoring share of VH startup: {:.0}% ({:.0}s)\n\
         paper: VH ≈ 4x BashReduce; task monitoring adds 21% (~52s) to VH startup\n",
        render_table(
            "Fig 5 — startup time, 72 slots (hello-world job)",
            &["platform", "startup s", "vs BashReduce"],
            &rows,
        ),
        (vh - jlh) / vh * 100.0,
        vh - jlh,
    )
}

/// Fig 6: per-task runtime overhead relative to native Linux, EAGLET
/// 1-sample tasks (the thesis's 4K-task experiment).
pub fn fig6(_ctx: &Ctx) -> String {
    let task_mib = 4608.0 / (1024.0 * 1024.0); // one EAGLET sample
    let native = PlatformSpec::native_linux().per_task_overhead_s(task_mib);
    let specs = [
        PlatformSpec::vanilla_hadoop(),
        PlatformSpec::job_level_hadoop(),
        PlatformSpec::lite_hadoop(),
        PlatformSpec::bts(),
        PlatformSpec::native_linux(),
    ];
    let rows: Vec<Vec<String>> = specs
        .iter()
        .map(|p| {
            let o = p.per_task_overhead_s(task_mib);
            vec![
                p.name.to_string(),
                format!("{:.2}", o * 1e3),
                format!("{:.2}x", o / native),
            ]
        })
        .collect();
    let vh = PlatformSpec::vanilla_hadoop();
    let jlh = PlatformSpec::job_level_hadoop();
    let monitor_pct = (vh.per_task_overhead_s(task_mib)
        - jlh.per_task_overhead_s(task_mib))
        / vh.per_task_overhead_s(task_mib)
        * 100.0;
    let hdfs_pct = (jlh.per_task_overhead_s(task_mib)
        - PlatformSpec::lite_hadoop().per_task_overhead_s(task_mib))
        / jlh.per_task_overhead_s(task_mib)
        * 100.0;
    format!(
        "{}\nmonitoring share of VH per-task overhead: {monitor_pct:.0}%; \
         HDFS share of JLH overhead: {hdfs_pct:.0}%\n\
         paper: failure monitoring ≈ 20% per task; bypassing HDFS on temp\n\
         paper: files is the largest gain; native ≈ BashReduce (12% sched)\n",
        render_table(
            "Fig 6 — per-task runtime overhead (1-sample EAGLET tasks)",
            &["platform", "overhead ms/task", "vs native"],
            &rows,
        )
    )
}
