//! Cache-simulator figures: Fig 2 (the case for tiny tasks), Fig 3
//! (kneepoint detection), Fig 9 (Netflix knees across confidence).

use super::Ctx;
use crate::cachesim::CacheConfig;
use crate::data::Workload;
use crate::kneepoint::{
    default_sizes, kneepoints, profile_workload, smallest_kneepoint,
    KNEE_THRESHOLD,
};
use crate::util::render_table;

fn mb(bytes: usize) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

/// Fig 2: L2/L3 misses per instruction + normalized AMAT vs task size on
/// EAGLET, Sandy-Bridge cache geometry.
pub fn fig2(_ctx: &Ctx) -> String {
    let cache = CacheConfig::sandy_bridge();
    let profile =
        profile_workload(Workload::Eaglet, &cache, &default_sizes(), None);
    let base_amat = profile
        .points
        .iter()
        .map(|p| p.amat)
        .fold(f64::INFINITY, f64::min);
    let rows: Vec<Vec<String>> = profile
        .points
        .iter()
        .map(|p| {
            vec![
                format!("{:.2}", mb(p.task_bytes)),
                format!("{:.6}", p.l2_mpi),
                format!("{:.6}", p.l3_mpi),
                format!("{:.1}", p.amat / base_amat),
            ]
        })
        .collect();
    let l2_knees = kneepoints(&profile.l2_curve(), KNEE_THRESHOLD);
    let l3_knees = kneepoints(&profile.l3_curve(), KNEE_THRESHOLD);
    let ratio = {
        let at = |target_mb: f64| {
            profile
                .points
                .iter()
                .min_by(|a, b| {
                    (mb(a.task_bytes) - target_mb)
                        .abs()
                        .partial_cmp(&(mb(b.task_bytes) - target_mb).abs())
                        .unwrap()
                })
                .unwrap()
        };
        at(25.0).l2_mpi / at(2.5).l2_mpi.max(1e-12)
    };
    let amat_growth = profile
        .points
        .iter()
        .map(|p| p.amat)
        .fold(0.0f64, f64::max)
        / base_amat;
    format!(
        "{}\nL2 kneepoints: {:?} MB   L3 kneepoints: {:?} MB\n\
         25MB/2.5MB L2-miss ratio: {ratio:.0}x   max AMAT growth: {amat_growth:.0}x\n\
         paper: knees at 2.5 MB (L2) and 11 MB (L3); 25MB task sees 35x the\n\
         paper: L2 misses/instr of a 2.5MB task; >1,000x AMAT growth overall\n",
        render_table(
            "Fig 2 — EAGLET task size vs cache behaviour (simulated Sandy Bridge)",
            &["task MB", "L2 miss/instr", "L3 miss/instr", "AMAT (norm)"],
            &rows,
        ),
        l2_knees.iter().map(|&b| mb(b)).collect::<Vec<_>>(),
        l3_knees.iter().map(|&b| mb(b)).collect::<Vec<_>>(),
    )
}

/// Fig 3: run the offline kneepoint algorithm end to end on the
/// simulated profile and show what it picks.
pub fn fig3(_ctx: &Ctx) -> String {
    let cache = CacheConfig::sandy_bridge();
    let mut out = String::new();
    for (w, label) in [
        (Workload::Eaglet, "EAGLET"),
        (Workload::NetflixHi, "Netflix (high confidence)"),
        (Workload::NetflixLo, "Netflix (low confidence)"),
    ] {
        let profile = profile_workload(w, &cache, &default_sizes(), None);
        let knee = smallest_kneepoint(&profile.l2_curve(), KNEE_THRESHOLD);
        out.push_str(&format!(
            "{label:32} smallest kneepoint: {}\n",
            knee.map(|b| format!("{:.2} MB", mb(b)))
                .unwrap_or_else(|| "none (flat curve)".into()),
        ));
    }
    out.push_str(
        "\nAlgorithm (thesis Fig 3): grow the working set until the\n\
         miss-rate *growth rate* first exceeds the initial growth rate;\n\
         return the last size before that increase.\n\
         paper: offline phase costs ~3% of online time, paid once per dataset\n",
    );
    out
}

/// Fig 9: Netflix kneepoints move with the confidence level (subsample
/// fraction), and the 1 MB choice stays near-best across levels.
pub fn fig9(ctx: &Ctx) -> String {
    let cache = CacheConfig::sandy_bridge();
    // five workloads varying by output confidence (subsample fraction)
    let fracs = [0.0625, 0.125, 0.25, 0.375, 0.5];
    let mut rows = Vec::new();
    let mut one_mb_ranks = Vec::new();
    for &frac in &fracs {
        let profile = profile_workload(
            Workload::NetflixHi,
            &cache,
            &default_sizes(),
            Some(frac),
        );
        let knee = smallest_kneepoint(&profile.l2_curve(), KNEE_THRESHOLD);
        // rank task sizes by simulated job throughput at this confidence
        let sizes = [256 * 1024, 512 * 1024, 1 << 20, 4 << 20, 16 << 20];
        let mut scored: Vec<(usize, f64)> = sizes
            .iter()
            .map(|&ts| {
                let mut p = crate::sim::default_params(
                    Workload::NetflixHi,
                    256 << 20,
                    ctx.compute_s_per_mib(Workload::NetflixHi),
                );
                p.penalty = profile
                    .points
                    .iter()
                    .map(|pt| crate::kneepoint::CurvePoint {
                        task_bytes: pt.task_bytes,
                        miss_rate: (pt.cpi
                            / profile
                                .points
                                .iter()
                                .map(|q| q.cpi)
                                .fold(f64::INFINITY, f64::min))
                        .max(1.0),
                    })
                    .collect();
                let mut plat = crate::platforms::PlatformSpec::bts();
                plat.sizing = crate::platforms::SizingKind::Fixed(ts);
                let r = crate::sim::simulate(
                    &plat,
                    &crate::sim::Cluster::homogeneous(
                        crate::sim::HardwareType::TypeII,
                        6,
                    ),
                    &p,
                );
                (ts, r.throughput_mbs)
            })
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let rank_1mb = scored
            .iter()
            .position(|(ts, _)| *ts == (1 << 20))
            .unwrap()
            + 1;
        one_mb_ranks.push(rank_1mb);
        rows.push(vec![
            format!("{frac:.4}"),
            knee.map(|b| format!("{:.2}", mb(b)))
                .unwrap_or_else(|| "-".into()),
            format!("{rank_1mb}"),
            format!("{:.1}", scored[0].1),
        ]);
    }
    let top2 = one_mb_ranks.iter().filter(|&&r| r <= 2).count();
    format!(
        "{}\n1 MB task size ranks in the top-2 for {top2}/5 confidence levels\n\
         paper: knees differ between high/low confidence; the single 1 MB\n\
         paper: setting ranked top-2 in 3/5 workloads, within 10% otherwise,\n\
         paper: and beat large/tiniest in all 5\n",
        render_table(
            "Fig 9 — Netflix kneepoints vs confidence level",
            &["subsample frac", "knee MB", "rank of 1MB", "best MB/s"],
            &rows,
        )
    )
}
