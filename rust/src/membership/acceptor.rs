//! The membership acceptor: a pool-lifetime accept loop that makes
//! worker arrival an *event*, not a startup phase.
//!
//! Before this module, the transport listener was drained by
//! [`crate::transport::accept_links`] exactly `remote.count` times at
//! job (or pool) start and then never polled again — a late
//! `bts worker --connect` sat in the backlog until its handshake timed
//! out, which is the "silently stops admitting connections" failure
//! mode this PR's satellite fixes. The [`Acceptor`] keeps accepting
//! for its whole life and classifies each first frame:
//!
//! * `Hello` within the initial quota, or any time when elastic
//!   membership is on → the connection is adopted as a fresh map slot
//!   ([`crate::transport::WorkerLink::adopt_handshaken`]) and
//!   surfaced as [`MemberEvent::Joined`] for the leader to absorb.
//! * `Hello` past the quota with elastic off → a versioned
//!   `Message::Error` frame is written back and the connection is
//!   dropped — the worker sees a clean `Error::Protocol`, never a
//!   hang.
//! * `DrainWorker { worker }` (the `bts drain` control plane) → the
//!   frame is echoed back as the ack and surfaced as
//!   [`MemberEvent::DrainRequested`].
//!
//! The leader owns the policy; the acceptor owns only the socket
//! lifecycle. [`Acceptor::stop`] shuts the loop down and politely
//! dismisses any adopted-but-unclaimed joiners.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use crate::dfs::Dfs;
use crate::error::{Error, Result};
use crate::net::protocol::{
    configure_stream, Message, NetCounters, HANDSHAKE_TIMEOUT,
    PROTOCOL_VERSION,
};
use crate::scheduler::ResponseTimeTracker;
use crate::transport::{Down, PumpCfg, Up, WorkerLink};

/// One membership-plane event, in arrival order.
pub enum MemberEvent {
    /// A worker connected and was adopted: its link is live and its
    /// slot index is [`WorkerLink::worker`]. The leader must absorb it
    /// (grow scheduler/tracker/in-flight state) or dismiss it.
    Joined(WorkerLink),
    /// A `bts drain <worker>` client asked for slot `worker` to leave
    /// gracefully. The leader sends [`Down::Drain`] if the slot exists.
    DrainRequested(usize),
}

/// See module docs. One per `run_cluster` attempt or serve pool.
pub struct Acceptor {
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
    events: mpsc::Receiver<MemberEvent>,
}

impl Acceptor {
    /// Start the accept loop on `listener`. Slots are assigned
    /// sequentially from `first_slot`; the first `initial_quota`
    /// Hellos are always admitted (they are the statically requested
    /// `--workers-remote` set), later ones only when `elastic`.
    /// Every adopted link's pump reports its wire traffic into
    /// `counters` (one instance per leader, not a global).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        listener: Arc<TcpListener>,
        first_slot: usize,
        initial_quota: usize,
        elastic: bool,
        dfs: Arc<Dfs>,
        up: mpsc::Sender<Up>,
        tracker: Option<Arc<ResponseTimeTracker>>,
        pump: PumpCfg,
        counters: Arc<NetCounters>,
    ) -> Result<Acceptor> {
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (ev_tx, ev_rx) = mpsc::channel();
        let loop_stop = stop.clone();
        let handle = thread::Builder::new()
            .name("bts-membership-acceptor".into())
            .spawn(move || {
                accept_loop(
                    &listener,
                    first_slot,
                    initial_quota,
                    elastic,
                    dfs,
                    up,
                    tracker,
                    pump,
                    counters,
                    &ev_tx,
                    &loop_stop,
                );
            })
            .map_err(|e| {
                Error::Scheduler(format!("spawn membership acceptor: {e}"))
            })?;
        Ok(Acceptor { stop, handle: Some(handle), events: ev_rx })
    }

    /// Next queued event, if any (the leader's per-iteration poll).
    pub fn try_event(&self) -> Option<MemberEvent> {
        self.events.try_recv().ok()
    }

    /// Block up to `timeout` for an event — how a leader with every
    /// slot gone waits for a rescuing joiner before giving up.
    pub fn wait_event(&self, timeout: Duration) -> Option<MemberEvent> {
        self.events.recv_timeout(timeout).ok()
    }

    /// Stop accepting and join the loop. Already-adopted joiners still
    /// queued as events are dismissed with a clean `Shutdown` — their
    /// processes exit instead of waiting on a dead leader.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        while let Ok(ev) = self.events.try_recv() {
            if let MemberEvent::Joined(link) = ev {
                let _ = link.send(Down::Shutdown);
                link.join();
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: &TcpListener,
    first_slot: usize,
    initial_quota: usize,
    elastic: bool,
    dfs: Arc<Dfs>,
    up: mpsc::Sender<Up>,
    tracker: Option<Arc<ResponseTimeTracker>>,
    pump: PumpCfg,
    counters: Arc<NetCounters>,
    events: &mpsc::Sender<MemberEvent>,
    stop: &AtomicBool,
) {
    let mut admitted = 0usize;
    while !stop.load(Ordering::SeqCst) {
        let stream = match listener.accept() {
            Ok((stream, _addr)) => stream,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                thread::sleep(Duration::from_millis(5));
                continue;
            }
            Err(_) => {
                // Listener-level hiccup: stay alive — the loop dying
                // silently is exactly the bug this module fixes.
                thread::sleep(Duration::from_millis(20));
                continue;
            }
        };
        if configure_stream(&stream).is_err() {
            continue;
        }
        let Ok(clone) = stream.try_clone() else { continue };
        let mut rd = BufReader::new(clone);
        match Message::read_deadline(&mut rd, Some(HANDSHAKE_TIMEOUT)) {
            Ok(Message::Hello { .. }) => {
                if admitted < initial_quota || elastic {
                    let slot = first_slot + admitted;
                    match WorkerLink::adopt_handshaken(
                        stream,
                        rd,
                        slot,
                        dfs.clone(),
                        up.clone(),
                        tracker.clone(),
                        pump,
                        counters.clone(),
                    ) {
                        Ok(link) => {
                            admitted += 1;
                            if events.send(MemberEvent::Joined(link)).is_err()
                            {
                                return; // leader gone
                            }
                        }
                        Err(_) => {} // handshake write failed: drop
                    }
                } else {
                    refuse(stream);
                }
            }
            Ok(Message::DrainWorker { worker }) => {
                // Echo the frame back as the ack, then surface the
                // request; the short-lived client disconnects itself.
                let mut wr = BufWriter::new(stream);
                let _ = Message::DrainWorker { worker }.write_to(&mut wr);
                if events
                    .send(MemberEvent::DrainRequested(worker as usize))
                    .is_err()
                {
                    return;
                }
            }
            Ok(other) => {
                let mut wr = BufWriter::new(stream);
                let _ = Message::Error {
                    message: format!(
                        "membership plane (protocol v{PROTOCOL_VERSION}) \
                         expected Hello or DrainWorker, got {other:?}"
                    ),
                }
                .write_to(&mut wr);
            }
            Err(_) => {} // garbage or handshake timeout: drop
        }
    }
}

/// Politely refuse a late joiner on a frozen (non-elastic) membership:
/// a versioned error frame, then drop — the peer surfaces it as
/// `Error::Protocol`, never a hang.
fn refuse(stream: TcpStream) {
    let mut wr = BufWriter::new(stream);
    let _ = Message::Error {
        message: format!(
            "membership is frozen (elastic off, protocol \
             v{PROTOCOL_VERSION}): late worker refused — start the \
             leader with --elastic on to admit mid-job joins"
        ),
    }
    .write_to(&mut wr);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::LatencyModel;
    use crate::transport::RemoteWorkers;

    fn hello(addr: &str) -> (BufReader<TcpStream>, BufWriter<TcpStream>) {
        let stream = TcpStream::connect(addr).unwrap();
        configure_stream(&stream).unwrap();
        let rd = BufReader::new(stream.try_clone().unwrap());
        let mut wr = BufWriter::new(stream);
        Message::Hello { worker: 0 }.write_to(&mut wr).unwrap();
        (rd, wr)
    }

    #[test]
    fn admits_quota_then_refuses_when_not_elastic() {
        let rw = RemoteWorkers::bind("127.0.0.1:0", 1).unwrap();
        let addr = rw.addr();
        let dfs = Dfs::new(1, 1, LatencyModel::none());
        let (up_tx, _up_rx) = mpsc::channel();
        let acceptor = Acceptor::spawn(
            rw.listener.clone(),
            3,
            1,
            false,
            dfs,
            up_tx,
            None,
            PumpCfg::default(),
            Arc::new(NetCounters::default()),
        )
        .unwrap();
        // First Hello: inside the quota — welcomed as slot 3.
        let (mut rd1, _wr1) = hello(&addr);
        match Message::read_deadline(&mut rd1, Some(HANDSHAKE_TIMEOUT))
            .unwrap()
        {
            Message::Welcome { worker: 3 } => {}
            other => panic!("expected Welcome 3, got {other:?}"),
        }
        match acceptor.wait_event(Duration::from_secs(10)) {
            Some(MemberEvent::Joined(link)) => {
                assert_eq!(link.worker(), 3);
                let _ = link.send(Down::Shutdown);
                link.join();
            }
            _ => panic!("expected Joined"),
        }
        // Second Hello: past the quota, elastic off — refused with a
        // versioned error frame, not a hang.
        let (mut rd2, _wr2) = hello(&addr);
        match Message::read_deadline(&mut rd2, Some(HANDSHAKE_TIMEOUT))
            .unwrap()
        {
            Message::Error { message } => {
                assert!(message.contains("frozen"), "{message}");
                assert!(
                    message.contains(&format!("v{PROTOCOL_VERSION}")),
                    "refusal must be versioned: {message}"
                );
            }
            other => panic!("expected Error frame, got {other:?}"),
        }
        acceptor.stop();
    }

    #[test]
    fn elastic_admits_past_quota_and_routes_drain_requests() {
        let rw = RemoteWorkers::bind("127.0.0.1:0", 0).unwrap();
        let addr = rw.addr();
        let dfs = Dfs::new(1, 1, LatencyModel::none());
        let (up_tx, _up_rx) = mpsc::channel();
        let acceptor = Acceptor::spawn(
            rw.listener.clone(),
            0,
            0,
            true,
            dfs,
            up_tx,
            None,
            PumpCfg::default(),
            Arc::new(NetCounters::default()),
        )
        .unwrap();
        // Quota is zero, but elastic admits anyway.
        let (mut rd, _wr) = hello(&addr);
        match Message::read_deadline(&mut rd, Some(HANDSHAKE_TIMEOUT))
            .unwrap()
        {
            Message::Welcome { worker: 0 } => {}
            other => panic!("expected Welcome 0, got {other:?}"),
        }
        let joined = match acceptor.wait_event(Duration::from_secs(10)) {
            Some(MemberEvent::Joined(link)) => link,
            _ => panic!("expected Joined"),
        };
        // A drain client asks for slot 0; the ack is the echoed frame.
        let stream = TcpStream::connect(&addr).unwrap();
        configure_stream(&stream).unwrap();
        let mut drd = BufReader::new(stream.try_clone().unwrap());
        let mut dwr = BufWriter::new(stream);
        Message::DrainWorker { worker: 0 }.write_to(&mut dwr).unwrap();
        match Message::read_deadline(&mut drd, Some(HANDSHAKE_TIMEOUT))
            .unwrap()
        {
            Message::DrainWorker { worker: 0 } => {}
            other => panic!("expected echoed ack, got {other:?}"),
        }
        match acceptor.wait_event(Duration::from_secs(10)) {
            Some(MemberEvent::DrainRequested(0)) => {}
            _ => panic!("expected DrainRequested(0)"),
        }
        let _ = joined.send(Down::Shutdown);
        joined.join();
        acceptor.stop();
    }
}
