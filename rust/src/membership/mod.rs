//! Elastic cluster membership (DESIGN.md §14): live worker join and
//! leave, queue rebalancing on membership change, and the task-level
//! checkpoint ledger that makes worker loss a re-dispatch instead of a
//! job restart.
//!
//! Three pieces, layered on the transport and scheduler rather than
//! inside them:
//!
//! * [`acceptor::Acceptor`] — a pool-lifetime accept loop on the
//!   leader's listener. New `bts worker --connect` processes become
//!   [`acceptor::MemberEvent::Joined`] links mid-job (elastic on) or
//!   are refused with a versioned error frame (elastic off); `bts
//!   drain` requests become [`acceptor::MemberEvent::DrainRequested`].
//! * **Rebalancing** lives in the pieces that already own placement:
//!   [`crate::scheduler::TwoStepScheduler::add_worker`] /
//!   [`crate::scheduler::TwoStepScheduler::retire_worker`] move queued
//!   tiny tasks through the pending pool (affinity scoring and
//!   collapsed windows intact), a joining slot gets a pessimistic
//!   [`crate::scheduler::ResponseTimeTracker`] prior, and
//!   [`crate::dfs::Ring::shrink`] re-homes replica responsibility
//!   without refetching survivors' cached blocks.
//! * [`ledger::Ledger`] — the `(ns, seq, attempt)` index over durable
//!   per-task outputs (map partials in the leader's seq vector,
//!   shuffle fragments under [`crate::reduce::shuffle_key`]): on a
//!   loss, exactly the dead slot's sole-carrier in-flight units
//!   re-dispatch. `coordinator::recovery`'s job-level restart remains
//!   as the fallback for non-membership failures.
//!
//! Determinism survives every membership change by construction: a
//! task's output is a function of `(job_seed, seq)` and the reduce is
//! seq-ordered, so who ran what, when they joined, and who died
//! mid-job never reach the output bytes — the elastic oracle suite
//! (`rust/tests/integration_elastic.rs`) diffs elastic runs
//! bit-for-bit against static baselines.

pub mod acceptor;
pub mod ledger;

pub use acceptor::{Acceptor, MemberEvent};
pub use ledger::{Ledger, TaskKind};
