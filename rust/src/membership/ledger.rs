//! The task ledger: per-task durability bookkeeping that turns a
//! worker loss into a re-dispatch of *that worker's in-flight window*
//! instead of a job-level restart.
//!
//! PR 6 already made completed outputs durable: map partials live in
//! the leader's seq-ordered `partials` vector, and shuffle fragments
//! are staged in the replicated store under
//! [`crate::reduce::shuffle_key`]. What was missing is the *indexing*
//! — when slot `w` vanishes, which `(kind, seq)` units were riding on
//! it and nowhere else? The [`Ledger`] answers that in O(entries):
//! every dispatch (primary or speculative clone) records the carrying
//! slot under `(ns, seq, attempt)`, every first completion retires the
//! entry, and [`Ledger::inflight_of`] lists exactly the units a dead
//! slot strands. Everything completed stays completed — determinism
//! holds because a task's output is a function of `(seed, seq)` alone,
//! so a re-dispatched unit produces bit-identical bytes wherever it
//! lands.

use std::collections::HashMap;
use std::sync::Arc;

/// Which phase a ledger unit belongs to. Map seqs and reduce
/// partitions are separate key spaces.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    Map,
    Reduce,
}

#[derive(Debug)]
struct Entry {
    attempt: u32,
    /// Slots carrying a live copy (primary first, clones appended).
    workers: Vec<usize>,
    done: bool,
}

/// See module docs. One per job attempt, owned by the leader's
/// `JobCtx` next to the `SpeculationState` that retains the specs a
/// re-dispatch needs.
#[derive(Debug, Default)]
pub struct Ledger {
    /// The job namespace the durable outputs live under (`""` solo) —
    /// with `seq` and `attempt` in the entries, the full durability
    /// key the ISSUE prescribes.
    ns: Arc<str>,
    entries: HashMap<(TaskKind, usize), Entry>,
    re_dispatched: u64,
}

impl Ledger {
    pub fn new(ns: Arc<str>) -> Ledger {
        Ledger { ns, entries: HashMap::new(), re_dispatched: 0 }
    }

    pub fn ns(&self) -> &str {
        &self.ns
    }

    /// Record that a copy of `(kind, seq)` left for `worker`. Called
    /// for the primary dispatch, every speculative clone, and every
    /// membership re-dispatch; duplicate `(entry, worker)` pairs
    /// collapse.
    pub fn dispatched(
        &mut self,
        kind: TaskKind,
        seq: usize,
        attempt: u32,
        worker: usize,
    ) {
        let e = self.entries.entry((kind, seq)).or_insert(Entry {
            attempt,
            workers: Vec::with_capacity(1),
            done: false,
        });
        e.attempt = attempt;
        if !e.workers.contains(&worker) {
            e.workers.push(worker);
        }
    }

    /// First completion retires the unit; returns `false` for
    /// duplicates (a dead clone, or a copy finishing after a
    /// membership re-dispatch already covered it).
    pub fn completed(&mut self, kind: TaskKind, seq: usize) -> bool {
        match self.entries.get_mut(&(kind, seq)) {
            Some(e) if !e.done => {
                e.done = true;
                e.workers.clear();
                true
            }
            _ => false,
        }
    }

    /// The units stranded if `worker` disappears right now: in flight,
    /// and carried by no *other* live slot (a cloned straggler whose
    /// twin survives needs no re-dispatch). Seq-sorted, map before
    /// reduce, so requeues re-dispatch deterministically.
    pub fn inflight_of(&self, worker: usize) -> Vec<(TaskKind, usize)> {
        let mut v: Vec<(TaskKind, usize)> = self
            .entries
            .iter()
            .filter(|(_, e)| {
                !e.done
                    && e.workers.contains(&worker)
                    && e.workers.iter().all(|&w| w == worker)
            })
            .map(|(&k, _)| k)
            .collect();
        v.sort_by_key(|&(kind, seq)| (kind != TaskKind::Map, seq));
        v
    }

    /// Drop `worker` from every live entry (it left the membership).
    /// Call after [`Ledger::inflight_of`] has been acted on.
    pub fn forget_worker(&mut self, worker: usize) {
        for e in self.entries.values_mut() {
            e.workers.retain(|&w| w != worker);
        }
    }

    /// Count units re-dispatched after membership loss (the bench's
    /// "only the in-flight window re-executes" assertion reads this).
    pub fn note_redispatch(&mut self, n: u64) {
        self.re_dispatched += n;
    }

    pub fn re_dispatched(&self) -> u64 {
        self.re_dispatched
    }

    /// Live (dispatched, not yet completed) units.
    pub fn in_flight(&self) -> usize {
        self.entries.values().filter(|e| !e.done).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_strands_only_sole_carrier_units() {
        let mut l = Ledger::new("j7/".into());
        assert_eq!(l.ns(), "j7/");
        l.dispatched(TaskKind::Map, 0, 1, 0);
        l.dispatched(TaskKind::Map, 1, 1, 1);
        l.dispatched(TaskKind::Map, 2, 1, 1);
        l.dispatched(TaskKind::Reduce, 0, 1, 1);
        // seq 2 was also cloned to slot 0 — a surviving twin covers it
        l.dispatched(TaskKind::Map, 2, 1, 0);
        // seq 1 completed before the loss
        assert!(l.completed(TaskKind::Map, 1));
        assert!(!l.completed(TaskKind::Map, 1), "duplicate dropped");
        // slot 1 dies: only its sole-carrier, unfinished units strand —
        // map seqs before reduce partitions, seq-sorted
        assert_eq!(l.inflight_of(1), vec![(TaskKind::Reduce, 0)]);
        l.forget_worker(1);
        assert_eq!(l.inflight_of(1), vec![]);
        // the re-dispatch lands on slot 0 and completes
        l.dispatched(TaskKind::Reduce, 0, 1, 0);
        l.note_redispatch(1);
        assert!(l.completed(TaskKind::Reduce, 0));
        assert_eq!(l.re_dispatched(), 1);
        assert_eq!(l.in_flight(), 2, "map 0 and map 2 still flying");
    }

    #[test]
    fn inflight_ordering_is_deterministic() {
        let mut l = Ledger::new("".into());
        l.dispatched(TaskKind::Reduce, 1, 1, 3);
        l.dispatched(TaskKind::Map, 9, 1, 3);
        l.dispatched(TaskKind::Map, 2, 1, 3);
        l.dispatched(TaskKind::Reduce, 0, 1, 3);
        assert_eq!(
            l.inflight_of(3),
            vec![
                (TaskKind::Map, 2),
                (TaskKind::Map, 9),
                (TaskKind::Reduce, 0),
                (TaskKind::Reduce, 1),
            ]
        );
    }
}
