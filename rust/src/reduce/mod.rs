//! Executed shuffle + reduce stage (thesis §4.2.4 / Fig 16, made real).
//!
//! Until PR 6 the platform executed only the map side; the reduce
//! phase lived as the analytical model in [`crate::sim::reduce_model`].
//! This module is the *execution* half of that pair: map partials are
//! sliced into per-partition **fragments** keyed by the workload's
//! reduce keys (EAGLET: LOD grid bins; Netflix: months; SeqAddr:
//! address bins; SSAG: block-size rungs), staged in the
//! leader's replicated store under shuffle keys, and streaming-merged
//! by reducer tasks that run in the same `worker_body` loop as map
//! slots. `sim::reduce_model` stays the model counterpart —
//! `rust/tests/integration_reduce.rs` cross-validates the two.
//!
//! **Skew-aware partitioning.** Netflix months under hot-key skew are
//! exactly the shape the thesis worries about ("BashReduce does not
//! support multiple reduce slots gracefully"): naive hash partitioning
//! can serialize the hot keys on one reducer. [`Partitioner::Skew`]
//! sorts keys by observed weight (descending, key id as tie-break) and
//! places each on the least-loaded partition — the classic LPT greedy,
//! the same move as SaSPartitioner's greedy balancer — with
//! zero-weight (cold) keys falling back to the hash placement. Because
//! LPT can occasionally lose to a lucky hash on tiny key sets, the
//! skew plan is computed *alongside* the hash plan and the one with
//! the lower imbalance factor wins (ties prefer greedy): "skew is
//! never worse than hash" holds by construction, and
//! `prop_invariants.rs` pins it.
//!
//! **Why determinism holds.** Both reduce kernels are elementwise per
//! output lane: the EAGLET tree computes each grid lane's weighted sum
//! independently (`wsum[lane] = Σ alod[lane]·w`, identical scalar
//! weights in `seq` order), the Netflix tree is an elementwise sum.
//! A reducer rebuilds zero-padded full-shape partials from its
//! fragments (owned lanes filled, every other lane 0.0, the *real*
//! scalar weights) and runs the *identical* `seq`-ordered,
//! `reduce_fan`-chunked tree as the r=1 path — so its owned-lane
//! values are bit-identical to the single-reducer result, which in
//! turn is the map-side-only aggregation of PRs 1–5. Assembly reads
//! each output lane from its owner partition only. Key→partition
//! assignment is a pure function of the key id and the seq-ordered
//! weight multiset, never of arrival order.

use std::sync::Arc;

use crate::coordinator::reduce::{
    finalize_netflix, finalize_seqaddr, reduce_eaglet, reduce_netflix,
    reduce_seqaddr, reduce_ssag,
};
use crate::coordinator::{JobOutput, TaskPartial};
use crate::data::{ModelParams, Workload};
use crate::error::{Error, Result};
use crate::runtime::Exec;
use crate::util::rng::mix64;

/// How reduce keys map onto reduce partitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Partitioner {
    /// `mix64(key) % partitions` — the naive baseline.
    #[default]
    Hash,
    /// Greedy least-loaded placement of weight-sorted keys (cold keys
    /// hash), kept only if it beats the hash plan on imbalance.
    Skew,
}

impl Partitioner {
    pub fn parse(s: &str) -> Option<Partitioner> {
        match s {
            "hash" => Some(Partitioner::Hash),
            "skew" => Some(Partitioner::Skew),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Partitioner::Hash => "hash",
            Partitioner::Skew => "skew",
        }
    }
}

/// A total, disjoint assignment of the key space `0..assign.len()`
/// onto `partitions` reduce partitions.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionPlan {
    pub partitions: u32,
    /// `assign[key] = partition` for every key id.
    pub assign: Vec<u32>,
}

impl PartitionPlan {
    pub fn partition_of(&self, key: u32) -> u32 {
        self.assign[key as usize]
    }

    /// Keys owned by `partition`, ascending.
    pub fn keys_of(&self, partition: u32) -> Vec<u32> {
        (0..self.assign.len() as u32)
            .filter(|&k| self.assign[k as usize] == partition)
            .collect()
    }

    /// Max partition load over the balanced-ideal load (`total /
    /// partitions`); 1.0 is perfect balance, `partitions` is fully
    /// serialized. Degenerate (zero-total) key sets report 1.0.
    pub fn imbalance_factor(&self, weights: &[f64]) -> f64 {
        let mut loads = vec![0.0f64; self.partitions as usize];
        for (k, &w) in weights.iter().enumerate() {
            loads[self.assign[k] as usize] += w.max(0.0);
        }
        let total: f64 = loads.iter().sum();
        if total <= 0.0 || self.partitions == 0 {
            return 1.0;
        }
        let max = loads.iter().cloned().fold(0.0, f64::max);
        max / (total / self.partitions as f64)
    }
}

fn hash_assign(n_keys: usize, partitions: u32) -> Vec<u32> {
    (0..n_keys as u64)
        .map(|k| (mix64(k) % partitions as u64) as u32)
        .collect()
}

/// LPT greedy: keys sorted by (weight desc, key asc) each go to the
/// least-loaded partition (lowest id on ties); cold (zero-weight)
/// keys keep their hash placement so the fallback is deterministic.
fn greedy_assign(weights: &[f64], partitions: u32) -> Vec<u32> {
    let mut assign = hash_assign(weights.len(), partitions);
    let mut hot: Vec<usize> = (0..weights.len())
        .filter(|&k| weights[k] > 0.0)
        .collect();
    hot.sort_by(|&a, &b| {
        weights[b]
            .partial_cmp(&weights[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut loads = vec![0.0f64; partitions as usize];
    for k in hot {
        let (mut best, mut best_load) = (0usize, f64::INFINITY);
        for (p, &load) in loads.iter().enumerate() {
            if load < best_load {
                best = p;
                best_load = load;
            }
        }
        assign[k] = best as u32;
        loads[best] += weights[k];
    }
    assign
}

/// Build the key→partition plan. Total and disjoint by construction;
/// a pure function of `(partitioner, weights, partitions)` — key
/// arrival order never enters (weights are computed from the complete
/// `seq`-ordered map-partial set).
pub fn build_plan(
    partitioner: Partitioner,
    weights: &[f64],
    partitions: usize,
) -> PartitionPlan {
    let partitions = partitions.max(1) as u32;
    let hash = PartitionPlan {
        partitions,
        assign: hash_assign(weights.len(), partitions),
    };
    match partitioner {
        Partitioner::Hash => hash,
        Partitioner::Skew => {
            let greedy = PartitionPlan {
                partitions,
                assign: greedy_assign(weights, partitions),
            };
            // never worse than hash, by construction
            if greedy.imbalance_factor(weights)
                <= hash.imbalance_factor(weights)
            {
                greedy
            } else {
                hash
            }
        }
    }
}

/// Number of reduce keys for a workload: EAGLET reduces over the LOD
/// grid, Netflix over months, SeqAddr over address bins, SSAG over
/// the block-size ladder.
pub fn n_keys(workload: Workload, p: &ModelParams) -> usize {
    match workload {
        Workload::Eaglet => p.grid,
        Workload::NetflixHi | Workload::NetflixLo => p.months,
        Workload::SeqAddr => p.sa_bins,
        Workload::Ssag => p.ssag_points,
    }
}

/// Output lanes per key: one value for the weighted-mean-curve
/// workloads (EAGLET ALOD, SSAG variance), the `(sum, sumsq, count)`
/// stat fields for the moment workloads (Netflix, SeqAddr).
pub fn lanes_per_key(workload: Workload, p: &ModelParams) -> usize {
    match workload {
        Workload::Eaglet | Workload::Ssag => 1,
        Workload::NetflixHi | Workload::NetflixLo | Workload::SeqAddr => {
            p.stat_fields
        }
    }
}

/// Observed per-key weights from the complete map-partial set, in
/// `seq` order. Curve workloads carry uniform weight (every partial
/// touches every key — skew degenerates to balanced greedy, which is
/// why EAGLET stays flat in Fig 16); the moment workloads are
/// weighted by their per-key counts, the real hot-key signal
/// (Netflix: rating draws per month; SeqAddr: window draws per
/// address bin).
pub fn key_weights(
    workload: Workload,
    p: &ModelParams,
    partials: &[TaskPartial],
) -> Result<Vec<f64>> {
    let keys = n_keys(workload, p);
    match workload {
        Workload::Eaglet | Workload::Ssag => Ok(vec![1.0; keys]),
        Workload::NetflixHi | Workload::NetflixLo | Workload::SeqAddr => {
            let f = p.stat_fields;
            let mut w = vec![0.0f64; keys];
            for t in partials {
                let TaskPartial::Netflix { stats } = t else {
                    return Err(Error::Scheduler(
                        "moment-keyed job produced a curve partial"
                            .into(),
                    ));
                };
                if stats.len() != keys * f {
                    return Err(Error::Scheduler(format!(
                        "partial stats {} != {keys}×{f}",
                        stats.len()
                    )));
                }
                for (m, wm) in w.iter_mut().enumerate() {
                    *wm += stats[m * f + 2] as f64; // count lane
                }
            }
            Ok(w)
        }
    }
}

/// Shuffle block key for `partition`'s slice of map task `seq`, under
/// the job namespace. Disjoint from data-block keys (those never use
/// the `sh:` prefix) and from other jobs (the `ns` prefix).
pub fn shuffle_key(ns: &str, partition: u32, seq: usize) -> String {
    format!("{ns}sh:{partition}:{seq}")
}

/// One partition's slice of one map partial, as staged in the store.
#[derive(Debug, Clone, PartialEq)]
pub enum Fragment {
    /// Owned grid lanes of one EAGLET partial + its real chunk weight
    /// (the scalar every reducer needs in full to keep the tree's
    /// weight arithmetic bit-identical).
    Eaglet { weight: f32, entries: Vec<(u32, f32)> },
    /// Owned months of one Netflix partial (each with its
    /// `stat_fields` lanes).
    Netflix { entries: Vec<(u32, Vec<f32>)> },
}

const FRAG_EAGLET: u8 = 0;
const FRAG_NETFLIX: u8 = 1;

/// Encode a fragment: `tag u8`, `[weight f32]` (EAGLET), `n u32`,
/// then `n × (key u32, lanes × f32)` — all little-endian. The codec
/// is self-contained (the net-layer frame helpers are private to
/// `net::protocol`); fragments travel inside `DfsBlock` payloads, so
/// this is a storage format, not a new frame type.
pub fn encode_fragment(frag: &Fragment) -> Vec<u8> {
    let mut out = Vec::new();
    match frag {
        Fragment::Eaglet { weight, entries } => {
            out.push(FRAG_EAGLET);
            out.extend_from_slice(&weight.to_le_bytes());
            out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
            for (k, v) in entries {
                out.extend_from_slice(&k.to_le_bytes());
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Fragment::Netflix { entries } => {
            out.push(FRAG_NETFLIX);
            out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
            for (k, lanes) in entries {
                out.extend_from_slice(&k.to_le_bytes());
                for v in lanes {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
    out
}

struct FragCursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> FragCursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(Error::Protocol("truncated shuffle fragment".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Alloc guard: a declared count may not promise more bytes than
    /// the fragment actually carries.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_bytes) > self.buf.len() - self.pos {
            return Err(Error::Protocol(format!(
                "fragment count {n} exceeds remaining bytes"
            )));
        }
        Ok(n)
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::Protocol(
                "trailing bytes after shuffle fragment".into(),
            ));
        }
        Ok(())
    }
}

/// Decode a fragment. `stat_fields` sizes the Netflix lane vectors;
/// counts are alloc-guarded against the bytes actually present and
/// trailing bytes are an error — hostile store contents surface as
/// `Error::Protocol`, never a panic or oversized allocation.
pub fn decode_fragment(bytes: &[u8], stat_fields: usize) -> Result<Fragment> {
    let mut c = FragCursor { buf: bytes, pos: 0 };
    let frag = match c.u8()? {
        FRAG_EAGLET => {
            let weight = c.f32()?;
            let n = c.count(8)?;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                entries.push((c.u32()?, c.f32()?));
            }
            Fragment::Eaglet { weight, entries }
        }
        FRAG_NETFLIX => {
            let lane_bytes = 4 + 4 * stat_fields.max(1);
            let n = c.count(lane_bytes)?;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                let k = c.u32()?;
                let mut lanes = Vec::with_capacity(stat_fields);
                for _ in 0..stat_fields {
                    lanes.push(c.f32()?);
                }
                entries.push((k, lanes));
            }
            Fragment::Netflix { entries }
        }
        other => {
            return Err(Error::Protocol(format!(
                "unknown fragment tag {other}"
            )))
        }
    };
    c.done()?;
    Ok(frag)
}

/// Slice one map partial down to `partition`'s owned keys (ascending
/// key order — deterministic bytes for deterministic re-staging).
pub fn slice_partial(
    p: &ModelParams,
    plan: &PartitionPlan,
    partial: &TaskPartial,
    partition: u32,
) -> Result<Fragment> {
    match partial {
        TaskPartial::Eaglet { alod, weight } => {
            if alod.len() != plan.assign.len() {
                return Err(Error::Scheduler(format!(
                    "curve partial {} != plan keys {}",
                    alod.len(),
                    plan.assign.len()
                )));
            }
            Ok(Fragment::Eaglet {
                weight: *weight,
                entries: plan
                    .keys_of(partition)
                    .into_iter()
                    .map(|k| (k, alod[k as usize]))
                    .collect(),
            })
        }
        TaskPartial::Netflix { stats } => {
            let f = p.stat_fields;
            if stats.len() != plan.assign.len() * f {
                return Err(Error::Scheduler(format!(
                    "stats partial {} != plan keys {}×{f}",
                    stats.len(),
                    plan.assign.len()
                )));
            }
            Ok(Fragment::Netflix {
                entries: plan
                    .keys_of(partition)
                    .into_iter()
                    .map(|k| {
                        let k = k as usize;
                        (k as u32, stats[k * f..(k + 1) * f].to_vec())
                    })
                    .collect(),
            })
        }
    }
}

/// Reducer-side merge: rebuild zero-padded full-shape partials from
/// this partition's fragments (one per map task, `seq` order) and run
/// the *same* `seq`-ordered reduce tree the r=1 path runs. Owned
/// lanes of the returned partial are bit-identical to the
/// single-reducer result; unowned lanes are meaningless and must
/// never be read (assembly doesn't).
pub fn run_reduce(
    rt: &impl Exec,
    p: &ModelParams,
    workload: Workload,
    fragments: &[Fragment],
) -> Result<TaskPartial> {
    let keys = n_keys(workload, p);
    match workload {
        Workload::Eaglet | Workload::Ssag => {
            let mut partials = Vec::with_capacity(fragments.len());
            for frag in fragments {
                let Fragment::Eaglet { weight, entries } = frag else {
                    return Err(Error::Scheduler(
                        "curve reduce got a stats fragment".into(),
                    ));
                };
                let mut alod = vec![0.0f32; keys];
                for &(k, v) in entries {
                    let lane = alod.get_mut(k as usize).ok_or_else(|| {
                        Error::Protocol(format!(
                            "fragment key {k} outside curve {keys}"
                        ))
                    })?;
                    *lane = v;
                }
                partials.push((alod, *weight));
            }
            let (alod, weight) = match workload {
                Workload::Eaglet => reduce_eaglet(rt, p, partials)?,
                _ => reduce_ssag(rt, p, partials)?,
            };
            Ok(TaskPartial::Eaglet { alod, weight })
        }
        Workload::NetflixHi | Workload::NetflixLo | Workload::SeqAddr => {
            let f = p.stat_fields;
            let mut partials = Vec::with_capacity(fragments.len());
            for frag in fragments {
                let Fragment::Netflix { entries } = frag else {
                    return Err(Error::Scheduler(
                        "stats reduce got a curve fragment".into(),
                    ));
                };
                let mut stats = vec![0.0f32; keys * f];
                for (k, lanes) in entries {
                    let k = *k as usize;
                    if k >= keys || lanes.len() != f {
                        return Err(Error::Protocol(format!(
                            "fragment key {k} / {} lanes outside \
                             {keys}×{f}",
                            lanes.len()
                        )));
                    }
                    stats[k * f..(k + 1) * f].copy_from_slice(lanes);
                }
                partials.push(stats);
            }
            let stats = match workload {
                Workload::SeqAddr => reduce_seqaddr(rt, p, partials)?,
                _ => reduce_netflix(rt, p, partials)?,
            };
            Ok(TaskPartial::Netflix { stats })
        }
    }
}

/// Leader-side assembly: take each output lane from its owner
/// partition's reduced partial (EAGLET's total weight comes from
/// partition 0 — every partition computes the identical weight sum).
pub fn assemble_output(
    p: &ModelParams,
    workload: Workload,
    plan: &PartitionPlan,
    reduced: &[TaskPartial],
) -> Result<JobOutput> {
    if reduced.len() != plan.partitions as usize {
        return Err(Error::Scheduler(format!(
            "assemble got {} reduce partials for {} partitions",
            reduced.len(),
            plan.partitions
        )));
    }
    let keys = n_keys(workload, p);
    match workload {
        Workload::Eaglet | Workload::Ssag => {
            let mut alod = vec![0.0f32; keys];
            let mut weight = None;
            for (k, lane) in alod.iter_mut().enumerate() {
                let TaskPartial::Eaglet { alod: part, weight: w } =
                    &reduced[plan.assign[k] as usize]
                else {
                    return Err(Error::Scheduler(
                        "curve assembly over a stats partial".into(),
                    ));
                };
                *lane = part[k];
                weight.get_or_insert(*w);
            }
            let TaskPartial::Eaglet { weight: w0, .. } = &reduced[0]
            else {
                return Err(Error::Scheduler(
                    "curve assembly over a stats partial".into(),
                ));
            };
            Ok(JobOutput::Eaglet {
                alod,
                weight: weight.unwrap_or(*w0),
            })
        }
        Workload::NetflixHi | Workload::NetflixLo | Workload::SeqAddr => {
            let f = p.stat_fields;
            let mut stats = vec![0.0f32; keys * f];
            for m in 0..keys {
                let TaskPartial::Netflix { stats: part } =
                    &reduced[plan.assign[m] as usize]
                else {
                    return Err(Error::Scheduler(
                        "stats assembly over a curve partial".into(),
                    ));
                };
                stats[m * f..(m + 1) * f]
                    .copy_from_slice(&part[m * f..(m + 1) * f]);
            }
            let out = match workload {
                Workload::SeqAddr => finalize_seqaddr(p, &stats)?,
                _ => finalize_netflix(p, &stats)?,
            };
            Ok(JobOutput::Netflix(out))
        }
    }
}

/// Convenience for the leader: slice + encode every map partial into
/// its per-partition shuffle blocks, returning `(key, bytes)` pairs
/// and the total staged shuffle bytes. Deterministic: re-staging on a
/// recovery attempt overwrites each key with identical bytes.
pub fn stage_fragments(
    p: &ModelParams,
    ns: &str,
    plan: &PartitionPlan,
    partials: &[TaskPartial],
) -> Result<(Vec<(String, Arc<Vec<u8>>)>, u64)> {
    let mut out = Vec::with_capacity(
        partials.len() * plan.partitions as usize,
    );
    let mut bytes = 0u64;
    for partition in 0..plan.partitions {
        for (seq, partial) in partials.iter().enumerate() {
            let frag = slice_partial(p, plan, partial, partition)?;
            let enc = encode_fragment(&frag);
            bytes += enc.len() as u64;
            out.push((shuffle_key(ns, partition, seq), Arc::new(enc)));
        }
    }
    Ok((out, bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Backend;
    use crate::util::rng::Rng;

    fn params() -> ModelParams {
        ModelParams::default()
    }

    #[test]
    fn partitioner_parses_and_names() {
        assert_eq!(Partitioner::parse("hash"), Some(Partitioner::Hash));
        assert_eq!(Partitioner::parse("skew"), Some(Partitioner::Skew));
        assert_eq!(Partitioner::parse("zipf"), None);
        assert_eq!(Partitioner::Hash.name(), "hash");
        assert_eq!(Partitioner::Skew.name(), "skew");
    }

    #[test]
    fn plans_are_total_disjoint_covers() {
        for partitioner in [Partitioner::Hash, Partitioner::Skew] {
            let weights: Vec<f64> =
                (0..13).map(|k| (k % 5) as f64).collect();
            let plan = build_plan(partitioner, &weights, 4);
            assert_eq!(plan.assign.len(), 13);
            assert!(plan.assign.iter().all(|&p| p < 4));
            // keys_of partitions the key space exactly once
            let mut seen = vec![0u32; 13];
            for part in 0..4 {
                for k in plan.keys_of(part) {
                    assert_eq!(plan.partition_of(k), part);
                    seen[k as usize] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "not a disjoint cover");
        }
    }

    #[test]
    fn skew_never_loses_to_hash_and_beats_it_on_zipf() {
        let mut rng = Rng::new(0x5EED);
        let mut skew_won_strictly = 0;
        for _ in 0..50 {
            let n = rng.range(4, 40) as usize;
            let r = rng.range(2, 7) as usize;
            let weights: Vec<f64> =
                (0..n).map(|_| rng.pareto(1.5)).collect();
            let hash = build_plan(Partitioner::Hash, &weights, r);
            let skew = build_plan(Partitioner::Skew, &weights, r);
            let (hi, si) = (
                hash.imbalance_factor(&weights),
                skew.imbalance_factor(&weights),
            );
            assert!(si <= hi + 1e-12, "skew {si} worse than hash {hi}");
            if si < hi - 1e-9 {
                skew_won_strictly += 1;
            }
        }
        assert!(
            skew_won_strictly > 25,
            "skew strictly beat hash only {skew_won_strictly}/50 times \
             under Zipf-like weights"
        );
    }

    #[test]
    fn imbalance_factor_degenerate_cases() {
        let plan = build_plan(Partitioner::Hash, &[0.0; 6], 3);
        assert_eq!(plan.imbalance_factor(&[0.0; 6]), 1.0);
        // one partition gets everything → factor = partitions
        let plan = PartitionPlan { partitions: 3, assign: vec![0, 0] };
        assert!((plan.imbalance_factor(&[1.0, 2.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn fragment_codec_round_trips() {
        let p = params();
        let frags = [
            Fragment::Eaglet {
                weight: 7.5,
                entries: vec![(0, 1.25), (31, -2.5)],
            },
            Fragment::Eaglet { weight: 1.0, entries: vec![] },
            Fragment::Netflix {
                entries: vec![
                    (3, vec![1.0, 2.0, 3.0]),
                    (11, vec![-1.0, 0.5, 9.0]),
                ],
            },
            Fragment::Netflix { entries: vec![] },
        ];
        for f in &frags {
            let enc = encode_fragment(f);
            let back = decode_fragment(&enc, p.stat_fields).unwrap();
            assert_eq!(&back, f, "codec changed the fragment");
        }
    }

    #[test]
    fn fragment_decode_rejects_hostile_bytes() {
        let p = params();
        // truncated, bad tag, lying count, trailing bytes
        assert!(decode_fragment(&[], p.stat_fields).is_err());
        assert!(decode_fragment(&[9, 0, 0, 0, 0], p.stat_fields).is_err());
        let mut lying = vec![FRAG_EAGLET];
        lying.extend_from_slice(&1.0f32.to_le_bytes());
        lying.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_fragment(&lying, p.stat_fields).is_err());
        let mut trailing =
            encode_fragment(&Fragment::Eaglet { weight: 1.0, entries: vec![] });
        trailing.push(0);
        assert!(decode_fragment(&trailing, p.stat_fields).is_err());
        // never panics on garbage
        let mut rng = Rng::new(0xFEED);
        for _ in 0..2000 {
            let n = rng.below(64) as usize;
            let bytes: Vec<u8> =
                (0..n).map(|_| rng.below(256) as u8).collect();
            let _ = decode_fragment(&bytes, p.stat_fields);
        }
    }

    #[test]
    fn shuffle_keys_are_namespace_and_partition_disjoint() {
        assert_eq!(shuffle_key("j1/", 2, 7), "j1/sh:2:7");
        assert_ne!(shuffle_key("j1/", 0, 1), shuffle_key("j2/", 0, 1));
        assert_ne!(shuffle_key("", 0, 1), shuffle_key("", 1, 0));
    }

    /// The determinism theorem, in miniature: slicing synthetic map
    /// partials by any plan, reducing each partition with the same
    /// tree, and assembling owned lanes reproduces the r=1 reduce
    /// bit for bit — for all four workloads and both partitioners.
    #[test]
    fn sliced_reduce_matches_single_reducer_bit_for_bit() {
        let p = params();
        let backend = Backend::native(p.clone());
        let mut rng = Rng::new(0xB75);

        // EAGLET: 9 partials with varied weights
        let partials: Vec<TaskPartial> = (0..9)
            .map(|_| TaskPartial::Eaglet {
                alod: (0..p.grid).map(|_| rng.f32() * 4.0).collect(),
                weight: rng.range(1, 6) as f32,
            })
            .collect();
        let single =
            run_reduce_all(&backend, &p, Workload::Eaglet, &partials, 1);
        for partitioner in [Partitioner::Hash, Partitioner::Skew] {
            for r in [2usize, 4] {
                let got = run_reduce_all_with(
                    &backend,
                    &p,
                    Workload::Eaglet,
                    &partials,
                    r,
                    partitioner,
                );
                assert_eq!(got, single, "eaglet r={r} {partitioner:?}");
            }
        }

        // Netflix: 7 partials with skewed month counts
        let f = p.stat_fields;
        let partials: Vec<TaskPartial> = (0..7)
            .map(|_| {
                let mut stats = vec![0.0f32; p.months * f];
                for m in 0..p.months {
                    let n = if m == 0 {
                        rng.range(50, 90)
                    } else {
                        rng.below(5)
                    } as f32;
                    stats[m * f] = n * 3.0;
                    stats[m * f + 1] = n * 10.0;
                    stats[m * f + 2] = n;
                }
                TaskPartial::Netflix { stats }
            })
            .collect();
        let single = run_reduce_all(
            &backend,
            &p,
            Workload::NetflixLo,
            &partials,
            1,
        );
        for partitioner in [Partitioner::Hash, Partitioner::Skew] {
            for r in [2usize, 4] {
                let got = run_reduce_all_with(
                    &backend,
                    &p,
                    Workload::NetflixLo,
                    &partials,
                    r,
                    partitioner,
                );
                assert_eq!(got, single, "netflix r={r} {partitioner:?}");
            }
        }

        // SSAG rides the curve algebra over ssag_points keys
        let partials: Vec<TaskPartial> = (0..6)
            .map(|_| TaskPartial::Eaglet {
                alod: (0..p.ssag_points)
                    .map(|_| rng.f32() * 2.0)
                    .collect(),
                weight: rng.range(1, 9) as f32,
            })
            .collect();
        let single =
            run_reduce_all(&backend, &p, Workload::Ssag, &partials, 1);
        for r in [2usize, 3] {
            let got = run_reduce_all_with(
                &backend,
                &p,
                Workload::Ssag,
                &partials,
                r,
                Partitioner::Skew,
            );
            assert_eq!(got, single, "ssag r={r}");
        }

        // SeqAddr rides the moment algebra over sa_bins keys
        let partials: Vec<TaskPartial> = (0..5)
            .map(|_| {
                let mut stats = vec![0.0f32; p.sa_bins * f];
                for b in 0..p.sa_bins {
                    let n = rng.below(12) as f32;
                    stats[b * f] = n * 1.5;
                    stats[b * f + 1] = n * 4.0;
                    stats[b * f + 2] = n;
                }
                TaskPartial::Netflix { stats }
            })
            .collect();
        let single = run_reduce_all(
            &backend,
            &p,
            Workload::SeqAddr,
            &partials,
            1,
        );
        for r in [2usize, 4] {
            let got = run_reduce_all_with(
                &backend,
                &p,
                Workload::SeqAddr,
                &partials,
                r,
                Partitioner::Skew,
            );
            assert_eq!(got, single, "seqaddr r={r}");
        }
    }

    fn run_reduce_all(
        backend: &Backend,
        p: &ModelParams,
        w: Workload,
        partials: &[TaskPartial],
        r: usize,
    ) -> JobOutput {
        run_reduce_all_with(backend, p, w, partials, r, Partitioner::Hash)
    }

    /// Shuffle + reduce entirely in memory (no store): the compute
    /// contract the executed path must reproduce.
    fn run_reduce_all_with(
        backend: &Backend,
        p: &ModelParams,
        w: Workload,
        partials: &[TaskPartial],
        r: usize,
        partitioner: Partitioner,
    ) -> JobOutput {
        let weights = key_weights(w, p, partials).unwrap();
        let plan = build_plan(partitioner, &weights, r);
        let reduced: Vec<TaskPartial> = (0..plan.partitions)
            .map(|part| {
                let frags: Vec<Fragment> = partials
                    .iter()
                    .map(|t| {
                        let enc = encode_fragment(
                            &slice_partial(p, &plan, t, part).unwrap(),
                        );
                        decode_fragment(&enc, p.stat_fields).unwrap()
                    })
                    .collect();
                run_reduce(backend, p, w, &frags).unwrap()
            })
            .collect();
        assemble_output(p, w, &plan, &reduced).unwrap()
    }

    #[test]
    fn staged_fragments_are_deterministic_and_counted() {
        let p = params();
        let partials: Vec<TaskPartial> = (0..3)
            .map(|i| TaskPartial::Eaglet {
                alod: vec![i as f32; p.grid],
                weight: 1.0 + i as f32,
            })
            .collect();
        let weights =
            key_weights(Workload::Eaglet, &p, &partials).unwrap();
        let plan = build_plan(Partitioner::Skew, &weights, 4);
        let (a, bytes_a) =
            stage_fragments(&p, "j9/", &plan, &partials).unwrap();
        let (b, bytes_b) =
            stage_fragments(&p, "j9/", &plan, &partials).unwrap();
        assert_eq!(bytes_a, bytes_b);
        assert_eq!(a.len(), 12, "r × tasks shuffle blocks");
        for ((ka, va), (kb, vb)) in a.iter().zip(&b) {
            assert_eq!(ka, kb);
            assert_eq!(va, vb, "re-staging changed bytes for {ka}");
        }
        let total: usize = a.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total as u64, bytes_a);
        assert!(a.iter().all(|(k, _)| k.starts_with("j9/sh:")));
    }
}
