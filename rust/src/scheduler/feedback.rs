//! Feedback loop: sizes step-2 batches from observed task timings.
//!
//! The thesis prescribes queueing enough tasks that a worker "can
//! quickly fetch from the queue" instead of waiting a scheduler
//! round-trip per tiny task: we target `lead_s` seconds of queued work
//! per worker, estimated from an EWMA of per-task execution time.

use crate::util::stats::Ewma;

/// Aggregated timing observations driving the step-2 batch size.
#[derive(Debug)]
pub struct FeedbackStats {
    /// EWMA of per-task wall execution seconds (map execute only).
    pub exec_s: Ewma,
    /// EWMA of per-task data fetch seconds.
    pub fetch_s: Ewma,
    /// Per-worker EWMA of execution seconds (busy-skip + hetero view).
    pub worker_exec_s: Vec<Ewma>,
    /// Tasks reported complete.
    pub completed: u64,
}

impl FeedbackStats {
    pub fn new(workers: usize, alpha: f64) -> Self {
        FeedbackStats {
            exec_s: Ewma::new(alpha),
            fetch_s: Ewma::new(alpha),
            worker_exec_s: (0..workers).map(|_| Ewma::new(alpha)).collect(),
            completed: 0,
        }
    }

    /// Grow the per-worker view for a slot that joined mid-job
    /// (elastic membership). The joiner starts with no history, so
    /// [`FeedbackStats::relative_speed`] reports 1.0 until it observes.
    pub fn add_worker(&mut self, alpha: f64) {
        self.worker_exec_s.push(Ewma::new(alpha));
    }

    pub fn observe(&mut self, worker: usize, fetch_s: f64, exec_s: f64) {
        self.exec_s.observe(exec_s);
        self.fetch_s.observe(fetch_s);
        if let Some(w) = self.worker_exec_s.get_mut(worker) {
            w.observe(exec_s);
        }
        self.completed += 1;
    }

    /// Relative speed of `worker` (1.0 = cluster mean; >1 = faster).
    /// Drives busy-skip: slow workers get smaller refills.
    pub fn relative_speed(&self, worker: usize) -> f64 {
        let mine = match self.worker_exec_s.get(worker).and_then(|e| e.get())
        {
            Some(v) if v > 0.0 => v,
            _ => return 1.0,
        };
        let known: Vec<f64> = self
            .worker_exec_s
            .iter()
            .filter_map(|e| e.get())
            .filter(|v| *v > 0.0)
            .collect();
        if known.is_empty() {
            return 1.0;
        }
        let mean = known.iter().sum::<f64>() / known.len() as f64;
        (mean / mine).clamp(0.1, 10.0)
    }
}

/// Batch size for a step-2 refill: enough tasks to cover `lead_s`
/// seconds at the observed per-task time, clamped to `[1, max_batch]`.
/// Before any observation exists (cold start), returns 1 — the probe.
pub fn batch_size(avg_exec_s: Option<f64>, lead_s: f64, max_batch: usize) -> usize {
    match avg_exec_s {
        None => 1,
        Some(t) if t <= 0.0 => max_batch.max(1),
        Some(t) => ((lead_s / t).ceil() as usize).clamp(1, max_batch.max(1)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_start_is_probe_sized() {
        assert_eq!(batch_size(None, 1.0, 64), 1);
    }

    #[test]
    fn fast_tasks_get_bigger_batches() {
        let slow = batch_size(Some(0.5), 1.0, 64);
        let fast = batch_size(Some(0.01), 1.0, 64);
        assert!(fast > slow, "fast={fast} slow={slow}");
        assert_eq!(fast, 64.min((1.0f64 / 0.01).ceil() as usize));
    }

    #[test]
    fn batch_clamped_to_max() {
        assert_eq!(batch_size(Some(1e-9), 1.0, 16), 16);
        assert_eq!(batch_size(Some(100.0), 1.0, 16), 1);
        assert_eq!(batch_size(Some(0.0), 1.0, 16), 16);
    }

    #[test]
    fn relative_speed_tracks_hetero_workers() {
        let mut s = FeedbackStats::new(3, 0.5);
        for _ in 0..20 {
            s.observe(0, 0.0, 0.10); // fast
            s.observe(1, 0.0, 0.10);
            s.observe(2, 0.0, 0.40); // slow node
        }
        assert!(s.relative_speed(0) > 1.0);
        assert!(s.relative_speed(2) < 0.7);
        // unknown worker defaults to mean speed
        assert_eq!(s.relative_speed(99), 1.0);
    }

    #[test]
    fn observe_counts() {
        let mut s = FeedbackStats::new(1, 0.3);
        s.observe(0, 0.1, 0.2);
        s.observe(0, 0.1, 0.2);
        assert_eq!(s.completed, 2);
        assert!(s.exec_s.get().unwrap() > 0.0);
    }
}
