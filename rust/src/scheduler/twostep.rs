//! The two-step scheduler itself: probe step, feedback-sized batch
//! refills with busy-skip round-robin, and work stealing.
//!
//! Concurrency model: workers call [`TwoStepScheduler::next`] to claim
//! work and [`TwoStepScheduler::report`] when a task finishes. All state
//! sits behind one mutex — the scheduler is *supposed* to be cheap
//! relative to even tiny tasks (the paper's BashReduce point), and the
//! hot-path bench (`benches/hot_paths.rs`) holds us to it.
//!
//! **Cache-affinity dispatch** (opt-in via
//! [`TwoStepScheduler::set_affinity`]): when a refill batch is built,
//! a bounded window at the front of the pending pool is scored by how
//! many of each task's blocks the claiming worker already holds
//! ([`crate::cache::AffinityIndex`]), and the batch takes the
//! best-scoring tasks first — seq order breaks ties, and zero-score
//! batches degrade to the plain FIFO refill. The probe step, the
//! busy-skip round-robin sweep, and work stealing are deliberately
//! untouched: affinity reorders refills, it never starves a worker.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use super::dynamic::ResponseTimeTracker;
use super::feedback::{batch_size, FeedbackStats};
use crate::cache::AffinityHook;
use crate::data::block::block_key;
use crate::data::Workload;
use crate::kneepoint::PackedTask;

/// How far into the pending pool a refill looks for affine tasks.
/// Bounded so the scoring scan stays off the hot-path critical path.
const AFFINITY_WINDOW: usize = 32;

/// A schedulable unit: a packed task plus everything the worker needs
/// to run it (workload kind and the subsample-index seed for this task).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    pub task: PackedTask,
    pub workload: Workload,
    /// Seed for drawing this task's subsample indices (deterministic per
    /// task so job-level recovery reproduces results bit-for-bit).
    pub seed: u64,
}

impl TaskSpec {
    pub fn new(task: PackedTask, workload: Workload, job_seed: u64) -> Self {
        let seed = job_seed ^ (task.seq as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        TaskSpec { task, workload, seed }
    }
}

#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Seconds of queued work to keep in front of each worker (step 2).
    pub lead_s: f64,
    /// Hard cap on tasks per refill batch.
    pub max_batch: usize,
    /// Hard cap on a worker's queue depth; busy-skip threshold.
    pub max_queue: usize,
    /// Enable work stealing from the longest queue when idle.
    pub steal: bool,
    /// EWMA smoothing for the feedback loop.
    pub alpha: f64,
    /// Response-time-aware dynamic mode: attach a
    /// [`ResponseTimeTracker`] so refill sizing and dispatch windows
    /// react to leader-observed slot response times (not just worker
    /// self-reports). Implied by `speculate`.
    pub dynamic: bool,
    /// Speculative re-execution: clone tasks that exceed the straggler
    /// threshold to the best-scoring idle slot (first result wins).
    pub speculate: bool,
    /// Quantile (percent) of observed response times the straggler
    /// threshold derives from (`--straggler-pct`).
    pub straggler_pct: f64,
    /// Leader poll cadence (milliseconds) while speculation or elastic
    /// membership keeps the event loop time-bounded
    /// (`--straggler-poll-ms`; 0 is clamped to 1ms).
    pub straggler_poll_ms: u64,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            lead_s: 0.25,
            max_batch: 32,
            max_queue: 64,
            steal: true,
            alpha: 0.3,
            dynamic: false,
            speculate: false,
            straggler_pct: 95.0,
            straggler_poll_ms: super::SPECULATION_POLL.as_millis() as u64,
        }
    }
}

impl SchedConfig {
    /// Whether a response-time tracker should be attached at all.
    pub fn wants_tracker(&self) -> bool {
        self.dynamic || self.speculate
    }

    /// [`SchedConfig::straggler_poll_ms`] as a bounded `Duration`.
    pub fn straggler_poll(&self) -> std::time::Duration {
        std::time::Duration::from_millis(self.straggler_poll_ms.max(1))
    }
}

#[derive(Debug)]
struct Inner {
    /// Tasks not yet assigned to any worker queue (FIFO by seq).
    pending: VecDeque<TaskSpec>,
    /// Per-worker local queues (step-2 batches land here).
    queues: Vec<VecDeque<TaskSpec>>,
    /// Whether each worker has received its step-1 probe task.
    probed: Vec<bool>,
    /// Slots that left the membership (drained or lost): the refill
    /// sweep must not park tasks on a queue nobody will ever claim.
    retired: Vec<bool>,
    stats: FeedbackStats,
    /// Round-robin cursor for refill fairness.
    rr: usize,
    assigned: u64,
    steals: u64,
    refills: u64,
    /// Tasks a refill placed on a worker already holding ≥1 of their
    /// blocks (the affinity win counter).
    affinity_routed: u64,
}

/// See module docs. One instance per job.
pub struct TwoStepScheduler {
    cfg: SchedConfig,
    workers: usize,
    total: usize,
    affinity: Option<AffinityHook>,
    /// Response-time tracker (dynamic mode): refill sizing consults
    /// leader-observed slot response times alongside the job-local
    /// feedback stats, so slowness only the leader can see still
    /// shrinks a slot's refills.
    tracker: Option<Arc<ResponseTimeTracker>>,
    inner: Mutex<Inner>,
}

/// Point-in-time counters (tests, metrics, the CLI `--verbose` path).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedSnapshot {
    pub pending: usize,
    pub queued: usize,
    pub assigned: u64,
    pub completed: u64,
    pub steals: u64,
    pub refills: u64,
    pub affinity_routed: u64,
    /// Tasks cloned to a second slot past the straggler threshold.
    /// The scheduler itself reports 0 here; the owning `JobCtx` (which
    /// runs the speculation loop) fills both counters into the
    /// snapshot it publishes.
    pub speculated: u64,
    /// Speculated tasks whose clone finished before the original.
    pub won_by_clone: u64,
}

impl TwoStepScheduler {
    pub fn new(tasks: Vec<TaskSpec>, workers: usize, cfg: SchedConfig) -> Self {
        assert!(workers > 0, "scheduler needs at least one worker");
        let total = tasks.len();
        TwoStepScheduler {
            workers,
            total,
            inner: Mutex::new(Inner {
                pending: tasks.into(),
                queues: (0..workers).map(|_| VecDeque::new()).collect(),
                probed: vec![false; workers],
                retired: vec![false; workers],
                stats: FeedbackStats::new(workers, cfg.alpha),
                rr: 0,
                assigned: 0,
                steals: 0,
                refills: 0,
                affinity_routed: 0,
            }),
            affinity: None,
            tracker: None,
            cfg,
        }
    }

    /// Enable cache-affinity dispatch: refill batches prefer tasks
    /// whose blocks (under the hook's namespace) the claiming worker
    /// already holds. Must be called before workers start claiming.
    pub fn set_affinity(&mut self, hook: AffinityHook) {
        self.affinity = Some(hook);
    }

    /// Attach the response-time tracker (dynamic mode). Must be called
    /// before workers start claiming.
    pub fn set_tracker(&mut self, tracker: Arc<ResponseTimeTracker>) {
        self.tracker = Some(tracker);
    }

    pub fn total_tasks(&self) -> usize {
        self.total
    }

    /// Claim the next task for `worker`. Returns `None` only when no
    /// work remains anywhere (own queue, pending pool, stealable peers).
    pub fn next(&self, worker: usize) -> Option<TaskSpec> {
        let mut g = self.inner.lock().unwrap();
        // Step 1: the probe — exactly one task, straight from pending.
        if !g.probed[worker] {
            g.probed[worker] = true;
            if let Some(t) = g.pending.pop_front() {
                g.assigned += 1;
                return Some(t);
            }
        }
        // Step 2: serve from the local queue.
        if let Some(t) = g.queues[worker].pop_front() {
            return Some(t);
        }
        // Local queue dry: pull a feedback-sized batch from pending.
        if !g.pending.is_empty() {
            self.refill(&mut g, worker);
            if let Some(t) = g.queues[worker].pop_front() {
                return Some(t);
            }
        }
        // Pending dry too: steal from the longest peer queue.
        if self.cfg.steal {
            if let Some(t) = Self::steal(&mut g, worker) {
                return Some(t);
            }
        }
        None
    }

    /// Report a finished task — feeds the step-2 loop and, when the
    /// reporter's queue has drained below half, proactively refills it
    /// (the "queue multiple tasks to a node" behaviour).
    pub fn report(&self, worker: usize, fetch_s: f64, exec_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.stats.observe(worker, fetch_s, exec_s);
        if g.queues[worker].len() * 2 < self.cfg.max_queue && !g.pending.is_empty() {
            self.refill(&mut g, worker);
        }
    }

    /// Register a freshly joined map slot (elastic membership) and
    /// return its index. The new slot starts unprobed — its first
    /// claim is a step-1 probe, exactly like a job-start worker — and
    /// with no timing history, so refills stay conservative until it
    /// reports.
    pub fn add_worker(&self) -> usize {
        let mut g = self.inner.lock().unwrap();
        g.queues.push(VecDeque::new());
        g.probed.push(false);
        g.retired.push(false);
        g.stats.add_worker(self.cfg.alpha);
        g.queues.len() - 1
    }

    /// Retire a slot that left the membership (drained or lost): its
    /// queued-but-unclaimed tasks return to the front of the pending
    /// pool in seq order (the next refills redistribute them with
    /// affinity scoring intact), and the busy-skip sweep stops feeding
    /// it. Returns how many tasks were reclaimed. Idempotent.
    pub fn retire_worker(&self, worker: usize) -> usize {
        let mut g = self.inner.lock().unwrap();
        if worker >= g.queues.len() || g.retired[worker] {
            return 0;
        }
        g.retired[worker] = true;
        let mut reclaimed: Vec<TaskSpec> =
            g.queues[worker].drain(..).collect();
        let n = reclaimed.len();
        reclaimed.sort_by_key(|t| t.task.seq);
        for t in reclaimed.into_iter().rev() {
            g.pending.push_front(t);
        }
        n
    }

    /// Return already-dispatched specs (a lost or drained slot's
    /// in-flight window) to the front of the pending pool, seq-ordered,
    /// so they re-dispatch ahead of untouched work.
    pub fn requeue(&self, mut specs: Vec<TaskSpec>) {
        if specs.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        specs.sort_by_key(|t| t.task.seq);
        for t in specs.into_iter().rev() {
            g.pending.push_front(t);
        }
    }

    /// Feedback-sized refill for `worker`, with busy-skip round-robin
    /// top-ups for other starved workers while we hold the lock.
    fn refill(&self, g: &mut Inner, worker: usize) {
        let avg = g.stats.exec_s.get();
        let base = batch_size(avg, self.cfg.lead_s, self.cfg.max_batch);
        // Busy-skip / hetero: scale the batch by the worker's relative
        // speed so slow nodes hold less queued work to strand. In
        // dynamic mode the leader-observed response-time view joins
        // in: take the more pessimistic of the two, so slowness only
        // the leader can see (node contention, link drag) still
        // shrinks the slot's refill.
        let mut speed = g.stats.relative_speed(worker);
        if let Some(t) = &self.tracker {
            speed = speed.min(t.relative_speed(worker));
        }
        let scaled = ((base as f64) * speed).round() as usize;
        // `clamp` panics when lo > hi: keep the refill headroom at ≥ 1
        // even if the queue is already at (or over) max_queue, e.g.
        // under a degenerate SchedConfig { max_queue: 0, .. }.
        let headroom =
            self.cfg.max_queue.saturating_sub(g.queues[worker].len()).max(1);
        let want = scaled.clamp(1, headroom);
        for t in self.pick_batch(g, worker, want) {
            g.queues[worker].push_back(t);
            g.assigned += 1;
        }
        g.refills += 1;
        // Round-robin sweep: give one task to each other worker whose
        // queue is empty (cheap starvation guard while the lock is hot).
        // Sweeps `queues.len()`, not the construction-time worker
        // count: elastic joins grow the slot set mid-job.
        let n = g.queues.len();
        for off in 0..n {
            let w = (g.rr + off) % n;
            if w != worker
                && g.queues[w].is_empty()
                && g.probed[w]
                && !g.retired[w]
            {
                if let Some(t) = g.pending.pop_front() {
                    g.queues[w].push_back(t);
                    g.assigned += 1;
                } else {
                    break;
                }
            }
        }
        g.rr = (g.rr + 1) % n;
    }

    /// Take up to `want` tasks from the pending pool for `worker`.
    /// Plain FIFO without affinity; with it, a bounded front window is
    /// scored by how many of each task's blocks the worker holds, and
    /// the batch takes the best scores first (seq order on ties — a
    /// zero-score window degrades to exactly the FIFO batch).
    fn pick_batch(
        &self,
        g: &mut Inner,
        worker: usize,
        want: usize,
    ) -> Vec<TaskSpec> {
        let want = want.min(g.pending.len());
        if want == 0 {
            return Vec::new();
        }
        let Some(hook) = &self.affinity else {
            return g.pending.drain(..want).collect();
        };
        if hook.index.recorded() == 0 || want == g.pending.len() {
            // nothing recorded yet, or the batch takes the whole pool
            // anyway (order within one worker's queue is irrelevant):
            // skip the scoring scan under the scheduler lock
            return g.pending.drain(..want).collect();
        }
        let window = g.pending.len().min(AFFINITY_WINDOW.max(want));
        // Within one worker's refill the predicted completion time is
        // a constant, so the full placement score would order exactly
        // like the bare affinity count — the prediction term earns its
        // keep where predictions differ across slots: refill *sizing*
        // above, and speculative clone targeting
        // ([`super::dynamic::placement_score`]).
        let mut scored: Vec<(usize, usize)> = (0..window)
            .map(|i| {
                let spec = &g.pending[i];
                let score = hook.index.score(
                    worker,
                    spec.task
                        .sample_ids
                        .iter()
                        .map(|&id| block_key(&hook.ns, spec.workload, id)),
                );
                (i, score)
            })
            .collect();
        scored.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        scored.truncate(want);
        g.affinity_routed +=
            scored.iter().filter(|(_, s)| *s > 0).count() as u64;
        // Pull the chosen positions out of the deque back to front so
        // earlier indices stay valid, then restore the chosen order.
        let chosen: Vec<usize> = scored.iter().map(|&(i, _)| i).collect();
        let mut by_pos = chosen.clone();
        by_pos.sort_unstable();
        by_pos.reverse();
        let mut pulled: HashMap<usize, TaskSpec> = by_pos
            .into_iter()
            .map(|i| (i, g.pending.remove(i).expect("window index in range")))
            .collect();
        chosen
            .into_iter()
            .map(|i| pulled.remove(&i).expect("chosen index pulled"))
            .collect()
    }

    fn steal(g: &mut Inner, thief: usize) -> Option<TaskSpec> {
        let victim = (0..g.queues.len())
            .filter(|&w| w != thief)
            .max_by_key(|&w| g.queues[w].len())?;
        if g.queues[victim].len() <= 1 {
            // Leave a lone queued task with its owner: it is about to be
            // picked up locally, and stealing it would just move the
            // tail-latency problem.
            return None;
        }
        let t = g.queues[victim].pop_back();
        if t.is_some() {
            g.steals += 1;
        }
        t
    }

    pub fn snapshot(&self) -> SchedSnapshot {
        let g = self.inner.lock().unwrap();
        SchedSnapshot {
            pending: g.pending.len(),
            queued: g.queues.iter().map(|q| q.len()).sum(),
            assigned: g.assigned,
            completed: g.stats.completed,
            steals: g.steals,
            refills: g.refills,
            affinity_routed: g.affinity_routed,
            speculated: 0,
            won_by_clone: 0,
        }
    }

    /// Mean observed exec/fetch seconds (feedback view; None pre-probe).
    pub fn observed_exec_s(&self) -> Option<f64> {
        self.inner.lock().unwrap().stats.exec_s.get()
    }

    pub fn observed_fetch_s(&self) -> Option<f64> {
        self.inner.lock().unwrap().stats.fetch_s.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::AffinityIndex;
    use crate::kneepoint::{pack, TaskSizing};
    use crate::data::SampleMeta;
    use crate::prop_assert;
    use crate::util::prop::check;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn specs(n: usize) -> Vec<TaskSpec> {
        let metas: Vec<SampleMeta> = (0..n as u64)
            .map(|id| SampleMeta { id, bytes: 2304, units: 1 })
            .collect();
        pack(&metas, TaskSizing::Tiniest)
            .into_iter()
            .map(|t| TaskSpec::new(t, Workload::Eaglet, 42))
            .collect()
    }

    fn drain_all(s: &TwoStepScheduler, workers: usize) -> Vec<Vec<usize>> {
        // Simulates workers taking turns; returns seqs per worker.
        let mut got = vec![Vec::new(); workers];
        let mut active = true;
        while active {
            active = false;
            for w in 0..workers {
                if let Some(t) = s.next(w) {
                    got[w].push(t.task.seq);
                    s.report(w, 0.001, 0.01);
                    active = true;
                }
            }
        }
        got
    }

    #[test]
    fn every_task_assigned_exactly_once() {
        let s = TwoStepScheduler::new(specs(103), 4, SchedConfig::default());
        let got = drain_all(&s, 4);
        let mut seqs: Vec<usize> = got.into_iter().flatten().collect();
        seqs.sort_unstable();
        assert_eq!(seqs, (0..103).collect::<Vec<_>>());
        let snap = s.snapshot();
        assert_eq!(snap.pending, 0);
        assert_eq!(snap.queued, 0);
    }

    #[test]
    fn probe_step_hands_out_one_task_first() {
        let s = TwoStepScheduler::new(specs(10), 3, SchedConfig::default());
        // All three probes come straight off the pending pool in order.
        let a = s.next(0).unwrap();
        let b = s.next(1).unwrap();
        let c = s.next(2).unwrap();
        assert_eq!((a.task.seq, b.task.seq, c.task.seq), (0, 1, 2));
        // No batches queued yet — feedback has no observations.
        assert_eq!(s.snapshot().assigned, 3);
    }

    #[test]
    fn batches_grow_after_fast_reports() {
        let cfg = SchedConfig { lead_s: 1.0, max_batch: 16, ..Default::default() };
        let s = TwoStepScheduler::new(specs(200), 2, cfg);
        let t = s.next(0).unwrap();
        s.report(0, 0.0, 0.01); // 10ms tasks → want ~16-task batches
        let _ = t;
        let _ = s.next(0).unwrap();
        let snap = s.snapshot();
        assert!(
            snap.assigned > 10,
            "expected a big refill after a fast probe, got {snap:?}"
        );
    }

    #[test]
    fn stealing_rescues_idle_worker() {
        let cfg = SchedConfig { steal: true, ..Default::default() };
        let s = TwoStepScheduler::new(specs(40), 2, cfg);
        // Worker 0 probes, reports fast, and hoards a batch.
        let _ = s.next(0).unwrap();
        s.report(0, 0.0, 0.001);
        let _ = s.next(0).unwrap();
        // Drain pending via worker 0's refills.
        while s.snapshot().pending > 0 {
            if s.next(0).is_none() {
                break;
            }
            s.report(0, 0.0, 0.001);
        }
        // Worker 1 arrives late: everything is queued at worker 0.
        let stolen = s.next(1);
        assert!(stolen.is_some(), "worker 1 should steal");
        assert!(s.snapshot().steals >= 1);
    }

    #[test]
    fn no_steal_when_disabled() {
        let cfg = SchedConfig { steal: false, max_batch: 64, max_queue: 128, lead_s: 10.0, ..Default::default() };
        let s = TwoStepScheduler::new(specs(20), 2, cfg);
        let _ = s.next(0).unwrap();
        s.report(0, 0.0, 0.001);
        while let Some(_t) = {
            let snap = s.snapshot();
            if snap.pending > 0 { s.next(0) } else { None }
        } {
            s.report(0, 0.0, 0.001);
        }
        // worker 1 gets its probe... which may already be gone; with
        // pending drained and stealing off, it must see None.
        if s.snapshot().queued > 0 {
            assert!(s.next(1).is_none());
        }
    }

    #[test]
    fn prop_scheduler_conserves_tasks() {
        check("scheduler conserves tasks", 60, |rng: &mut Rng| {
            let n = rng.range(1, 150) as usize;
            let workers = rng.range(1, 9) as usize;
            let cfg = SchedConfig {
                lead_s: 0.05 + rng.f64() * 0.5,
                max_batch: rng.range(1, 33) as usize,
                max_queue: rng.range(4, 65) as usize,
                steal: rng.below(2) == 0,
                alpha: 0.3,
                ..Default::default()
            };
            let s = TwoStepScheduler::new(specs(n), workers, cfg);
            let mut seen = std::collections::HashSet::new();
            let mut active = true;
            while active {
                active = false;
                for w in 0..workers {
                    if let Some(t) = s.next(w) {
                        prop_assert!(
                            seen.insert(t.task.seq),
                            "task {} double-assigned",
                            t.task.seq
                        );
                        s.report(w, 0.0, rng.f64() * 0.02);
                        active = true;
                    }
                }
            }
            prop_assert!(seen.len() == n, "{} of {n} tasks ran", seen.len());
            Ok(())
        });
    }

    #[test]
    fn affinity_routes_tasks_to_block_holders() {
        let index = Arc::new(AffinityIndex::new(1024));
        // worker 1 already holds the blocks of samples 5..10
        for id in 5..10u64 {
            index.record(1, &block_key("", Workload::Eaglet, id));
        }
        // small batches so the refill has a real choice to make (a
        // batch that would drain the whole pool skips scoring)
        let cfg = SchedConfig { max_batch: 4, ..Default::default() };
        let mut s = TwoStepScheduler::new(specs(20), 2, cfg);
        s.set_affinity(AffinityHook::new(index, "".into()));
        // the probe step stays FIFO
        let probe = s.next(1).unwrap();
        assert_eq!(probe.task.seq, 0);
        s.report(1, 0.001, 0.01);
        // the feedback refill prefers the held blocks
        let t = s.next(1).unwrap();
        assert!(
            (5..10).contains(&t.task.seq),
            "refill ignored affinity: got seq {}",
            t.task.seq
        );
        assert!(s.snapshot().affinity_routed >= 1);
    }

    #[test]
    fn zero_score_affinity_degrades_to_fifo() {
        let index = Arc::new(AffinityIndex::new(1024));
        // non-empty registry (so the scoring path runs), but nothing
        // relevant to this job's keys
        index.record(0, "other-job/blk");
        let mut s =
            TwoStepScheduler::new(specs(10), 2, SchedConfig::default());
        s.set_affinity(AffinityHook::new(index, "".into()));
        let probe = s.next(0).unwrap();
        assert_eq!(probe.task.seq, 0);
        s.report(0, 0.001, 0.01);
        let t = s.next(0).unwrap();
        assert_eq!(t.task.seq, 1, "empty registry must keep seq order");
        assert_eq!(s.snapshot().affinity_routed, 0);
    }

    #[test]
    fn affinity_still_conserves_every_task() {
        let index = Arc::new(AffinityIndex::new(1024));
        for id in 0..40u64 {
            index.record((id % 3) as usize, &block_key("", Workload::Eaglet, id));
        }
        let mut s =
            TwoStepScheduler::new(specs(103), 3, SchedConfig::default());
        s.set_affinity(AffinityHook::new(index, "".into()));
        let got = drain_all(&s, 3);
        let mut seqs: Vec<usize> = got.into_iter().flatten().collect();
        seqs.sort_unstable();
        assert_eq!(seqs, (0..103).collect::<Vec<_>>());
        assert_eq!(s.snapshot().pending, 0);
        assert_eq!(s.snapshot().queued, 0);
    }

    #[test]
    fn tracker_shrinks_refills_for_leader_observed_slow_workers() {
        let tracker = Arc::new(ResponseTimeTracker::new());
        // the leader has watched slot 1 respond 100x slower than slot 0
        for _ in 0..20 {
            tracker.observe_task(0, 0.001);
            tracker.observe_task(1, 0.1);
        }
        let cfg = SchedConfig {
            lead_s: 10.0,
            max_batch: 32,
            dynamic: true,
            ..Default::default()
        };
        let mut s = TwoStepScheduler::new(specs(400), 2, cfg);
        s.set_tracker(tracker);
        // fast worker: probe, fast self-report, full-size refill
        let _ = s.next(0).unwrap();
        s.report(0, 0.0, 0.001);
        let _ = s.next(0).unwrap();
        let after_fast = s.snapshot().assigned;
        // slow worker self-reports *fast* (turbulence the worker can't
        // see) — only the tracker knows better, and it must win
        let _ = s.next(1).unwrap();
        s.report(1, 0.0, 0.001);
        let _ = s.next(1).unwrap();
        let slow_delta = s.snapshot().assigned - after_fast;
        assert!(
            slow_delta * 2 < after_fast,
            "slow slot refill not shrunk: fast={after_fast} slow_delta={slow_delta}"
        );
    }

    #[test]
    fn added_worker_probes_then_joins_the_refill_sweep() {
        let s = TwoStepScheduler::new(specs(60), 2, SchedConfig::default());
        let _ = s.next(0).unwrap();
        s.report(0, 0.001, 0.01);
        // a third slot joins mid-job: its first claim is a probe, and
        // from then on it drains like any other worker
        let w = s.add_worker();
        assert_eq!(w, 2);
        let probe = s.next(w).expect("joined slot gets work");
        s.report(w, 0.001, 0.01);
        let _ = probe;
        let got = drain_all(&s, 3);
        let mut seqs: Vec<usize> = got.into_iter().flatten().collect();
        assert!(!seqs.is_empty());
        seqs.sort_unstable();
        let snap = s.snapshot();
        assert_eq!(snap.pending, 0);
        assert_eq!(snap.queued, 0);
    }

    #[test]
    fn retired_worker_returns_queue_and_conservation_holds() {
        let cfg = SchedConfig { lead_s: 10.0, ..Default::default() };
        let s = TwoStepScheduler::new(specs(80), 3, cfg);
        // worker 1 probes, reports fast, and hoards a refill batch
        let first = s.next(1).unwrap();
        s.report(1, 0.0, 0.001);
        let second = s.next(1).unwrap();
        assert!(s.snapshot().queued > 0, "need a hoarded queue to retire");
        // worker 1 leaves: its queue returns to pending, and the two
        // claimed-but-unfinished specs are requeued by the leader
        let reclaimed = s.retire_worker(1);
        assert!(reclaimed > 0);
        assert_eq!(s.retire_worker(1), 0, "retire must be idempotent");
        s.requeue(vec![first, second]);
        // survivors drain everything exactly once
        let mut seen = std::collections::HashSet::new();
        loop {
            let mut any = false;
            for w in [0usize, 2] {
                if let Some(t) = s.next(w) {
                    assert!(seen.insert(t.task.seq), "double-assigned");
                    s.report(w, 0.0, 0.001);
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        assert_eq!(seen.len(), 80);
        assert_eq!(s.snapshot().pending, 0);
        assert_eq!(s.snapshot().queued, 0);
    }

    #[test]
    fn requeued_specs_redispatch_first_in_seq_order() {
        let s = TwoStepScheduler::new(specs(10), 1, SchedConfig::default());
        let a = s.next(0).unwrap(); // seq 0 (probe)
        s.report(0, 0.0, 0.001);
        let b = s.next(0).unwrap();
        let (sa, sb) = (a.task.seq, b.task.seq);
        s.requeue(vec![b, a]);
        // the lost window comes back before untouched work, low seq
        // first regardless of the order the caller collected it in
        let w = s.add_worker();
        assert_eq!(s.next(w).unwrap().task.seq, sa.min(sb));
    }

    #[test]
    fn task_spec_seed_is_per_task_deterministic() {
        let a = specs(5);
        let b = specs(5);
        assert_eq!(a, b);
        assert_ne!(a[0].seed, a[1].seed);
    }
}
