//! Two-step dynamic task scheduler (thesis §1.1.2, §3.5, Fig 7).
//!
//! Step 1 assigns exactly **one probe task per worker**. Step 2 runs a
//! feedback loop: measured task execution and data-fetch times size the
//! per-worker batches ("the dynamic scheduler now queues multiple tasks
//! to a node such that a node need not wait for next task, instead it
//! can quickly fetch from the queue"). Refills are round-robin with
//! busy-skip — workers whose queue is still deep are skipped, which is
//! what erases the heterogeneity slowdown on large jobs (§4.2.4) — and
//! idle workers steal from the longest queue once the pending pool
//! drains (work stealing, refs [2],[39],[41]).

//! Since PR 5, the **dynamic** layer (`dynamic`) closes the loop the
//! thesis asks for — "schedules the tasks to worker nodes based on the
//! availability and response times of the data nodes": a shared
//! [`ResponseTimeTracker`] of leader-observed per-slot and per-data-
//! node response times feeds refill sizing, dispatch-window collapse
//! for slow slots, and quantile-thresholded speculative re-execution
//! of straggling tiny tasks (first bit-identical result wins).

pub mod dynamic;
pub mod feedback;
pub mod twostep;

pub use dynamic::{
    inflight_target, placement_score, rank_idle_slots, DoneKind,
    LatencyHistogram, ResponseTimeTracker, SpeculationState,
    SPECULATION_POLL,
};
pub use feedback::{batch_size, FeedbackStats};
pub use twostep::{SchedConfig, SchedSnapshot, TaskSpec, TwoStepScheduler};
