//! Response-time-aware dynamic scheduling: the tracker, the placement
//! score, and speculative tiny-task re-execution (DESIGN.md §12).
//!
//! The thesis's dynamic scheduler "schedules the tasks to worker nodes
//! based on the availability and response times of the data nodes".
//! The two-step scheduler already adapts batch *size* from worker
//! self-reported timings, but self-reports miss exactly the failures
//! that matter: a contended node sleeps *outside* its own timers, a
//! partitioned TCP worker reports nothing at all. This module closes
//! the loop from the leader's side:
//!
//! * [`ResponseTimeTracker`] — EWMAs of *leader-observed* response
//!   time per map slot (dispatch → first completion, so queue drag and
//!   invisible slowness count), the latest per-data-node fetch
//!   response mirrored from [`crate::dfs::Dfs::get_traced`]'s internal
//!   estimates, and heartbeat-gap overruns reported by the remote link
//!   pumps. Shared as an `Arc`: the serve pool keeps one for its whole
//!   life, so a new tenant's first task already knows which slots are
//!   slow.
//! * [`placement_score`] — combines cache affinity (blocks the slot
//!   already holds) with predicted completion time into one comparable
//!   score. Strictly monotone: a slower observed slot never gains
//!   score (`prop_invariants.rs` holds this for arbitrary inputs).
//! * [`SpeculationState`] — leader-side bookkeeping for speculative
//!   re-execution: when a dispatched tiny task's age exceeds a
//!   quantile-based straggler threshold, it is cloned to the
//!   best-scoring idle slot, **exactly once**; the first completion
//!   wins and late duplicates are dropped. Determinism holds because
//!   a task's partial is a function of `(seed, seq)` alone — whichever
//!   copy lands first carries bit-identical bytes, and the seq-ordered
//!   reduce never sees arrival order.
//!
//! The straggler threshold comes from a [`LatencyHistogram`]:
//! log-bucketed, bounded, and permutation-invariant, so the quantile a
//! threshold is derived from does not depend on the order completions
//! happened to arrive in — restarts and multiplexing reorder freely.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::scheduler::TaskSpec;
use crate::util::stats::Ewma;

/// Completions required before speculation may fire (a threshold from
/// one probe is noise).
pub const MIN_STRAGGLER_SAMPLES: u64 = 8;

/// Floor on the straggler threshold: sub-millisecond jitter on healthy
/// slots must not trigger clone churn.
pub const MIN_STRAGGLER_S: f64 = 1e-3;

/// A task is a straggler when its age exceeds this multiple of the
/// `straggler_pct` quantile of observed response times.
pub const STRAGGLER_MULT: f64 = 2.0;

/// Seconds of predicted-completion credit per block a slot already
/// holds (the affinity half of [`placement_score`]).
pub const AFFINITY_CREDIT_S: f64 = 5e-4;

/// Leader poll cadence while speculation is armed: how often in-flight
/// task ages are checked against the straggler threshold. Shared by
/// the solo executor and the serve dispatcher.
pub const SPECULATION_POLL: Duration = Duration::from_millis(2);

/// Below this relative speed (vs the fastest slot) a slot's dispatch
/// window collapses to one task, so a slow slot can strand at most a
/// single tiny task. 1/3 = sustained 3× slower than the best slot.
pub const SLOW_SLOT_SPEED: f64 = 1.0 / 3.0;

/// EWMA smoothing for the tracker's estimates.
const TRACKER_ALPHA: f64 = 0.25;

/// Log₂-bucketed latency histogram over microseconds: bounded,
/// permutation-invariant, and cheap to quantile. Bucket `i` covers
/// `(2^(i-1), 2^i]` µs; bucket 0 is everything ≤ 1 µs.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; 64],
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { counts: [0; 64], total: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket(secs: f64) -> usize {
        let us = secs * 1e6;
        if us <= 1.0 {
            return 0;
        }
        (us.log2().ceil() as usize).min(63)
    }

    /// Record one latency. Non-finite or negative observations are
    /// ignored — the histogram can never be poisoned into NaN.
    pub fn observe(&mut self, secs: f64) {
        if !secs.is_finite() || secs < 0.0 {
            return;
        }
        self.counts[Self::bucket(secs)] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Upper bound of the bucket holding the `pct` (0–100) quantile.
    /// `None` with no observations. Depends only on the multiset of
    /// observations, never their order.
    pub fn quantile(&self, pct: f64) -> Option<f64> {
        if self.total == 0 {
            return None;
        }
        let pct = if pct.is_finite() { pct.clamp(0.0, 100.0) } else { 100.0 };
        let rank = ((pct / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some(2f64.powi(i as i32) * 1e-6);
            }
        }
        Some(2f64.powi(63) * 1e-6)
    }
}

#[derive(Debug, Default)]
struct TrackerInner {
    /// Leader-observed response time (dispatch → first completion) per
    /// map slot. Grown on demand — remote slots appear when they join.
    slots: Vec<Ewma>,
    /// Latest per-data-node response estimate, mirrored from the DFS
    /// client's own replica-selection EWMAs.
    nodes: Vec<Option<f64>>,
    /// Heartbeat-gap overrun per slot (remote link pumps report how
    /// late each Ping arrived past its interval; 0 for healthy links).
    rtt: Vec<Ewma>,
    hist: LatencyHistogram,
}

fn ensure(v: &mut Vec<Ewma>, slot: usize) {
    while v.len() <= slot {
        v.push(Ewma::new(TRACKER_ALPHA));
    }
}

/// See module docs. One per solo run; one per serve pool, shared by
/// every job the pool's warm slots carry.
#[derive(Debug, Default)]
pub struct ResponseTimeTracker {
    inner: Mutex<TrackerInner>,
}

impl ResponseTimeTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// One task's leader-observed response time on `slot`. Non-finite
    /// or negative observations are dropped at the door.
    pub fn observe_task(&self, slot: usize, secs: f64) {
        if !secs.is_finite() || secs < 0.0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        ensure(&mut g.slots, slot);
        g.slots[slot].observe(secs);
        g.hist.observe(secs);
    }

    /// Heartbeat-gap overrun for `slot` (seconds past the expected
    /// ping interval; clamped at 0 for early pings).
    pub fn observe_rtt(&self, slot: usize, overrun_s: f64) {
        if !overrun_s.is_finite() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        ensure(&mut g.rtt, slot);
        g.rtt[slot].observe(overrun_s.max(0.0));
    }

    /// Mirror the DFS client's per-node response estimates (the
    /// existing `get_traced` feedback) into the tracker.
    pub fn ingest_node_responses(&self, responses: &[Option<f64>]) {
        let mut g = self.inner.lock().unwrap();
        g.nodes = responses
            .iter()
            .map(|r| (*r).filter(|v| v.is_finite() && *v >= 0.0))
            .collect();
    }

    /// Latest response estimate for data node `node`, if any.
    pub fn node_response_s(&self, node: usize) -> Option<f64> {
        self.inner.lock().unwrap().nodes.get(node).copied().flatten()
    }

    /// The currently slowest data node `(node, secs)`, if any node has
    /// served a fetch yet.
    pub fn slowest_node(&self) -> Option<(usize, f64)> {
        let g = self.inner.lock().unwrap();
        g.nodes
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.map(|v| (i, v)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    /// Completions observed so far.
    pub fn samples(&self) -> u64 {
        self.inner.lock().unwrap().hist.count()
    }

    /// Predicted response time for the next task on `slot`: the slot's
    /// own EWMA (falling back to the cross-slot mean, then 0 with no
    /// data at all) plus its heartbeat overrun. Always finite and
    /// non-negative.
    pub fn predicted_task_s(&self, slot: usize) -> f64 {
        let g = self.inner.lock().unwrap();
        let own = g.slots.get(slot).and_then(|e| e.get());
        let base = own.unwrap_or_else(|| {
            let known: Vec<f64> =
                g.slots.iter().filter_map(|e| e.get()).collect();
            if known.is_empty() {
                0.0
            } else {
                known.iter().sum::<f64>() / known.len() as f64
            }
        });
        let rtt = g.rtt.get(slot).and_then(|e| e.get()).unwrap_or(0.0);
        (base + rtt).max(0.0)
    }

    /// Relative speed of `slot` against the *fastest* observed slot:
    /// 1.0 means "as fast as the best", 0.1 means "ten times slower".
    /// Benchmarked against the best rather than the mean so a single
    /// slow slot in a small pool cannot drag the yardstick down and
    /// hide itself. Clamped to `[0.05, 1.0]`; 1.0 with no data.
    pub fn relative_speed(&self, slot: usize) -> f64 {
        let g = self.inner.lock().unwrap();
        let rtt =
            |i: usize| g.rtt.get(i).and_then(|e| e.get()).unwrap_or(0.0);
        let mine = match g.slots.get(slot).and_then(|e| e.get()) {
            Some(v) if v + rtt(slot) > 0.0 => v + rtt(slot),
            _ => return 1.0,
        };
        let fastest = g
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.get().map(|v| v + rtt(i)))
            .filter(|v| *v > 0.0)
            .fold(f64::INFINITY, f64::min);
        if !fastest.is_finite() {
            return 1.0;
        }
        (fastest / mine).clamp(0.05, 1.0)
    }

    /// Pessimistic prior for a slot that just joined (elastic
    /// membership): seed its EWMA at several times the *slowest* known
    /// slot, so the two-step refill starts it probe-sized and clone
    /// placement avoids it until real completions talk it down
    /// (`TRACKER_ALPHA` converges in a handful of tasks). The straggler
    /// histogram is deliberately not seeded — a prior is not an
    /// observation and must not move the quantile threshold. No-op
    /// when nothing has been observed yet: with no yardstick, the
    /// joiner is as unknown as everyone else.
    pub fn seed_pessimistic(&self, slot: usize) {
        let mut g = self.inner.lock().unwrap();
        let worst = g
            .slots
            .iter()
            .filter_map(|e| e.get())
            .fold(0.0f64, f64::max);
        if worst <= 0.0 {
            return;
        }
        ensure(&mut g.slots, slot);
        g.slots[slot].observe(worst * 4.0);
    }

    /// Age past which an in-flight task counts as a straggler, or
    /// `None` until [`MIN_STRAGGLER_SAMPLES`] completions exist.
    /// `pct` is the quantile in percent (`--straggler-pct`).
    pub fn straggler_threshold_s(&self, pct: f64) -> Option<f64> {
        let g = self.inner.lock().unwrap();
        if g.hist.count() < MIN_STRAGGLER_SAMPLES {
            return None;
        }
        g.hist
            .quantile(pct)
            .map(|q| (q * STRAGGLER_MULT).max(MIN_STRAGGLER_S))
    }
}

/// One comparable placement score for "run this task on that slot":
/// affinity credit for blocks the slot already holds, minus the
/// predicted completion time. Monotone by construction — more held
/// blocks never hurts, a slower slot never helps — and total: bad
/// inputs (NaN, negative predictions) sanitize to 0 rather than
/// poisoning comparisons.
pub fn placement_score(affine_blocks: usize, predicted_s: f64) -> f64 {
    let p = if predicted_s.is_finite() && predicted_s > 0.0 {
        predicted_s
    } else {
        0.0
    };
    affine_blocks as f64 * AFFINITY_CREDIT_S - p
}

/// Dispatch window for `slot`: `base` tasks normally, collapsing to 1
/// when the tracker has seen the slot run slow — a straggling slot can
/// then strand at most one tiny task instead of a whole window.
pub fn inflight_target(
    tracker: Option<&ResponseTimeTracker>,
    slot: usize,
    base: usize,
) -> usize {
    match tracker {
        Some(t) if t.relative_speed(slot) < SLOW_SLOT_SPEED => 1,
        _ => base.max(1),
    }
}

/// Order idle slots fastest-predicted first (ties by slot id, so the
/// ranking is total and deterministic); identity order without a
/// tracker. Reduce partitions are few and long, so which slot gets one
/// matters more than it does for tiny map tasks — drivers hand the
/// heaviest remaining partition to the best-ranked slot.
pub fn rank_idle_slots(
    tracker: Option<&ResponseTimeTracker>,
    idle: &[usize],
) -> Vec<usize> {
    let mut v = idle.to_vec();
    if let Some(t) = tracker {
        v.sort_by(|&a, &b| {
            t.predicted_task_s(a)
                .partial_cmp(&t.predicted_task_s(b))
                .expect("predictions are finite")
                .then(a.cmp(&b))
        });
    }
    v
}

#[derive(Debug)]
struct TaskTimes {
    /// The spec, retained while in flight (what a clone re-dispatches);
    /// dropped at first completion to keep tombstones small.
    spec: Option<TaskSpec>,
    primary: usize,
    primary_at: Instant,
    /// The speculative copy, if one was dispatched: (slot, instant).
    clone: Option<(usize, Instant)>,
    done: bool,
}

impl TaskTimes {
    /// Leader-observed latency of the copy running on `slot`, measured
    /// from *that copy's own* dispatch — the rescuing slot must never
    /// be charged for the time the straggler sat elsewhere.
    fn slot_latency_s(&self, slot: usize) -> f64 {
        match self.clone {
            Some((w, at)) if w == slot => at.elapsed().as_secs_f64(),
            _ => self.primary_at.elapsed().as_secs_f64(),
        }
    }
}

/// What one completion meant to the speculation bookkeeping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DoneKind {
    /// First completion, by the slot the task was dispatched to.
    Primary,
    /// First completion, by the speculative clone — the clone won.
    CloneWin,
    /// A late copy of an already-completed task; drop it.
    Duplicate,
}

/// One completion, resolved against the dispatch bookkeeping.
#[derive(Debug, Clone, Copy)]
pub struct DoneInfo {
    pub kind: DoneKind,
    /// Effective task latency: primary dispatch → first completion.
    /// Meaningful only on the first completion (0 for duplicates) —
    /// this is what `JobReport.task_turnaround` summarizes.
    pub turnaround_s: f64,
    /// Latency attributed to the *reporting slot*, measured from that
    /// copy's own dispatch — what feeds the [`ResponseTimeTracker`].
    pub slot_latency_s: f64,
}

/// Leader-side speculative re-execution bookkeeping for one job
/// attempt: which tasks are in flight where and since when, which have
/// been cloned (at most once each), and who won. Embedded in
/// `exec::cluster::JobCtx`; also the source of the leader-observed
/// latencies that feed the [`ResponseTimeTracker`]. Completed entries
/// persist as tombstones so a losing copy's late arrival still yields
/// the true latency of the slot that ran it.
#[derive(Debug, Default)]
pub struct SpeculationState {
    tasks: HashMap<usize, TaskTimes>,
    in_flight: usize,
    speculated: u64,
    won_by_clone: u64,
}

impl SpeculationState {
    pub fn new() -> Self {
        Self::default()
    }

    /// A task left the scheduler for `slot` (the primary dispatch).
    /// `retain_spec` keeps a copy for later cloning — pass the
    /// speculation flag, so non-speculative runs don't pay a per-task
    /// `TaskSpec` clone on the hot dispatch path just to record an
    /// `Instant`.
    pub fn on_dispatch(
        &mut self,
        spec: &TaskSpec,
        slot: usize,
        retain_spec: bool,
    ) {
        self.tasks.insert(
            spec.task.seq,
            TaskTimes {
                spec: retain_spec.then(|| spec.clone()),
                primary: slot,
                primary_at: Instant::now(),
                clone: None,
                done: false,
            },
        );
        self.in_flight += 1;
    }

    /// Drop the in-flight record for `seq` without completing it: its
    /// carrier left the membership and the unit is being requeued, so
    /// the next dispatch re-registers it fresh. Returns the retained
    /// spec (what the re-dispatch sends), or `None` if the task is
    /// done, untracked, or its spec was not retained. Done tombstones
    /// are kept — duplicate detection must survive the departure.
    pub fn abandon(&mut self, seq: usize) -> Option<TaskSpec> {
        match self.tasks.remove(&seq) {
            Some(t) if !t.done => {
                self.in_flight -= 1;
                t.spec
            }
            Some(t) => {
                self.tasks.insert(seq, t);
                None
            }
            None => None,
        }
    }

    /// A completion for `seq` arrived from `slot`. The first
    /// completion reports the turnaround and retires the task;
    /// anything after that is a dead clone to clean up
    /// ([`DoneKind::Duplicate`]) — still stamped with its own copy's
    /// latency so the tracker learns how slow the loser really was.
    pub fn on_done(&mut self, seq: usize, slot: usize) -> DoneInfo {
        let Some(t) = self.tasks.get_mut(&seq) else {
            // Untracked (e.g. a JobCtx rebuilt mid-flight): neutral.
            return DoneInfo {
                kind: DoneKind::Duplicate,
                turnaround_s: 0.0,
                slot_latency_s: 0.0,
            };
        };
        let slot_latency_s = t.slot_latency_s(slot);
        if t.done {
            return DoneInfo {
                kind: DoneKind::Duplicate,
                turnaround_s: 0.0,
                slot_latency_s,
            };
        }
        t.done = true;
        t.spec = None;
        self.in_flight -= 1;
        let kind = match t.clone {
            Some((w, _)) if w == slot && slot != t.primary => {
                self.won_by_clone += 1;
                DoneKind::CloneWin
            }
            _ => DoneKind::Primary,
        };
        DoneInfo {
            kind,
            turnaround_s: t.primary_at.elapsed().as_secs_f64(),
            slot_latency_s,
        }
    }

    /// In-flight seqs older than `threshold_s` that have never been
    /// cloned, oldest first. Cloned and completed tasks never appear,
    /// so a straggler is offered for cloning at most once.
    pub fn overdue(&self, threshold_s: f64) -> Vec<usize> {
        let mut v: Vec<(usize, Duration)> = self
            .tasks
            .iter()
            .filter(|(_, t)| !t.done && t.clone.is_none())
            .filter_map(|(&seq, t)| {
                let age = t.primary_at.elapsed();
                (age.as_secs_f64() > threshold_s).then_some((seq, age))
            })
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.into_iter().map(|(seq, _)| seq).collect()
    }

    /// The primary slot carrying `seq`, while it is still in flight
    /// (clone targets must differ from it).
    pub fn primary_of(&self, seq: usize) -> Option<usize> {
        self.tasks.get(&seq).filter(|t| !t.done).map(|t| t.primary)
    }

    /// The spec of an in-flight task (what a clone re-dispatches).
    pub fn spec_of(&self, seq: usize) -> Option<&TaskSpec> {
        self.tasks.get(&seq).and_then(|t| t.spec.as_ref())
    }

    /// Record that `seq` was cloned to `slot` now. Returns false (and
    /// records nothing) if the task is done or already cloned — the
    /// exactly-once guarantee.
    pub fn mark_cloned(&mut self, seq: usize, slot: usize) -> bool {
        match self.tasks.get_mut(&seq) {
            Some(t) if !t.done && t.clone.is_none() => {
                t.clone = Some((slot, Instant::now()));
                self.speculated += 1;
                true
            }
            _ => false,
        }
    }

    /// Undo [`SpeculationState::mark_cloned`] for a clone that never
    /// actually left the leader (its link died on send): the straggler
    /// becomes cloneable again and the counter stays truthful.
    pub fn cancel_clone(&mut self, seq: usize) {
        if let Some(t) = self.tasks.get_mut(&seq) {
            if !t.done && t.clone.take().is_some() {
                self.speculated -= 1;
            }
        }
    }

    pub fn speculated(&self) -> u64 {
        self.speculated
    }

    pub fn won_by_clone(&self) -> u64 {
        self.won_by_clone
    }

    /// Tasks currently in flight (clones not double-counted).
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Workload;
    use crate::kneepoint::{pack, TaskSizing};
    use crate::data::SampleMeta;

    fn spec(seq: usize) -> TaskSpec {
        let metas: Vec<SampleMeta> = (0..=seq as u64)
            .map(|id| SampleMeta { id, bytes: 2304, units: 1 })
            .collect();
        pack(&metas, TaskSizing::Tiniest)
            .into_iter()
            .map(|t| TaskSpec::new(t, Workload::Eaglet, 42))
            .nth(seq)
            .expect("packed seq")
    }

    #[test]
    fn histogram_quantile_is_monotone_and_order_free() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let xs = [0.001, 0.5, 0.002, 0.0001, 0.25, 0.004];
        for &x in &xs {
            a.observe(x);
        }
        for &x in xs.iter().rev() {
            b.observe(x);
        }
        for pct in [0.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(a.quantile(pct), b.quantile(pct));
        }
        assert!(a.quantile(99.0) >= a.quantile(50.0));
        // bad observations are ignored, not propagated
        a.observe(f64::NAN);
        a.observe(f64::INFINITY);
        a.observe(-1.0);
        assert_eq!(a.count(), xs.len() as u64);
    }

    #[test]
    fn tracker_predicts_and_ranks_slots() {
        let t = ResponseTimeTracker::new();
        assert_eq!(t.predicted_task_s(0), 0.0);
        assert_eq!(t.relative_speed(0), 1.0);
        for _ in 0..20 {
            t.observe_task(0, 0.001);
            t.observe_task(1, 0.050);
        }
        assert!(t.predicted_task_s(1) > t.predicted_task_s(0));
        assert!((t.relative_speed(0) - 1.0).abs() < 1e-9);
        assert!(t.relative_speed(1) < SLOW_SLOT_SPEED);
        // an unknown slot predicts the cross-slot mean
        let mean = t.predicted_task_s(7);
        assert!(mean > 0.0 && mean.is_finite());
        // rtt overrun makes a slot look slower
        t.observe_rtt(0, 0.5);
        assert!(t.predicted_task_s(0) > 0.4);
    }

    #[test]
    fn pessimistic_prior_slows_a_joiner_without_moving_the_quantile() {
        let t = ResponseTimeTracker::new();
        // no observations yet: seeding is a no-op
        t.seed_pessimistic(5);
        assert_eq!(t.predicted_task_s(5), 0.0);
        for _ in 0..20 {
            t.observe_task(0, 0.001);
            t.observe_task(1, 0.010);
        }
        let samples = t.samples();
        t.seed_pessimistic(2);
        // the joiner predicts worse than the worst incumbent and its
        // dispatch window collapses to a probe
        assert!(t.predicted_task_s(2) > t.predicted_task_s(1));
        assert!(t.relative_speed(2) < SLOW_SLOT_SPEED);
        assert_eq!(inflight_target(Some(&t), 2, 4), 1);
        // the prior is not an observation: quantile basis unchanged
        assert_eq!(t.samples(), samples);
        // real completions talk the prior down
        for _ in 0..30 {
            t.observe_task(2, 0.001);
        }
        assert!(t.relative_speed(2) > SLOW_SLOT_SPEED);
    }

    #[test]
    fn straggler_threshold_needs_samples_and_has_a_floor() {
        let t = ResponseTimeTracker::new();
        for i in 0..MIN_STRAGGLER_SAMPLES - 1 {
            t.observe_task(0, 1e-5 * (i + 1) as f64);
        }
        assert_eq!(t.straggler_threshold_s(95.0), None);
        t.observe_task(0, 1e-5);
        let th = t.straggler_threshold_s(95.0).unwrap();
        assert!(th >= MIN_STRAGGLER_S, "floor violated: {th}");
        assert!(th.is_finite());
    }

    #[test]
    fn node_responses_mirror_and_rank() {
        let t = ResponseTimeTracker::new();
        assert!(t.slowest_node().is_none());
        t.ingest_node_responses(&[Some(0.001), None, Some(0.2)]);
        assert_eq!(t.node_response_s(0), Some(0.001));
        assert_eq!(t.node_response_s(1), None);
        assert_eq!(t.slowest_node(), Some((2, 0.2)));
        // a poisoned estimate is dropped, never surfaced
        t.ingest_node_responses(&[Some(f64::NAN)]);
        assert_eq!(t.node_response_s(0), None);
    }

    #[test]
    fn placement_score_is_sane() {
        assert!(placement_score(1, 0.001) > placement_score(0, 0.001));
        assert!(placement_score(0, 0.001) > placement_score(0, 0.1));
        assert!(placement_score(0, f64::NAN).is_finite());
        assert!(placement_score(3, f64::INFINITY).is_finite());
    }

    #[test]
    fn speculation_clones_exactly_once_and_drops_dead_clones() {
        let mut s = SpeculationState::new();
        s.on_dispatch(&spec(0), 1, true);
        std::thread::sleep(Duration::from_millis(2));
        let over = s.overdue(1e-4);
        assert_eq!(over, vec![0]);
        assert_eq!(s.primary_of(0), Some(1));
        assert!(s.mark_cloned(0, 0));
        assert!(!s.mark_cloned(0, 2), "second clone must be refused");
        assert_eq!(s.speculated(), 1);
        // once cloned it is never offered again
        assert!(s.overdue(0.0).is_empty());
        // the clone wins; the primary's late copy is a dead clone
        let win = s.on_done(0, 0);
        assert_eq!(win.kind, DoneKind::CloneWin);
        assert!(win.turnaround_s > 0.0);
        // the winner's slot is charged only from its own dispatch, not
        // for the time the task sat straggling at the primary
        assert!(win.slot_latency_s <= win.turnaround_s);
        assert_eq!(s.won_by_clone(), 1);
        // the dead clone is dropped, but still reports how late the
        // losing slot really was (primary-dispatch relative)
        let dup = s.on_done(0, 1);
        assert_eq!(dup.kind, DoneKind::Duplicate);
        assert_eq!(dup.turnaround_s, 0.0);
        assert!(
            dup.slot_latency_s >= win.turnaround_s,
            "loser latency {} < winner turnaround {}",
            dup.slot_latency_s,
            win.turnaround_s
        );
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn primary_completion_beats_its_clone() {
        let mut s = SpeculationState::new();
        s.on_dispatch(&spec(3), 0, true);
        assert!(s.mark_cloned(3, 1));
        let first = s.on_done(3, 0);
        assert_eq!(first.kind, DoneKind::Primary);
        assert_eq!(s.won_by_clone(), 0);
        assert_eq!(s.on_done(3, 1).kind, DoneKind::Duplicate);
        // a completion for a task the state never saw is neutral
        let ghost = s.on_done(99, 0);
        assert_eq!(ghost.kind, DoneKind::Duplicate);
        assert_eq!(ghost.slot_latency_s, 0.0);
    }

    #[test]
    fn cancelled_clone_restores_the_attempt_and_the_counter() {
        let mut s = SpeculationState::new();
        s.on_dispatch(&spec(0), 1, true);
        assert!(s.mark_cloned(0, 0));
        assert_eq!(s.speculated(), 1);
        // the dispatch failed: the straggler gets its attempt back
        s.cancel_clone(0);
        assert_eq!(s.speculated(), 0);
        assert!(s.mark_cloned(0, 2), "cancelled clone must be retryable");
        assert_eq!(s.speculated(), 1);
        // after completion, cancel is a no-op
        let _ = s.on_done(0, 2);
        s.cancel_clone(0);
        assert_eq!(s.speculated(), 1);
    }

    #[test]
    fn rank_idle_slots_orders_by_prediction() {
        let idle = vec![3, 1, 2];
        // no tracker: identity order (a stable, deterministic default)
        assert_eq!(rank_idle_slots(None, &idle), vec![3, 1, 2]);
        let t = ResponseTimeTracker::new();
        // no observations yet: every prediction ties at the mean, so
        // slot id breaks the tie
        assert_eq!(rank_idle_slots(Some(&t), &idle), vec![1, 2, 3]);
        for _ in 0..20 {
            t.observe_task(1, 0.1);
            t.observe_task(2, 0.001);
            t.observe_task(3, 0.01);
        }
        assert_eq!(rank_idle_slots(Some(&t), &idle), vec![2, 3, 1]);
    }

    #[test]
    fn inflight_target_collapses_for_slow_slots() {
        let t = ResponseTimeTracker::new();
        assert_eq!(inflight_target(None, 0, 4), 4);
        assert_eq!(inflight_target(Some(&t), 0, 4), 4);
        for _ in 0..20 {
            t.observe_task(0, 0.001);
            t.observe_task(1, 0.1);
        }
        assert_eq!(inflight_target(Some(&t), 1, 4), 1);
        assert_eq!(inflight_target(Some(&t), 0, 4), 4);
        assert_eq!(inflight_target(Some(&t), 0, 0), 1);
    }
}
