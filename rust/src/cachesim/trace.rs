//! Access-trace generator for subsampling map tasks.
//!
//! Models exactly the phenomenon the thesis measures (§3.2): a task's
//! working set is `task_bytes` of sample data laid out contiguously; the
//! subsampling component makes *random* marker accesses into it, and the
//! statistical component re-touches a hot region (code, stack, the
//! accumulator grid) between data accesses. As `task_bytes` grows past a
//! cache level, the random accesses start evicting the hot region and
//! each other — miss rate per instruction climbs in the knee-shaped curve
//! of Fig 2 ("random accesses evicting frequently accessed data that
//! normally ... would have hit in cache").

use super::hierarchy::Hierarchy;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Task working-set size (the x-axis of Fig 2 / Fig 9).
    pub task_bytes: usize,
    /// Contiguous bytes touched per subsample access (one marker record).
    pub record_bytes: usize,
    /// Fraction of records subsampled per round — the workload's
    /// "confidence level" knob (Netflix hi vs lo, Fig 9).
    pub subsample_frac: f64,
    /// Subsample rounds (EAGLET recomputes 30×; we scale rounds down and
    /// hold rounds × frac meaningful).
    pub rounds: usize,
    /// Passes the statistic makes over the drawn subset within one round
    /// (EAGLET re-traverses the subsampled markers per LOD-grid position;
    /// Netflix re-reads per accumulator pass). This is what makes the
    /// *subsampled* set — frac × task_bytes — the reuse-critical resident
    /// set, so the knee position scales with the confidence level (Fig 9).
    pub reuse_passes: usize,
    /// Hot region re-touched between data accesses (accumulators, stack).
    pub hot_bytes: usize,
    /// Hot accesses interleaved per record access.
    pub hot_per_record: usize,
    /// Instructions retired per record processed.
    pub instr_per_record: u64,
    pub seed: u64,
}

impl TraceConfig {
    /// EAGLET-shaped task (multi-component pipeline: bigger hot region,
    /// more instructions per record).
    pub fn eaglet(task_bytes: usize) -> Self {
        TraceConfig {
            task_bytes,
            record_bytes: 2304 / 8, // one marker row of a chunk
            subsample_frac: 0.25,   // S/M = 16/64
            rounds: 3,
            reuse_passes: 4, // grid-wise re-traversal of the subsample
            hot_bytes: 24 * 1024,
            hot_per_record: 4,
            instr_per_record: 220,
            seed: 0xF16_2,
        }
    }

    /// Netflix-shaped task; `frac` encodes the confidence level.
    pub fn netflix(task_bytes: usize, frac: f64) -> Self {
        TraceConfig {
            task_bytes,
            // one cache line of rating tuples (~5 (val, month, mask)
            // tuples); sub-line records would alias lines and muddy the
            // resident-set ratio the confidence knob controls
            record_bytes: 64,
            subsample_frac: frac,
            rounds: 3,
            reuse_passes: 3,
            hot_bytes: 8 * 1024,
            hot_per_record: 2,
            instr_per_record: 60,
            seed: 0xF16_9,
        }
    }
}

/// Drive one task's trace through a hierarchy. Returns (accesses,
/// instructions) for the caller's bookkeeping; counters accumulate in
/// `h`. The measurement is *steady-state*: a warm-up (the task's initial
/// sequential input read plus one subsample round) fills the caches,
/// counters reset, then the remaining rounds are measured — compulsory
/// misses are not the phenomenon, capacity evictions are (§3.2). Access
/// volume is capped so huge task sizes stay cheap to model — the *rates*
/// are what matters, and they stabilize quickly.
pub fn run_task_trace(cfg: &TraceConfig, h: &mut Hierarchy) -> (u64, u64) {
    // Warm-up: sequential scan of the task's input (every task reads its
    // data once) + one throw-away subsample round.
    let warm_cap = (cfg.task_bytes as u64).min(48 * 1024 * 1024);
    let mut a = 0u64;
    while a < warm_cap {
        h.access(a);
        a += h.cfg.line as u64;
    }
    run_rounds(cfg, h, 1, cfg.seed ^ 0xACE5);
    h.reset_counters();
    run_rounds(cfg, h, cfg.rounds, cfg.seed ^ cfg.task_bytes as u64)
}

fn run_rounds(
    cfg: &TraceConfig,
    h: &mut Hierarchy,
    rounds: usize,
    seed: u64,
) -> (u64, u64) {
    let mut rng = Rng::new(seed);
    let records = (cfg.task_bytes / cfg.record_bytes).max(1) as u64;
    let per_round =
        ((records as f64 * cfg.subsample_frac) as u64).max(1);
    // Bound the number of distinct subset *entries* by coarsening records
    // into contiguous super-records; the resident set (frac × task_bytes)
    // and the full address span are preserved, only loop bookkeeping
    // shrinks. Line-level access counts are irreducible — they ARE the
    // resident set.
    const MAX_SUBSET: u64 = 24_000;
    let group = per_round.div_ceil(MAX_SUBSET).max(1);
    let subset_n = (per_round / group).max(1);
    let eff_bytes = cfg.record_bytes as u64 * group;
    let span_super = (records / group).max(1);
    let hot_base = (cfg.task_bytes + 4096) as u64; // hot region above data
    let mut accesses = 0u64;
    let mut instructions = 0u64;
    let mut i = 0u64;
    for _ in 0..rounds {
        // Subsampling decides its indices at runtime — the prefetcher
        // can't help (thesis §3.2 "data can't be pre fetched").
        let subset: Vec<u64> = (0..subset_n)
            .map(|_| rng.below(span_super))
            .collect();
        // The statistic re-traverses the drawn subset `reuse_passes`
        // times (grid positions / accumulator passes).
        for _pass in 0..cfg.reuse_passes.max(1) {
            for &rec in &subset {
                let base = rec * eff_bytes;
                let mut off = 0u64;
                while off < eff_bytes {
                    h.access(base + off);
                    accesses += 1;
                    off += h.cfg.line as u64;
                }
                // interleaved hot-region touches (these are the accesses
                // large tasks evict)
                for k in 0..cfg.hot_per_record as u64 {
                    let ha = hot_base
                        + ((i.wrapping_mul(2654435761).wrapping_add(k * 97))
                            % (cfg.hot_bytes as u64 / 8))
                            * 8;
                    h.access(ha);
                    accesses += 1;
                }
                // a super-record stands for `group` real records
                h.retire(cfg.instr_per_record * group);
                instructions += cfg.instr_per_record * group;
                i += 1;
            }
        }
    }
    (accesses, instructions)
}

/// Reuse-distance histogram of a short trace (analysis/testing aid:
/// the thesis's stack-distance argument, §3.2 [12]).
pub fn reuse_distances(addrs: &[u64], line: u64) -> Vec<usize> {
    let mut stack: Vec<u64> = Vec::new();
    let mut out = Vec::with_capacity(addrs.len());
    for &a in addrs {
        let l = a / line;
        if let Some(pos) = stack.iter().rposition(|&x| x == l) {
            out.push(stack.len() - 1 - pos);
            stack.remove(pos);
        } else {
            out.push(usize::MAX); // cold
        }
        stack.push(l);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::hierarchy::CacheConfig;

    fn mpi_at(task_kb: usize) -> f64 {
        let mut h = Hierarchy::new(CacheConfig::sandy_bridge());
        run_task_trace(&TraceConfig::eaglet(task_kb * 1024), &mut h);
        h.l2_mpi()
    }

    #[test]
    fn miss_rate_grows_with_task_size() {
        let small = mpi_at(256);
        let large = mpi_at(16 * 1024);
        assert!(
            large > 4.0 * small.max(1e-9),
            "expected knee: small {small}, large {large}"
        );
    }

    #[test]
    fn tiny_tasks_have_low_mpi() {
        // well under L2: subsample working set is cache-resident
        assert!(mpi_at(128) < 0.002, "mpi {}", mpi_at(128));
    }

    #[test]
    fn confidence_shifts_the_curve() {
        // Fig 9: higher confidence (bigger frac) hits the knee at a
        // *smaller* task size.
        let mut mpi = |task_kb: usize, frac: f64| {
            let mut h = Hierarchy::new(CacheConfig::sandy_bridge());
            run_task_trace(
                &TraceConfig::netflix(task_kb * 1024, frac),
                &mut h,
            );
            h.l2_mpi()
        };
        let mid = 3 * 1024; // between the two knees
        let hi = mpi(mid, 0.5);
        let lo = mpi(mid, 0.02);
        assert!(hi > lo, "hi-conf {hi} should miss more than lo-conf {lo}");
    }

    #[test]
    fn reuse_distance_of_repeated_scan() {
        // scan of N lines repeated: reuse distance N-1 for each re-access
        let addrs: Vec<u64> =
            (0..8u64).chain(0..8u64).map(|i| i * 64).collect();
        let d = reuse_distances(&addrs, 64);
        assert!(d[..8].iter().all(|&x| x == usize::MAX));
        assert!(d[8..].iter().all(|&x| x == 7));
    }

    #[test]
    fn trace_is_deterministic() {
        let mut h1 = Hierarchy::new(CacheConfig::sandy_bridge());
        let mut h2 = Hierarchy::new(CacheConfig::sandy_bridge());
        run_task_trace(&TraceConfig::eaglet(1024 * 1024), &mut h1);
        run_task_trace(&TraceConfig::eaglet(1024 * 1024), &mut h2);
        assert_eq!(h1.l2_misses, h2.l2_misses);
        assert_eq!(h1.instructions, h2.instructions);
    }
}
