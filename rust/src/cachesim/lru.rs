//! Set-associative LRU cache model.
//!
//! This is the substrate that replaces OProfile hardware counters
//! (DESIGN.md §2): we feed it the address trace a subsampling task would
//! generate and read back miss counts. True-LRU replacement per set;
//! ages via a global logical clock.

#[derive(Debug, Clone)]
pub struct SetAssocCache {
    pub line_size: usize,
    pub sets: usize,
    pub ways: usize,
    /// tag per (set, way); u64::MAX = invalid
    tags: Vec<u64>,
    /// last-touch clock per (set, way)
    age: Vec<u64>,
    clock: u64,
    pub accesses: u64,
    pub misses: u64,
}

impl SetAssocCache {
    /// `capacity_bytes` must be divisible by line_size * ways.
    pub fn new(capacity_bytes: usize, line_size: usize, ways: usize) -> Self {
        assert!(capacity_bytes % (line_size * ways) == 0,
            "capacity {capacity_bytes} not divisible by line*ways");
        let sets = capacity_bytes / (line_size * ways);
        assert!(sets.is_power_of_two(), "sets {sets} must be a power of two");
        SetAssocCache {
            line_size,
            sets,
            ways,
            tags: vec![u64::MAX; sets * ways],
            age: vec![0; sets * ways],
            clock: 0,
            accesses: 0,
            misses: 0,
        }
    }

    pub fn capacity_bytes(&self) -> usize {
        self.sets * self.ways * self.line_size
    }

    /// Access one byte address. Returns true on hit.
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        self.clock += 1;
        self.accesses += 1;
        let line = addr / self.line_size as u64;
        let set = (line % self.sets as u64) as usize;
        let tag = line / self.sets as u64;
        let base = set * self.ways;
        let ways = &mut self.tags[base..base + self.ways];
        // hit?
        for (w, t) in ways.iter().enumerate() {
            if *t == tag {
                self.age[base + w] = self.clock;
                return true;
            }
        }
        // miss: evict LRU way
        self.misses += 1;
        let mut victim = 0;
        let mut oldest = u64::MAX;
        for w in 0..self.ways {
            if self.tags[base + w] == u64::MAX {
                victim = w;
                break;
            }
            if self.age[base + w] < oldest {
                oldest = self.age[base + w];
                victim = w;
            }
        }
        self.tags[base + victim] = tag;
        self.age[base + victim] = self.clock;
        false
    }

    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }

    pub fn reset_counters(&mut self) {
        self.accesses = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_fits_in_cache_hits_on_second_pass() {
        // 32 KiB cache, touch 16 KiB twice: second pass all hits.
        let mut c = SetAssocCache::new(32 * 1024, 64, 8);
        for addr in (0..16 * 1024).step_by(64) {
            c.access(addr as u64);
        }
        c.reset_counters();
        for addr in (0..16 * 1024).step_by(64) {
            assert!(c.access(addr as u64), "addr {addr} should hit");
        }
        assert_eq!(c.misses, 0);
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        // 32 KiB cache, stream 1 MiB repeatedly: ~0 hits (LRU streaming).
        let mut c = SetAssocCache::new(32 * 1024, 64, 8);
        for _ in 0..3 {
            for addr in (0..1024 * 1024).step_by(64) {
                c.access(addr as u64);
            }
        }
        assert!(c.miss_rate() > 0.99, "miss rate {}", c.miss_rate());
    }

    #[test]
    fn same_line_hits() {
        let mut c = SetAssocCache::new(4 * 1024, 64, 4);
        c.access(100);
        assert!(c.access(101));
        assert!(c.access(163) == false); // different line
    }

    #[test]
    fn lru_evicts_oldest() {
        // direct-mapped-ish: 2 ways, force 3 tags into one set
        let mut c = SetAssocCache::new(2 * 64 * 2, 64, 2); // 2 sets, 2 ways
        let set_stride = 2 * 64; // same set every stride
        c.access(0); // tag A
        c.access(set_stride as u64); // tag B
        c.access(0); // A is now MRU
        c.access(2 * set_stride as u64); // tag C evicts B (LRU)
        assert!(c.access(0), "A should still be cached");
        assert!(!c.access(set_stride as u64), "B was evicted");
    }

    #[test]
    fn capacity_accounts() {
        let c = SetAssocCache::new(1536 * 1024, 64, 12);
        assert_eq!(c.capacity_bytes(), 1536 * 1024);
    }
}
