//! Cache simulator substrate — the OProfile replacement (DESIGN.md §2).
//!
//! `kneepoint::profiler` drives `trace::run_task_trace` through a
//! `hierarchy::Hierarchy` across task sizes to produce the task-size →
//! miss-rate curve of Fig 2 / Fig 9; `figures::fig2` renders it.

pub mod hierarchy;
pub mod lru;
pub mod trace;

pub use hierarchy::{CacheConfig, Hierarchy, Level};
pub use lru::SetAssocCache;
pub use trace::{reuse_distances, run_task_trace, TraceConfig};
