//! Multi-level cache hierarchy + AMAT model.
//!
//! Latencies follow the thesis's own analysis (§3.2): AMAT is "the time
//! for a lookup in the fastest cache plus the product of the miss rate
//! and the miss penalty" [Patterson & Hennessy], normalized so the
//! fastest cache lookup costs 1 cycle; "memory fetch is 63 times slower
//! than L2 cache fetch on architectures such as Intel Sandy Bridge".

use super::lru::SetAssocCache;

/// Where an access was served from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    L1,
    L2,
    L3,
    Mem,
}

#[derive(Debug, Clone)]
pub struct CacheConfig {
    pub l1_bytes: usize,
    pub l2_bytes: usize,
    pub l3_bytes: usize,
    pub line: usize,
    pub l1_ways: usize,
    pub l2_ways: usize,
    pub l3_ways: usize,
    /// cycles: fastest lookup normalized to 1 (thesis Fig 2 secondary axis)
    pub l1_cycles: f64,
    pub l2_cycles: f64,
    pub l3_cycles: f64,
    pub mem_cycles: f64,
}

impl CacheConfig {
    /// The thesis testbed: Sandy Bridge, 1.5 MB L2, 15 MB L3 (Table 2 /
    /// §3.2). L1 32 KB. mem = 63 × L2 fetch.
    pub fn sandy_bridge() -> Self {
        CacheConfig {
            l1_bytes: 32 * 1024,
            l2_bytes: 1536 * 1024,
            l3_bytes: 15 * 1024 * 1024 / 15 * 15, // 15 MiB-ish, pow2 sets via ways
            line: 64,
            l1_ways: 8,
            l2_ways: 12,
            l3_ways: 15,
            l1_cycles: 1.0,
            l2_cycles: 8.0,
            l3_cycles: 40.0,
            mem_cycles: 8.0 * 63.0,
        }
    }

    /// Opteron-like (Table 2 type 3): bigger L2 (32 MB aggregate).
    pub fn opteron() -> Self {
        CacheConfig {
            l2_bytes: 2 * 1024 * 1024,
            l3_bytes: 32 * 1024 * 1024,
            l3_ways: 16,
            ..Self::sandy_bridge()
        }
    }
}

#[derive(Debug, Clone)]
pub struct Hierarchy {
    pub cfg: CacheConfig,
    l1: SetAssocCache,
    l2: SetAssocCache,
    l3: SetAssocCache,
    pub accesses: u64,
    pub l1_misses: u64,
    pub l2_misses: u64,
    pub l3_misses: u64,
    /// instructions retired alongside the accesses (set by the trace)
    pub instructions: u64,
}

impl Hierarchy {
    pub fn new(cfg: CacheConfig) -> Self {
        // Round capacities so sets are powers of two.
        fn mk(bytes: usize, line: usize, ways: usize) -> SetAssocCache {
            let per_set = line * ways;
            let sets = (bytes / per_set).next_power_of_two();
            let sets = if sets * per_set > bytes * 2 { sets / 2 } else { sets };
            SetAssocCache::new(sets.max(1) * per_set, line, ways)
        }
        Hierarchy {
            l1: mk(cfg.l1_bytes, cfg.line, cfg.l1_ways),
            l2: mk(cfg.l2_bytes, cfg.line, cfg.l2_ways),
            l3: mk(cfg.l3_bytes, cfg.line, cfg.l3_ways),
            cfg,
            accesses: 0,
            l1_misses: 0,
            l2_misses: 0,
            l3_misses: 0,
            instructions: 0,
        }
    }

    /// Access one address through the hierarchy (inclusive fill).
    #[inline]
    pub fn access(&mut self, addr: u64) -> Level {
        self.accesses += 1;
        if self.l1.access(addr) {
            return Level::L1;
        }
        self.l1_misses += 1;
        if self.l2.access(addr) {
            return Level::L2;
        }
        self.l2_misses += 1;
        if self.l3.access(addr) {
            return Level::L3;
        }
        self.l3_misses += 1;
        Level::Mem
    }

    pub fn retire(&mut self, instructions: u64) {
        self.instructions += instructions;
    }

    pub fn l2_mpi(&self) -> f64 {
        if self.instructions == 0 {
            0.0
        } else {
            self.l2_misses as f64 / self.instructions as f64
        }
    }

    pub fn l3_mpi(&self) -> f64 {
        if self.instructions == 0 {
            0.0
        } else {
            self.l3_misses as f64 / self.instructions as f64
        }
    }

    /// Average memory access time in cycles per access (normalized,
    /// fastest = 1 cycle): AMAT = hit_L1 + mr1*(L2 + mr2*(L3 + mr3*Mem)).
    pub fn amat(&self) -> f64 {
        if self.accesses == 0 {
            return self.cfg.l1_cycles;
        }
        let a = self.accesses as f64;
        let mr1 = self.l1_misses as f64 / a;
        let mr2 = if self.l1_misses == 0 {
            0.0
        } else {
            self.l2_misses as f64 / self.l1_misses as f64
        };
        let mr3 = if self.l2_misses == 0 {
            0.0
        } else {
            self.l3_misses as f64 / self.l2_misses as f64
        };
        self.cfg.l1_cycles
            + mr1 * (self.cfg.l2_cycles
                + mr2 * (self.cfg.l3_cycles + mr3 * self.cfg.mem_cycles))
    }

    /// Cycles-per-instruction estimate: base IPC-1 work + memory stalls.
    pub fn cpi(&self, base_cpi: f64) -> f64 {
        if self.instructions == 0 {
            return base_cpi;
        }
        let mem_cycles = self.l1_misses as f64 * self.cfg.l2_cycles
            + self.l2_misses as f64 * self.cfg.l3_cycles
            + self.l3_misses as f64 * self.cfg.mem_cycles;
        base_cpi + mem_cycles / self.instructions as f64
    }

    pub fn reset_counters(&mut self) {
        self.accesses = 0;
        self.l1_misses = 0;
        self.l2_misses = 0;
        self.l3_misses = 0;
        self.instructions = 0;
        self.l1.reset_counters();
        self.l2.reset_counters();
        self.l3.reset_counters();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sandy_bridge_levels_ordered() {
        let h = Hierarchy::new(CacheConfig::sandy_bridge());
        assert!(h.l1.capacity_bytes() < h.l2.capacity_bytes());
        assert!(h.l2.capacity_bytes() < h.l3.capacity_bytes());
    }

    #[test]
    fn small_working_set_stays_in_l1() {
        let mut h = Hierarchy::new(CacheConfig::sandy_bridge());
        // warm up, then measure steady state
        for addr in (0..8 * 1024u64).step_by(64) {
            h.access(addr);
        }
        h.reset_counters();
        for _ in 0..4 {
            for addr in (0..8 * 1024u64).step_by(64) {
                h.access(addr);
            }
        }
        assert_eq!(h.l1_misses, 0);
        assert!(h.amat() < 1.5, "amat {}", h.amat());
    }

    #[test]
    fn huge_working_set_goes_to_memory() {
        let mut h = Hierarchy::new(CacheConfig::sandy_bridge());
        // stream 64 MiB: far beyond L3
        for addr in (0..64 * 1024 * 1024u64).step_by(64) {
            h.access(addr);
        }
        assert!(h.l3_misses > 0);
        assert!(h.amat() > 100.0, "amat {}", h.amat());
    }

    #[test]
    fn amat_monotone_in_working_set() {
        let mut last = 0.0;
        for ws_kb in [16usize, 512, 4096, 32768] {
            let mut h = Hierarchy::new(CacheConfig::sandy_bridge());
            for _ in 0..3 {
                for addr in (0..ws_kb * 1024).step_by(64) {
                    h.access(addr as u64);
                }
            }
            let amat = h.amat();
            assert!(
                amat >= last * 0.95,
                "amat should not collapse: {amat} after {last} @{ws_kb}KiB"
            );
            last = amat;
        }
    }

    #[test]
    fn mpi_counts_instructions() {
        let mut h = Hierarchy::new(CacheConfig::sandy_bridge());
        for addr in (0..4 * 1024 * 1024u64).step_by(64) {
            h.access(addr);
            h.retire(50);
        }
        assert!(h.l2_mpi() > 0.0);
        assert!(h.cpi(1.0) > 1.0);
    }
}
