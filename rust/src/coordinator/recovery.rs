//! Job-level recovery (thesis §3.3).
//!
//! The thesis argues task-level recovery only pays when failures are
//! likely *within* a job: with SLO window P(w), cluster size N, mean
//! time to failure mttf and heavy-tail factor φ, the expected failures
//! per execution are `f_w = N·P(w)·φ / mttf`. At the paper's settings
//! (P(w)=10 min, N=100, mttf=4.3 months, φ=1.5) f_w ≈ 0.0078 — so
//! monitoring overhead would have to fall below ~1% to justify
//! task-level recovery, and BTS restarts whole jobs instead.

use super::job::{run_job, JobConfig, JobResult};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::runtime::Manifest;
use std::sync::Arc;

/// Inputs to the f_w analysis.
#[derive(Debug, Clone)]
pub struct RecoveryParams {
    /// Worst-case running time (the SLO window), seconds.
    pub slo_s: f64,
    /// Cluster size in nodes.
    pub nodes: usize,
    /// Mean time to node/disk failure, seconds.
    pub mttf_s: f64,
    /// Correlated heavy-tail factor φ.
    pub phi: f64,
}

impl RecoveryParams {
    /// The thesis's worked example: P(w)=10 min, N=100, mttf=4.3 months,
    /// φ=1.5 → f_w ≈ 0.0078.
    pub fn thesis_example() -> Self {
        RecoveryParams {
            slo_s: 10.0 * 60.0,
            nodes: 100,
            mttf_s: 4.3 * 30.44 * 24.0 * 3600.0,
            phi: 1.5,
        }
    }
}

/// Expected failures during one execution window: `N·P(w)·φ / mttf`.
pub fn expected_failures(p: &RecoveryParams) -> f64 {
    p.nodes as f64 * p.slo_s * p.phi / p.mttf_s
}

/// Minimum task-level monitoring slowdown (cost_tl) that job-level
/// recovery tolerates: restarting whole jobs costs `f_w · job_time`
/// extra in expectation, so monitoring must cost less than that to win.
pub fn breakeven_monitor_overhead(p: &RecoveryParams) -> f64 {
    expected_failures(p)
}

/// Failure injection: simulated node crash for recovery tests and the
/// §3.3 experiments.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailurePlan {
    /// Worker that dies.
    pub worker: usize,
    /// ... after completing this many tasks.
    pub after_tasks: u64,
    /// ... on this attempt only (1-based). Later attempts run clean,
    /// modelling a transient node failure.
    pub on_attempt: u32,
}

/// Generic job-level retry: run `attempt_fn(attempt)` (1-based) up to
/// `max_attempts.max(1)` times. `Ok` carries the successful value plus
/// the number of restarts that preceded it; exhaustion yields
/// [`Error::JobFailed`] whose `attempts` matches the attempts actually
/// run. Shared by [`run_with_recovery`] and
/// `exec::run_cluster_with_recovery`.
pub fn retry<T>(
    max_attempts: u32,
    mut attempt_fn: impl FnMut(u32) -> Result<T>,
) -> Result<(T, u32)> {
    let max_attempts = max_attempts.max(1);
    let mut last_err: Option<Error> = None;
    for attempt in 1..=max_attempts {
        match attempt_fn(attempt) {
            Ok(v) => return Ok((v, attempt - 1)),
            Err(e) => last_err = Some(e),
        }
    }
    Err(Error::JobFailed {
        attempts: max_attempts,
        cause: last_err
            .map(|e| e.to_string())
            .unwrap_or_else(|| "unknown".into()),
    })
}

/// Run a job with job-level recovery: on any worker failure the *entire
/// job* restarts (same seed → identical final statistic), up to
/// `max_attempts`.
pub fn run_with_recovery(
    dataset: &dyn Dataset,
    manifest: Arc<Manifest>,
    cfg: &JobConfig,
    max_attempts: u32,
) -> Result<JobResult> {
    let (mut result, restarts) = retry(max_attempts, |attempt| {
        let mut attempt_cfg = cfg.clone();
        attempt_cfg.attempt = attempt;
        run_job(dataset, manifest.clone(), &attempt_cfg)
    })?;
    result.report.restarts = restarts;
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thesis_fw_value_reproduced() {
        let fw = expected_failures(&RecoveryParams::thesis_example());
        // §3.3: "Under these settings, fw = 0.0078"
        assert!(
            (fw - 0.0078).abs() < 0.0010,
            "f_w = {fw}, thesis says 0.0078"
        );
    }

    #[test]
    fn fw_scales_linearly_with_cluster_and_window() {
        let base = RecoveryParams::thesis_example();
        let mut big = base.clone();
        big.nodes *= 10;
        assert!(
            (expected_failures(&big) / expected_failures(&base) - 10.0).abs()
                < 1e-9
        );
        let mut long = base.clone();
        long.slo_s *= 3.0;
        assert!(
            (expected_failures(&long) / expected_failures(&base) - 3.0).abs()
                < 1e-9
        );
    }

    #[test]
    fn monitoring_breakeven_below_one_percent() {
        // The §3.3 punchline: "monitoring overhead would have to fall
        // below 1% to justify task-level recovery".
        let be = breakeven_monitor_overhead(&RecoveryParams::thesis_example());
        assert!(be < 0.01, "breakeven {be} should be < 1%");
    }

    // End-to-end restart determinism is covered by
    // rust/tests/integration_recovery.rs (needs artifacts).
}
