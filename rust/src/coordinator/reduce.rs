//! Artifact-based reduce tree + scalar finalization.
//!
//! Reduce phases for interactive subsampling workloads are short
//! relative to map (§3.1); BTS runs them on the master through the same
//! compiled artifacts, `reduce_fan` partials per call, repeating until
//! one partial remains. Partials are combined in `seq` order so results
//! are bit-identical across runs and across job-level restarts.

use crate::data::ModelParams;
use crate::error::{Error, Result};
use crate::runtime::{Exec, HostTensor};

/// Reduce EAGLET `(alod, weight)` partials to the final `(alod, total
/// weight)` via the `eaglet_reduce` artifact (weighted combine).
pub fn reduce_eaglet(
    rt: &impl Exec,
    p: &ModelParams,
    mut partials: Vec<(Vec<f32>, f32)>,
) -> Result<(Vec<f32>, f32)> {
    if partials.is_empty() {
        return Err(Error::Scheduler("reduce over zero partials".into()));
    }
    let g = p.grid;
    let k = p.reduce_fan;
    let entry = rt
        .manifest()
        .entry("eaglet_reduce", k)
        .ok_or_else(|| Error::Artifact("missing eaglet_reduce".into()))?
        .clone();
    while partials.len() > 1 {
        let mut next = Vec::with_capacity(partials.len().div_ceil(k));
        for group in partials.chunks(k) {
            let mut parts = vec![0.0f32; k * g];
            let mut weights = vec![0.0f32; k];
            for (i, (alod, w)) in group.iter().enumerate() {
                if alod.len() != g {
                    return Err(Error::Artifact(format!(
                        "partial grid {} != {g}",
                        alod.len()
                    )));
                }
                parts[i * g..(i + 1) * g].copy_from_slice(alod);
                weights[i] = *w;
            }
            let out = rt.run(
                &entry,
                vec![
                    HostTensor::F32(parts, vec![k, g]),
                    HostTensor::F32(weights, vec![k]),
                ],
            )?;
            let wsum = &out[0];
            let wtot = out[1][0];
            if wtot <= 0.0 {
                return Err(Error::Artifact(
                    "reduce produced zero total weight".into(),
                ));
            }
            next.push((wsum.iter().map(|v| v / wtot).collect(), wtot));
        }
        partials = next;
    }
    Ok(partials.pop().expect("non-empty"))
}

/// Reduce Netflix `[months × fields]` partial stat tensors to one.
pub fn reduce_netflix(
    rt: &impl Exec,
    p: &ModelParams,
    mut partials: Vec<Vec<f32>>,
) -> Result<Vec<f32>> {
    if partials.is_empty() {
        return Err(Error::Scheduler("reduce over zero partials".into()));
    }
    let f = p.months * p.stat_fields;
    let k = p.reduce_fan;
    let entry = rt
        .manifest()
        .entry("netflix_reduce", k)
        .ok_or_else(|| Error::Artifact("missing netflix_reduce".into()))?
        .clone();
    while partials.len() > 1 {
        let mut next = Vec::with_capacity(partials.len().div_ceil(k));
        for group in partials.chunks(k) {
            let mut parts = vec![0.0f32; k * f];
            for (i, s) in group.iter().enumerate() {
                if s.len() != f {
                    return Err(Error::Artifact(format!(
                        "partial stats {} != {f}",
                        s.len()
                    )));
                }
                parts[i * f..(i + 1) * f].copy_from_slice(s);
            }
            let out = rt.run(
                &entry,
                vec![HostTensor::F32(parts, vec![k, p.months, p.stat_fields])],
            )?;
            next.push(out[0].clone());
        }
        partials = next;
    }
    Ok(partials.pop().expect("non-empty"))
}

/// Final per-month estimates (the quantity §4.1.1.2 reports: "typical
/// user ratings by month", with a confidence interval).
#[derive(Debug, Clone, PartialEq)]
pub struct NetflixStats {
    pub mean: Vec<f64>,
    /// 95% CI half-width per month (t≈1.96 normal approximation).
    pub ci_half: Vec<f64>,
    pub count: Vec<f64>,
}

/// Turn the reduced `[months × (sum, sumsq, count)]` tensor into
/// mean/CI — scalar math after the reduce tree bottoms out.
pub fn finalize_netflix(p: &ModelParams, stats: &[f32]) -> Result<NetflixStats> {
    let f = p.stat_fields;
    if stats.len() != p.months * f {
        return Err(Error::Artifact(format!(
            "finalize: stats {} != {}×{f}",
            stats.len(),
            p.months
        )));
    }
    let mut out = NetflixStats {
        mean: Vec::with_capacity(p.months),
        ci_half: Vec::with_capacity(p.months),
        count: Vec::with_capacity(p.months),
    };
    for m in 0..p.months {
        let sum = stats[m * f] as f64;
        let sumsq = stats[m * f + 1] as f64;
        let n = stats[m * f + 2] as f64;
        if n < 1.0 {
            out.mean.push(f64::NAN);
            out.ci_half.push(f64::NAN);
            out.count.push(n);
            continue;
        }
        let mean = sum / n;
        let var = if n > 1.0 {
            ((sumsq - sum * sum / n) / (n - 1.0)).max(0.0)
        } else {
            0.0
        };
        out.mean.push(mean);
        out.ci_half.push(1.96 * (var / n).sqrt());
        out.count.push(n);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finalize_handles_simple_stats() {
        let p = ModelParams::default();
        let f = p.stat_fields;
        let mut stats = vec![0.0f32; p.months * f];
        // month 0: ratings {3, 5} → mean 4, var 2
        stats[0] = 8.0;
        stats[1] = 34.0;
        stats[2] = 2.0;
        let s = finalize_netflix(&p, &stats).unwrap();
        assert!((s.mean[0] - 4.0).abs() < 1e-9);
        let want_ci = 1.96 * (2.0f64 / 2.0).sqrt();
        assert!((s.ci_half[0] - want_ci).abs() < 1e-9);
        // empty month → NaN mean, count 0
        assert!(s.mean[1].is_nan());
        assert_eq!(s.count[1], 0.0);
    }

    #[test]
    fn finalize_rejects_wrong_len() {
        let p = ModelParams::default();
        assert!(finalize_netflix(&p, &[0.0; 5]).is_err());
    }

    // Tree-reduce correctness against a host-side oracle lives in
    // rust/tests/integration_runtime.rs (needs compiled artifacts).
}
