//! Artifact-based reduce tree + scalar finalization.
//!
//! Reduce phases for interactive subsampling workloads are short
//! relative to map (§3.1); BTS runs them on the master through the same
//! compiled artifacts, `reduce_fan` partials per call, repeating until
//! one partial remains. Partials are combined in `seq` order so results
//! are bit-identical across runs and across job-level restarts.
//!
//! Two merge algebras cover all four workloads: a weighted-mean curve
//! (EAGLET's ALOD grid, SSAG's variance ladder) and an elementwise sum
//! of `(sum, sumsq, count)` moment lanes (Netflix's months, SeqAddr's
//! address bins).

use crate::data::ModelParams;
use crate::error::{Error, Result};
use crate::runtime::{Exec, HostTensor};

/// Tree-reduce weighted `(curve, weight)` partials through the named
/// reduce artifact; `g` is the curve length. Each call re-normalizes
/// `wsum / wtot` so the invariant "a partial is a weighted mean" holds
/// at every tree level.
fn reduce_weighted_curve(
    rt: &impl Exec,
    p: &ModelParams,
    mut partials: Vec<(Vec<f32>, f32)>,
    kind: &str,
    g: usize,
) -> Result<(Vec<f32>, f32)> {
    if partials.is_empty() {
        return Err(Error::Scheduler("reduce over zero partials".into()));
    }
    let k = p.reduce_fan;
    let entry = rt
        .manifest()
        .entry(kind, k)
        .ok_or_else(|| Error::Artifact(format!("missing {kind}")))?
        .clone();
    while partials.len() > 1 {
        let mut next = Vec::with_capacity(partials.len().div_ceil(k));
        for group in partials.chunks(k) {
            let mut parts = vec![0.0f32; k * g];
            let mut weights = vec![0.0f32; k];
            for (i, (curve, w)) in group.iter().enumerate() {
                if curve.len() != g {
                    return Err(Error::Artifact(format!(
                        "partial curve {} != {g}",
                        curve.len()
                    )));
                }
                parts[i * g..(i + 1) * g].copy_from_slice(curve);
                weights[i] = *w;
            }
            let out = rt.run(
                &entry,
                vec![
                    HostTensor::F32(parts, vec![k, g]),
                    HostTensor::F32(weights, vec![k]),
                ],
            )?;
            let wsum = &out[0];
            let wtot = out[1][0];
            if wtot <= 0.0 {
                return Err(Error::Artifact(
                    "reduce produced zero total weight".into(),
                ));
            }
            next.push((wsum.iter().map(|v| v / wtot).collect(), wtot));
        }
        partials = next;
    }
    Ok(partials.pop().expect("non-empty"))
}

/// Tree-reduce summed stat tensors through the named reduce artifact;
/// `dims` is the per-partial tensor shape (lane count = product).
fn reduce_summed_stats(
    rt: &impl Exec,
    p: &ModelParams,
    mut partials: Vec<Vec<f32>>,
    kind: &str,
    dims: &[usize],
) -> Result<Vec<f32>> {
    if partials.is_empty() {
        return Err(Error::Scheduler("reduce over zero partials".into()));
    }
    let f: usize = dims.iter().product();
    let k = p.reduce_fan;
    let entry = rt
        .manifest()
        .entry(kind, k)
        .ok_or_else(|| Error::Artifact(format!("missing {kind}")))?
        .clone();
    while partials.len() > 1 {
        let mut next = Vec::with_capacity(partials.len().div_ceil(k));
        for group in partials.chunks(k) {
            let mut parts = vec![0.0f32; k * f];
            for (i, s) in group.iter().enumerate() {
                if s.len() != f {
                    return Err(Error::Artifact(format!(
                        "partial stats {} != {f}",
                        s.len()
                    )));
                }
                parts[i * f..(i + 1) * f].copy_from_slice(s);
            }
            let mut shape = Vec::with_capacity(dims.len() + 1);
            shape.push(k);
            shape.extend_from_slice(dims);
            let out =
                rt.run(&entry, vec![HostTensor::F32(parts, shape)])?;
            next.push(out[0].clone());
        }
        partials = next;
    }
    Ok(partials.pop().expect("non-empty"))
}

/// Reduce EAGLET `(alod, weight)` partials to the final `(alod, total
/// weight)` via the `eaglet_reduce` artifact (weighted combine).
pub fn reduce_eaglet(
    rt: &impl Exec,
    p: &ModelParams,
    partials: Vec<(Vec<f32>, f32)>,
) -> Result<(Vec<f32>, f32)> {
    reduce_weighted_curve(rt, p, partials, "eaglet_reduce", p.grid)
}

/// Reduce SSAG `(variance curve, weight)` partials — same algebra as
/// EAGLET over `ssag_points` lanes.
pub fn reduce_ssag(
    rt: &impl Exec,
    p: &ModelParams,
    partials: Vec<(Vec<f32>, f32)>,
) -> Result<(Vec<f32>, f32)> {
    reduce_weighted_curve(rt, p, partials, "ssag_reduce", p.ssag_points)
}

/// Reduce Netflix `[months × fields]` partial stat tensors to one.
pub fn reduce_netflix(
    rt: &impl Exec,
    p: &ModelParams,
    partials: Vec<Vec<f32>>,
) -> Result<Vec<f32>> {
    reduce_summed_stats(
        rt,
        p,
        partials,
        "netflix_reduce",
        &[p.months, p.stat_fields],
    )
}

/// Reduce SeqAddr `[sa_bins × fields]` partial stat tensors to one.
pub fn reduce_seqaddr(
    rt: &impl Exec,
    p: &ModelParams,
    partials: Vec<Vec<f32>>,
) -> Result<Vec<f32>> {
    reduce_summed_stats(
        rt,
        p,
        partials,
        "seqaddr_reduce",
        &[p.sa_bins, p.stat_fields],
    )
}

/// Final per-key estimates. Historically Netflix's "typical user
/// ratings by month" (§4.1.1.2); the same mean/CI finalization serves
/// SeqAddr's per-address-bin window means — `mean[k]` is then the
/// windowed-mean estimate for bin `k`.
#[derive(Debug, Clone, PartialEq)]
pub struct NetflixStats {
    pub mean: Vec<f64>,
    /// 95% CI half-width per key (t≈1.96 normal approximation).
    pub ci_half: Vec<f64>,
    pub count: Vec<f64>,
}

/// Turn a reduced `[keys × (sum, sumsq, count)]` tensor into mean/CI —
/// scalar math after the reduce tree bottoms out.
fn finalize_moments(
    stat_fields: usize,
    keys: usize,
    stats: &[f32],
) -> Result<NetflixStats> {
    let f = stat_fields;
    if stats.len() != keys * f {
        return Err(Error::Artifact(format!(
            "finalize: stats {} != {keys}×{f}",
            stats.len()
        )));
    }
    let mut out = NetflixStats {
        mean: Vec::with_capacity(keys),
        ci_half: Vec::with_capacity(keys),
        count: Vec::with_capacity(keys),
    };
    for m in 0..keys {
        let sum = stats[m * f] as f64;
        let sumsq = stats[m * f + 1] as f64;
        let n = stats[m * f + 2] as f64;
        if n < 1.0 {
            out.mean.push(f64::NAN);
            out.ci_half.push(f64::NAN);
            out.count.push(n);
            continue;
        }
        let mean = sum / n;
        let var = if n > 1.0 {
            ((sumsq - sum * sum / n) / (n - 1.0)).max(0.0)
        } else {
            0.0
        };
        out.mean.push(mean);
        out.ci_half.push(1.96 * (var / n).sqrt());
        out.count.push(n);
    }
    Ok(out)
}

/// Finalize the Netflix reduce: one (mean, CI) per month.
pub fn finalize_netflix(
    p: &ModelParams,
    stats: &[f32],
) -> Result<NetflixStats> {
    finalize_moments(p.stat_fields, p.months, stats)
}

/// Finalize the SeqAddr reduce: one (mean, CI) per address bin.
pub fn finalize_seqaddr(
    p: &ModelParams,
    stats: &[f32],
) -> Result<NetflixStats> {
    finalize_moments(p.stat_fields, p.sa_bins, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finalize_handles_simple_stats() {
        let p = ModelParams::default();
        let f = p.stat_fields;
        let mut stats = vec![0.0f32; p.months * f];
        // month 0: ratings {3, 5} → mean 4, var 2
        stats[0] = 8.0;
        stats[1] = 34.0;
        stats[2] = 2.0;
        let s = finalize_netflix(&p, &stats).unwrap();
        assert!((s.mean[0] - 4.0).abs() < 1e-9);
        let want_ci = 1.96 * (2.0f64 / 2.0).sqrt();
        assert!((s.ci_half[0] - want_ci).abs() < 1e-9);
        // empty month → NaN mean, count 0
        assert!(s.mean[1].is_nan());
        assert_eq!(s.count[1], 0.0);
    }

    #[test]
    fn finalize_seqaddr_uses_bin_count() {
        let p = ModelParams::default();
        let f = p.stat_fields;
        let mut stats = vec![0.0f32; p.sa_bins * f];
        stats[0] = 6.0; // bin 0: {2, 4} → mean 3
        stats[1] = 20.0;
        stats[2] = 2.0;
        let s = finalize_seqaddr(&p, &stats).unwrap();
        assert_eq!(s.mean.len(), p.sa_bins);
        assert!((s.mean[0] - 3.0).abs() < 1e-9);
        // wrong length (months ≠ sa_bins would catch a mixed-up call)
        assert!(finalize_seqaddr(&p, &stats[..f]).is_err());
    }

    #[test]
    fn finalize_rejects_wrong_len() {
        let p = ModelParams::default();
        assert!(finalize_netflix(&p, &[0.0; 5]).is_err());
    }

    // Tree-reduce correctness against a host-side oracle lives in
    // rust/tests/integration_runtime.rs (needs compiled artifacts).
}
