//! Marshal fetched dfs blocks into the padded, bucketed tensors the AOT
//! artifacts expect, and draw the per-task subsample indices.
//!
//! Subsampling "decides which data is accessed in runtime" (§3.2) — the
//! random indices are *not* baked into the compiled graph. The
//! coordinator draws them per task from the task's seed, ships them as
//! the `idx` input, and identical seeds reproduce identical statistics
//! (the job-level-recovery determinism guarantee).

use crate::data::block::{
    Block, KIND_EAGLET, KIND_NETFLIX, KIND_SEQADDR, KIND_SSAG,
};
use crate::data::{ModelParams, Workload};
use crate::error::{Error, Result};
use crate::runtime::{Exec, HostTensor};
use crate::util::rng::Rng;

/// Draw EAGLET subsample indices: `rounds × subsample` distinct marker
/// columns per round (a subsample round never repeats a marker — that
/// would double-count its information).
pub fn draw_eaglet_idx(p: &ModelParams, seed: u64) -> HostTensor {
    let mut rng = Rng::new(seed);
    let mut idx = Vec::with_capacity(p.rounds * p.subsample);
    for r in 0..p.rounds {
        let mut round = rng.fork(r as u64);
        let mut picks =
            round.sample_distinct(p.markers as u64, p.subsample as u64);
        picks.sort_unstable();
        idx.extend(picks.into_iter().map(|v| v as i32));
    }
    HostTensor::I32(idx, vec![p.rounds, p.subsample])
}

/// Draw Netflix subsample positions: `s` draws (with replacement — the
/// classic bootstrap) over the padded rating slots; padded slots carry
/// mask 0 and contribute nothing.
pub fn draw_netflix_idx(p: &ModelParams, s: usize, seed: u64) -> HostTensor {
    let mut rng = Rng::new(seed);
    let idx: Vec<i32> =
        (0..s).map(|_| rng.below(p.ratings_cap as u64) as i32).collect();
    HostTensor::I32(idx, vec![s])
}

/// Draw sequential-addressing window start offsets: `sa_rounds` draws
/// (with replacement) over the valid starts `[0, sa_len - sa_window]`.
/// One draw is shared by every row in the batch — sequential
/// addressing reads the *same* window of each series, which is what
/// keeps the access pattern contiguous (Pan et al. 2021).
pub fn draw_seqaddr_idx(p: &ModelParams, seed: u64) -> HostTensor {
    let mut rng = Rng::new(seed);
    let starts = (p.sa_len - p.sa_window + 1) as u64;
    let idx: Vec<i32> =
        (0..p.sa_rounds).map(|_| rng.below(starts) as i32).collect();
    HostTensor::I32(idx, vec![p.sa_rounds])
}

/// The common LOD grid all EAGLET partials are combined over.
pub fn lod_grid_points(p: &ModelParams) -> Vec<f32> {
    (0..p.grid).map(|g| g as f32 / p.grid as f32).collect()
}

/// A fully-assembled map task: inputs ready for `Runtime::execute`, plus
/// the bookkeeping needed to interpret the padded output.
pub struct MapTask {
    /// Manifest entry kind (eaglet_map / netflix_map_hi /
    /// netflix_map_lo / seqaddr_map / ssag_map).
    pub kind: &'static str,
    /// Bucket rows actually backed by data (≤ compiled bucket).
    pub real_rows: usize,
    pub bucket: usize,
    pub inputs: Vec<HostTensor>,
}

impl MapTask {
    /// Assemble from decoded blocks. For EAGLET a row is one chunk (a
    /// task batches `units` chunks across its families); for Netflix a
    /// row is one movie. Errors if the task exceeds the largest compiled
    /// bucket — large (BLT-style) tasks go through [`MapTask::slices`].
    pub fn assemble(
        p: &ModelParams,
        workload: Workload,
        blocks: &[Block],
        seed: u64,
    ) -> Result<MapTask> {
        let slices = Self::slices(p, workload, blocks, seed)?;
        match <[_; 1]>::try_from(slices) {
            Ok([one]) => Ok(one),
            Err(v) => Err(Error::Scheduler(format!(
                "task needs {} slices; use MapTask::slices",
                v.len()
            ))),
        }
    }

    /// Assemble into one or more bucket-sized execution slices. Tiny
    /// tasks yield exactly one slice; a BLT "all of Sn in one file" task
    /// yields many — one software-component invocation streaming through
    /// the whole partition, exactly the behaviour whose cache profile
    /// the thesis measures.
    pub fn slices(
        p: &ModelParams,
        workload: Workload,
        blocks: &[Block],
        seed: u64,
    ) -> Result<Vec<MapTask>> {
        match workload {
            Workload::Eaglet => Self::eaglet_slices(p, blocks, seed),
            Workload::NetflixHi => {
                Self::netflix_slices(p, blocks, seed, true)
            }
            Workload::NetflixLo => {
                Self::netflix_slices(p, blocks, seed, false)
            }
            Workload::SeqAddr => Self::seqaddr_slices(p, blocks, seed),
            Workload::Ssag => Self::ssag_slices(p, blocks),
        }
    }

    fn eaglet_slices(
        p: &ModelParams,
        blocks: &[Block],
        seed: u64,
    ) -> Result<Vec<MapTask>> {
        let m = p.markers;
        let i = p.individuals;
        let chunk_words = m * i + m;
        // Flatten to (block, chunk) rows; a huge family may span slices.
        let mut rows: Vec<(&Block, usize)> = Vec::new();
        for b in blocks {
            if b.id.kind != KIND_EAGLET {
                return Err(Error::Data(format!(
                    "eaglet task got block kind {}",
                    b.id.kind
                )));
            }
            if b.payload.len() != b.units as usize * chunk_words {
                return Err(Error::Data(format!(
                    "block {} payload {} != {} chunks × {chunk_words}",
                    b.id.sample,
                    b.payload.len(),
                    b.units
                )));
            }
            rows.extend((0..b.units as usize).map(|c| (b, c)));
        }
        rows.chunks(p.max_bucket())
            .map(|slice| {
                let n = slice.len();
                let bucket = p.bucket_for(n).expect("≤ max bucket");
                let mut geno = vec![0.0f32; bucket * m * i];
                let mut pos = vec![0.0f32; bucket * m];
                for (row, (b, c)) in slice.iter().enumerate() {
                    let src =
                        &b.payload[c * chunk_words..(c + 1) * chunk_words];
                    geno[row * m * i..(row + 1) * m * i]
                        .copy_from_slice(&src[..m * i]);
                    pos[row * m..(row + 1) * m]
                        .copy_from_slice(&src[m * i..]);
                }
                Ok(MapTask {
                    kind: "eaglet_map",
                    real_rows: n,
                    bucket,
                    inputs: vec![
                        HostTensor::F32(geno, vec![bucket, m, i]),
                        HostTensor::F32(pos, vec![bucket, m]),
                        draw_eaglet_idx(p, seed),
                        HostTensor::F32(lod_grid_points(p), vec![p.grid]),
                    ],
                })
            })
            .collect()
    }

    fn netflix_slices(
        p: &ModelParams,
        blocks: &[Block],
        seed: u64,
        high_confidence: bool,
    ) -> Result<Vec<MapTask>> {
        let cap = p.ratings_cap;
        let (kind, s) = if high_confidence {
            ("netflix_map_hi", p.s_hi)
        } else {
            ("netflix_map_lo", p.s_lo)
        };
        blocks
            .chunks(p.max_bucket())
            .map(|slice| {
                let rows = slice.len();
                let bucket = p.bucket_for(rows).expect("≤ max bucket");
                let mut vals = vec![0.0f32; bucket * cap];
                let mut months = vec![0.0f32; bucket * cap];
                let mut mask = vec![0.0f32; bucket * cap];
                for (row, b) in slice.iter().enumerate() {
                    if b.id.kind != KIND_NETFLIX {
                        return Err(Error::Data(format!(
                            "netflix task got block kind {}",
                            b.id.kind
                        )));
                    }
                    if b.payload.len() != 3 * cap {
                        return Err(Error::Data(format!(
                            "movie block {} payload {} != 3×{cap}",
                            b.id.sample,
                            b.payload.len()
                        )));
                    }
                    vals[row * cap..(row + 1) * cap]
                        .copy_from_slice(&b.payload[..cap]);
                    months[row * cap..(row + 1) * cap]
                        .copy_from_slice(&b.payload[cap..2 * cap]);
                    mask[row * cap..(row + 1) * cap]
                        .copy_from_slice(&b.payload[2 * cap..]);
                }
                Ok(MapTask {
                    kind,
                    real_rows: rows,
                    bucket,
                    inputs: vec![
                        HostTensor::F32(vals, vec![bucket, cap]),
                        HostTensor::F32(months, vec![bucket, cap]),
                        HostTensor::F32(mask, vec![bucket, cap]),
                        draw_netflix_idx(p, s, seed),
                    ],
                })
            })
            .collect()
    }

    /// Shared shell for the series workloads: one sample per row, the
    /// payload is the bare series.
    fn series_slices(
        p: &ModelParams,
        blocks: &[Block],
        want_kind: u32,
        len: usize,
        kind: &'static str,
        extra: impl Fn() -> Vec<HostTensor>,
    ) -> Result<Vec<MapTask>> {
        blocks
            .chunks(p.max_bucket())
            .map(|slice| {
                let rows = slice.len();
                let bucket = p.bucket_for(rows).expect("≤ max bucket");
                let mut series = vec![0.0f32; bucket * len];
                for (row, b) in slice.iter().enumerate() {
                    if b.id.kind != want_kind {
                        return Err(Error::Data(format!(
                            "{kind} task got block kind {}",
                            b.id.kind
                        )));
                    }
                    if b.payload.len() != len {
                        return Err(Error::Data(format!(
                            "series block {} payload {} != {len}",
                            b.id.sample,
                            b.payload.len()
                        )));
                    }
                    series[row * len..(row + 1) * len]
                        .copy_from_slice(&b.payload);
                }
                let mut inputs =
                    vec![HostTensor::F32(series, vec![bucket, len])];
                inputs.extend(extra());
                Ok(MapTask { kind, real_rows: rows, bucket, inputs })
            })
            .collect()
    }

    fn seqaddr_slices(
        p: &ModelParams,
        blocks: &[Block],
        seed: u64,
    ) -> Result<Vec<MapTask>> {
        Self::series_slices(
            p,
            blocks,
            KIND_SEQADDR,
            p.sa_len,
            "seqaddr_map",
            || vec![draw_seqaddr_idx(p, seed)],
        )
    }

    /// Politis's scalable subsampling is deterministic — the blocks ARE
    /// the subsamples — so there is no idx input to draw.
    fn ssag_slices(p: &ModelParams, blocks: &[Block]) -> Result<Vec<MapTask>> {
        Self::series_slices(
            p,
            blocks,
            KIND_SSAG,
            p.ssag_len,
            "ssag_map",
            Vec::new,
        )
    }
}

/// Execute assembled slices through any backend and merge them into
/// the task's partial — the shared worker-side hot loop (the exec
/// cluster's workers and the TCP workers both run exactly this).
/// Inputs are handed to the backend by value; the slice shell keeps
/// the row bookkeeping needed to interpret the padded output.
pub fn execute_slices(
    rt: &impl Exec,
    p: &ModelParams,
    slices: Vec<MapTask>,
) -> Result<TaskPartial> {
    let mut parts = Vec::with_capacity(slices.len());
    for mut s in slices {
        let entry = rt
            .manifest()
            .entry(s.kind, s.bucket)
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no entry {} bucket {}",
                    s.kind, s.bucket
                ))
            })?
            .clone();
        let inputs = std::mem::take(&mut s.inputs);
        let out = rt.run(&entry, inputs)?;
        parts.push(TaskPartial::from_map_output(p, &s, &out[0])?);
    }
    TaskPartial::merge(parts)
}

/// A map task's contribution to the final statistic, ready for the
/// reduce tree. Padded output rows are already discarded here.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskPartial {
    /// Mean ALOD over the task's real chunks + its chunk weight.
    Eaglet { alod: Vec<f32>, weight: f32 },
    /// Per-month (sum, sumsq, count) summed over the task's movies.
    Netflix { stats: Vec<f32> },
}

impl TaskPartial {
    /// Merge slice partials into one task partial (used when a large
    /// task executed as several bucket-sized slices).
    pub fn merge(parts: Vec<TaskPartial>) -> Result<TaskPartial> {
        let mut it = parts.into_iter();
        let mut acc = it
            .next()
            .ok_or_else(|| Error::Scheduler("merge of zero partials".into()))?;
        for p in it {
            match (&mut acc, p) {
                (
                    TaskPartial::Eaglet { alod, weight },
                    TaskPartial::Eaglet { alod: a2, weight: w2 },
                ) => {
                    let wtot = *weight + w2;
                    for (x, y) in alod.iter_mut().zip(&a2) {
                        *x = (*x * *weight + y * w2) / wtot;
                    }
                    *weight = wtot;
                }
                (
                    TaskPartial::Netflix { stats },
                    TaskPartial::Netflix { stats: s2 },
                ) => {
                    for (x, y) in stats.iter_mut().zip(&s2) {
                        *x += y;
                    }
                }
                _ => {
                    return Err(Error::Scheduler(
                        "cannot merge partials of different kinds".into(),
                    ))
                }
            }
        }
        Ok(acc)
    }

    /// Build from the raw map output (`out[0]`, row-major over the
    /// bucket dimension).
    pub fn from_map_output(
        p: &ModelParams,
        task: &MapTask,
        out0: &[f32],
    ) -> Result<TaskPartial> {
        // Two shapes only: a weighted-mean curve (Eaglet algebra) or a
        // summed stats vector (Netflix algebra). Each kernel kind maps
        // onto one of them with its own lane count.
        let mean_curve = |g: usize| -> Result<TaskPartial> {
            if out0.len() != task.bucket * g {
                return Err(Error::Artifact(format!(
                    "{} output {} != {}×{g}",
                    task.kind,
                    out0.len(),
                    task.bucket
                )));
            }
            let mut alod = vec![0.0f32; g];
            for row in 0..task.real_rows {
                for (a, v) in
                    alod.iter_mut().zip(&out0[row * g..(row + 1) * g])
                {
                    *a += v;
                }
            }
            let w = task.real_rows as f32;
            for a in &mut alod {
                *a /= w;
            }
            Ok(TaskPartial::Eaglet { alod, weight: w })
        };
        let summed_stats = |f: usize| -> Result<TaskPartial> {
            if out0.len() != task.bucket * f {
                return Err(Error::Artifact(format!(
                    "{} output {} != {}×{f}",
                    task.kind,
                    out0.len(),
                    task.bucket
                )));
            }
            let mut stats = vec![0.0f32; f];
            for row in 0..task.real_rows {
                for (a, v) in
                    stats.iter_mut().zip(&out0[row * f..(row + 1) * f])
                {
                    *a += v;
                }
            }
            Ok(TaskPartial::Netflix { stats })
        };
        match task.kind {
            "eaglet_map" => mean_curve(p.grid),
            "ssag_map" => mean_curve(p.ssag_points),
            "netflix_map_hi" | "netflix_map_lo" => {
                summed_stats(p.months * p.stat_fields)
            }
            "seqaddr_map" => summed_stats(p.sa_bins * p.stat_fields),
            other => Err(Error::Artifact(format!(
                "unknown map kind {other}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::eaglet::{EagletConfig, EagletDataset};
    use crate::data::netflix::{NetflixConfig, NetflixDataset};
    use crate::data::Dataset;

    fn params() -> ModelParams {
        ModelParams::default()
    }

    #[test]
    fn eaglet_idx_is_deterministic_and_in_range() {
        let p = params();
        let a = draw_eaglet_idx(&p, 7);
        let b = draw_eaglet_idx(&p, 7);
        assert_eq!(a, b);
        let c = draw_eaglet_idx(&p, 8);
        assert_ne!(a, c);
        if let HostTensor::I32(v, shape) = &a {
            assert_eq!(shape, &[p.rounds, p.subsample]);
            assert!(v.iter().all(|&x| (0..p.markers as i32).contains(&x)));
            // distinct within a round
            for r in 0..p.rounds {
                let mut round = v[r * p.subsample..(r + 1) * p.subsample].to_vec();
                round.sort_unstable();
                round.dedup();
                assert_eq!(round.len(), p.subsample);
            }
        } else {
            panic!("expected i32 tensor");
        }
    }

    #[test]
    fn netflix_idx_shape_and_range() {
        let p = params();
        let t = draw_netflix_idx(&p, p.s_lo, 3);
        if let HostTensor::I32(v, shape) = &t {
            assert_eq!(shape, &[p.s_lo]);
            assert!(v.iter().all(|&x| (0..p.ratings_cap as i32).contains(&x)));
        } else {
            panic!("expected i32 tensor");
        }
    }

    #[test]
    fn assemble_eaglet_pads_to_bucket() {
        let p = params();
        let d = EagletDataset::generate(
            &p,
            EagletConfig { families: 20, ..Default::default() },
        );
        // two ordinary families (ids 2,3 to dodge the outliers)
        let blocks = vec![d.encode_block(2), d.encode_block(3)];
        let rows: usize = blocks.iter().map(|b| b.units as usize).sum();
        let t = MapTask::assemble(&p, Workload::Eaglet, &blocks, 1).unwrap();
        assert_eq!(t.real_rows, rows);
        assert!(t.bucket >= rows);
        assert_eq!(t.inputs[0].shape(), &[t.bucket, p.markers, p.individuals]);
        // padding rows are zero
        if let HostTensor::F32(geno, _) = &t.inputs[0] {
            let m = p.markers * p.individuals;
            assert!(geno[rows * m..].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn assemble_netflix_rows_are_movies() {
        let p = params();
        let d = NetflixDataset::generate(
            &p,
            NetflixConfig { movies: 10, ..Default::default() },
        );
        let blocks: Vec<Block> = (0..5).map(|i| d.encode_block(i)).collect();
        let t =
            MapTask::assemble(&p, Workload::NetflixLo, &blocks, 9).unwrap();
        assert_eq!(t.real_rows, 5);
        assert_eq!(t.bucket, 16);
        assert_eq!(t.kind, "netflix_map_lo");
        assert_eq!(t.inputs[3].shape(), &[p.s_lo]);
    }

    #[test]
    fn seqaddr_idx_deterministic_and_in_range() {
        let p = params();
        let a = draw_seqaddr_idx(&p, 7);
        assert_eq!(a, draw_seqaddr_idx(&p, 7));
        assert_ne!(a, draw_seqaddr_idx(&p, 8));
        if let HostTensor::I32(v, shape) = &a {
            assert_eq!(shape, &[p.sa_rounds]);
            let hi = (p.sa_len - p.sa_window) as i32;
            assert!(v.iter().all(|&x| (0..=hi).contains(&x)));
        } else {
            panic!("expected i32 tensor");
        }
    }

    #[test]
    fn assemble_series_workloads() {
        use crate::data::seqaddr::{SeqAddrConfig, SeqAddrDataset};
        use crate::data::ssag::{SsagConfig, SsagDataset};
        let p = params();
        let d = SeqAddrDataset::generate(
            &p,
            SeqAddrConfig { series: 6, ..Default::default() },
        );
        let blocks: Vec<Block> = (0..5).map(|i| d.encode_block(i)).collect();
        let t =
            MapTask::assemble(&p, Workload::SeqAddr, &blocks, 9).unwrap();
        assert_eq!(t.kind, "seqaddr_map");
        assert_eq!(t.real_rows, 5);
        assert_eq!(t.bucket, 16);
        assert_eq!(t.inputs[0].shape(), &[t.bucket, p.sa_len]);
        assert_eq!(t.inputs[1].shape(), &[p.sa_rounds]);

        let d = SsagDataset::generate(
            &p,
            SsagConfig { series: 6, ..Default::default() },
        );
        let blocks: Vec<Block> = (0..3).map(|i| d.encode_block(i)).collect();
        let t = MapTask::assemble(&p, Workload::Ssag, &blocks, 9).unwrap();
        assert_eq!(t.kind, "ssag_map");
        assert_eq!(t.real_rows, 3);
        assert_eq!(t.bucket, 4);
        assert_eq!(t.inputs.len(), 1);
        assert_eq!(t.inputs[0].shape(), &[t.bucket, p.ssag_len]);
        // wrong-kind blocks are rejected, both directions
        assert!(MapTask::assemble(&p, Workload::SeqAddr, &blocks, 0)
            .is_err());
    }

    #[test]
    fn assemble_rejects_wrong_kind() {
        let p = params();
        let d = NetflixDataset::generate(
            &p,
            NetflixConfig { movies: 3, ..Default::default() },
        );
        let blocks = vec![d.encode_block(0)];
        assert!(MapTask::assemble(&p, Workload::Eaglet, &blocks, 0).is_err());
    }

    #[test]
    fn partial_discards_padding_rows() {
        let p = params();
        let task = MapTask {
            kind: "eaglet_map",
            real_rows: 2,
            bucket: 4,
            inputs: vec![],
        };
        // rows: [1..], [3..], then padding rows that must be ignored
        let mut out = vec![0.0f32; 4 * p.grid];
        out[..p.grid].iter_mut().for_each(|v| *v = 1.0);
        out[p.grid..2 * p.grid].iter_mut().for_each(|v| *v = 3.0);
        out[2 * p.grid..].iter_mut().for_each(|v| *v = 99.0);
        let partial = TaskPartial::from_map_output(&p, &task, &out).unwrap();
        match partial {
            TaskPartial::Eaglet { alod, weight } => {
                assert_eq!(weight, 2.0);
                assert!(alod.iter().all(|&v| (v - 2.0).abs() < 1e-6));
            }
            _ => panic!("wrong partial kind"),
        }
    }

    #[test]
    fn partial_size_mismatch_errors() {
        let p = params();
        let task = MapTask {
            kind: "eaglet_map",
            real_rows: 1,
            bucket: 1,
            inputs: vec![],
        };
        assert!(TaskPartial::from_map_output(&p, &task, &[0.0; 3]).is_err());
    }
}
