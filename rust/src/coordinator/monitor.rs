//! Optional task monitoring — the "BTS with monitoring" arm of §4.2.2.
//!
//! The thesis bolted Hadoop-style observability onto BTS to price it:
//! per-task metric records shipped to a central sink plus periodic
//! system snapshots, costing +21% startup on MB-sized jobs and +15%
//! runtime on GB-sized jobs. We implement the same structure — a
//! central, mutex-guarded sink that every task completion serializes a
//! JSON record into, and a per-slot registration handshake at startup —
//! and *measure* its cost rather than asserting the paper's constants
//! (EXPERIMENTS.md compares the two).

use std::sync::Mutex;

use crate::util::json::{num, obj, s, Json};

/// Central monitoring sink. One per job; shared by all workers.
#[derive(Default)]
pub struct MonitorSink {
    enabled: bool,
    records: Mutex<Vec<String>>,
}

impl MonitorSink {
    pub fn new(enabled: bool) -> Self {
        MonitorSink { enabled, records: Mutex::new(Vec::new()) }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Startup handshake: register a map slot with the central service
    /// (Hadoop's TaskTracker announces every slot before tasks launch).
    pub fn register_slot(&self, worker: usize, slots: usize) {
        if !self.enabled {
            return;
        }
        let rec = obj(vec![
            ("event", s("register")),
            ("worker", num(worker as f64)),
            ("slots", num(slots as f64)),
        ])
        .to_string_pretty();
        // Round-trip through the parser: the central service validates
        // what it displays (this is the work Hadoop's HTTP front end
        // does per heartbeat).
        let parsed = Json::parse(&rec).expect("self-made record parses");
        let _ = parsed.get("event");
        self.records.lock().unwrap().push(rec);
    }

    /// Per-task completion record (seq, timings, cache counters).
    pub fn record_task(
        &self,
        worker: usize,
        seq: usize,
        fetch_s: f64,
        exec_s: f64,
        bytes: usize,
    ) {
        if !self.enabled {
            return;
        }
        let rec = obj(vec![
            ("event", s("task")),
            ("worker", num(worker as f64)),
            ("seq", num(seq as f64)),
            ("fetch_s", num(fetch_s)),
            ("exec_s", num(exec_s)),
            ("bytes", num(bytes as f64)),
        ])
        .to_string_pretty();
        let parsed = Json::parse(&rec).expect("self-made record parses");
        let _ = parsed.get("seq");
        self.records.lock().unwrap().push(rec);
    }

    pub fn record_count(&self) -> usize {
        self.records.lock().unwrap().len()
    }

    /// Drain the collected records (the web-display path in Hadoop; the
    /// CLI's `--monitor-dump` path here).
    pub fn drain(&self) -> Vec<String> {
        std::mem::take(&mut *self.records.lock().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sink_records_nothing() {
        let m = MonitorSink::new(false);
        m.register_slot(0, 4);
        m.record_task(0, 1, 0.1, 0.2, 100);
        assert_eq!(m.record_count(), 0);
    }

    #[test]
    fn enabled_sink_collects_records() {
        let m = MonitorSink::new(true);
        m.register_slot(0, 4);
        m.record_task(0, 1, 0.1, 0.2, 100);
        m.record_task(1, 2, 0.1, 0.2, 100);
        assert_eq!(m.record_count(), 3);
        let recs = m.drain();
        assert_eq!(recs.len(), 3);
        assert_eq!(m.record_count(), 0);
        assert!(recs[0].contains("register"));
        assert!(recs[1].contains("\"seq\""));
    }
}
