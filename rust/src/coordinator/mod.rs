//! The BTS coordinator: job lifecycle around the two-step scheduler and
//! the replicated data layer, executing map/reduce statistics through
//! the PJRT runtime.
//!
//! Layout:
//! - [`assemble`]  — dfs blocks → padded `HostTensor` batches; per-task
//!   subsample index drawing (the L3 side of the subsampling contract).
//! - [`reduce`]    — artifact-based reduce tree + scalar finalization.
//! - [`job`]       — master/worker execution of one map-reduce job.
//! - [`recovery`]  — job-level recovery: f_w analysis (§3.3), failure
//!   injection, restart-until-done wrapper.
//! - [`monitor`]   — optional task monitoring (the "BTS with
//!   monitoring" experiment, §4.2.2).
//!
//! [`job`] is the scoped-thread engine (workers pull from a shared
//! scheduler and execute through the PJRT pool); the channel-based
//! leader/worker executor with pluggable backends lives in
//! [`crate::exec`] and reuses [`assemble`] and [`reduce`] unchanged.

pub mod assemble;
pub mod job;
pub mod monitor;
pub mod recovery;
pub mod reduce;

pub use assemble::{draw_eaglet_idx, draw_netflix_idx, MapTask, TaskPartial};
pub use job::{run_job, JobConfig, JobOutput, JobResult};
pub use monitor::MonitorSink;
pub use recovery::{expected_failures, run_with_recovery, FailurePlan, RecoveryParams};
pub use reduce::{finalize_netflix, reduce_eaglet, reduce_netflix, NetflixStats};
