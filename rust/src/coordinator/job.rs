//! One map-reduce job, end to end: stage → schedule → map → shuffle →
//! reduce → finalize.
//!
//! The master stages sample blocks into the replicated store, packs
//! tasks under the configured sizing policy, and runs the two-step
//! scheduler. Worker threads model BashReduce map slots: each owns a
//! PJRT runtime (compiled-executable cache and all) plus a prefetcher,
//! claims tasks, fetches and decodes blocks, executes the map artifact,
//! and ships its partial to the master over the shuffle channel. While
//! the map phase runs, the master drives the adaptive replication
//! controller off the scheduler's feedback EWMAs. The reduce tree runs
//! on the master through the same compiled artifacts.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use super::assemble::{MapTask, TaskPartial};
use super::monitor::MonitorSink;
use super::recovery::FailurePlan;
use super::reduce::{
    finalize_netflix, finalize_seqaddr, reduce_eaglet, reduce_netflix,
    reduce_seqaddr, reduce_ssag, NetflixStats,
};
use crate::data::{BlockId, Dataset, Workload};
use crate::data::block::Block;
use crate::dfs::{
    initial_data_nodes, ControllerState, Dfs, LatencyModel, Prefetcher,
    ReplicationPolicy,
};
use crate::error::{Error, Result};
use crate::kneepoint::TaskSizing;
use crate::metrics::{JobMetrics, JobReport, Timer};
use crate::runtime::{ExecutorPool, Manifest};
use crate::scheduler::{SchedConfig, SchedSnapshot, TaskSpec, TwoStepScheduler};

/// Everything a job run needs beyond the dataset and the artifacts.
#[derive(Debug, Clone)]
pub struct JobConfig {
    pub sizing: TaskSizing,
    /// Worker threads (map slots).
    pub workers: usize,
    /// Data nodes backing the replicated store.
    pub data_nodes: usize,
    pub latency: LatencyModel,
    pub replication: ReplicationPolicy,
    /// Drive the replication factor from the fetch/exec feedback loop.
    pub adaptive_rf: bool,
    pub sched: SchedConfig,
    /// Upper bound on the per-worker prefetch depth k.
    pub prefetch_k: usize,
    /// Enable the central monitoring sink (the §4.2.2 experiment).
    pub monitoring: bool,
    /// Job seed: drives every task's subsample indices.
    pub seed: u64,
    /// Injected failure (recovery tests / §3.3 experiments).
    pub failure: Option<FailurePlan>,
    /// Attempt number, set by `run_with_recovery` (1-based).
    pub attempt: u32,
    /// Label for reports ("bts", "blt", "btt", ...).
    pub platform: String,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            sizing: TaskSizing::Kneepoint(256 * 1024),
            workers: 4,
            data_nodes: 4,
            latency: LatencyModel::none(),
            replication: ReplicationPolicy::default(),
            adaptive_rf: true,
            sched: SchedConfig::default(),
            prefetch_k: 8,
            monitoring: false,
            seed: 0xB75,
            failure: None,
            attempt: 1,
            platform: "bts".into(),
        }
    }
}

///// The job's statistical output. Two shapes cover all four workloads:
/// SSAG jobs finalize as `Eaglet` (a weighted mean curve — the
/// variance ladder), SeqAddr jobs as `Netflix` (per-key mean/CI —
/// keyed by address bin instead of month).
#[derive(Debug, Clone, PartialEq)]
pub enum JobOutput {
    /// Final weighted mean curve (EAGLET ALOD grid / SSAG variance
    /// ladder) + total row weight.
    Eaglet { alod: Vec<f32>, weight: f32 },
    Netflix(NetflixStats),
}

impl JobOutput {
    /// The statistic as deterministic JSON — what equivalence gates
    /// (the CI transport/suite smokes, `bts exec --out-json`) diff
    /// between runs: bit-identical outputs ⇒ byte-identical subtrees.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{arr, num, obj, s};
        match self {
            JobOutput::Eaglet { alod, weight } => obj(vec![
                ("workload", s("eaglet")),
                ("weight", num(*weight as f64)),
                (
                    "alod",
                    arr(alod.iter().map(|&v| num(v as f64)).collect()),
                ),
            ]),
            JobOutput::Netflix(stats) => obj(vec![
                ("workload", s("netflix")),
                (
                    "mean",
                    arr(stats.mean.iter().map(|&v| num(v)).collect()),
                ),
                (
                    "ci_half",
                    arr(stats.ci_half.iter().map(|&v| num(v)).collect()),
                ),
                (
                    "count",
                    arr(stats.count.iter().map(|&v| num(v)).collect()),
                ),
            ]),
        }
    }
}

#[derive(Debug, Clone)]
pub struct JobResult {
    pub output: JobOutput,
    pub report: JobReport,
    pub sched: SchedSnapshot,
    /// Replication-factor trajectory (initial → final decisions).
    pub rf_trajectory: Vec<usize>,
    pub monitor_records: usize,
}

/// Run one job attempt. Worker failure (injected or real) surfaces as
/// `Err` — job-level recovery (`run_with_recovery`) restarts the whole
/// job, never a task.
pub fn run_job(
    dataset: &dyn Dataset,
    manifest: Arc<Manifest>,
    cfg: &JobConfig,
) -> Result<JobResult> {
    if cfg.workers == 0 {
        return Err(Error::Config("job needs at least one worker".into()));
    }
    let p = manifest.params.clone();
    let workload = dataset.workload();
    let total_t = Timer::start();
    let monitor = Arc::new(MonitorSink::new(cfg.monitoring));

    // ---- startup: pack, stage, register --------------------------------
    let metas = dataset.metas();
    if metas.is_empty() {
        return Err(Error::Data("empty dataset".into()));
    }
    let tasks = crate::kneepoint::pack(metas, cfg.sizing);
    let n_tasks = tasks.len();
    let mean_task_bytes =
        tasks.iter().map(|t| t.bytes).sum::<usize>() / n_tasks.max(1);
    let rf0 = initial_data_nodes(
        cfg.workers,
        mean_task_bytes,
        0.05, // pre-probe guess; the controller corrects it online
        &cfg.replication,
    )
    .min(cfg.data_nodes);
    let dfs = Dfs::new(cfg.data_nodes, rf0, cfg.latency.clone());
    let kind = crate::data::block::kind_of(workload);
    for meta in metas {
        let block = dataset.encode_block(meta.id);
        let key = BlockId { kind, sample: meta.id }.key();
        dfs.put(&key, Arc::new(block.encode()));
    }
    let specs: Vec<TaskSpec> = tasks
        .into_iter()
        .map(|t| TaskSpec::new(t, workload, cfg.seed))
        .collect();
    let sched = TwoStepScheduler::new(specs, cfg.workers, cfg.sched.clone());
    for w in 0..cfg.workers {
        monitor.register_slot(w, cfg.workers);
    }
    let startup_s = total_t.secs();

    // ---- map phase ------------------------------------------------------
    let map_t = Timer::start();
    let metrics = JobMetrics::new();
    let (tx, rx) = mpsc::channel::<(usize, TaskPartial)>();
    let failed = Arc::new(AtomicBool::new(false));
    let mut partials: Vec<Option<TaskPartial>> = vec![None; n_tasks];
    let mut rf_trajectory = vec![dfs.replication_factor()];
    let mut worker_err: Option<Error> = None;

    std::thread::scope(|sc| {
        let mut handles = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let tx = tx.clone();
            let sched = &sched;
            let dfs = dfs.clone();
            let manifest = manifest.clone();
            let monitor = monitor.clone();
            let metrics = &metrics;
            let failed = failed.clone();
            let cfg = &*cfg;
            handles.push(sc.spawn(move || {
                worker_loop(
                    w, cfg, sched, dfs, manifest, monitor, metrics, failed,
                    tx,
                )
            }));
        }
        drop(tx);

        // Master loop: collect partials; drive the replication controller.
        let mut ctrl = ControllerState::default();
        loop {
            match rx.recv_timeout(Duration::from_millis(10)) {
                Ok((seq, partial)) => partials[seq] = Some(partial),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            if cfg.adaptive_rf {
                if let (Some(fetch), Some(exec)) =
                    (sched.observed_fetch_s(), sched.observed_exec_s())
                {
                    let cur = dfs.replication_factor();
                    let next = crate::dfs::decide(
                        &cfg.replication,
                        &mut ctrl,
                        fetch,
                        exec,
                        cur,
                    );
                    if next != cur {
                        dfs.set_replication_factor(next);
                        rf_trajectory.push(next);
                    }
                }
            }
        }
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => worker_err = Some(e),
                Err(_) => {
                    worker_err =
                        Some(Error::Scheduler("worker panicked".into()))
                }
            }
        }
    });
    if let Some(e) = worker_err {
        return Err(e);
    }
    let map_s = map_t.secs();

    // ---- shuffle sanity + reduce ---------------------------------------
    let collected: Vec<TaskPartial> = partials
        .into_iter()
        .enumerate()
        .map(|(seq, p)| {
            p.ok_or_else(|| {
                Error::Scheduler(format!("task {seq} produced no partial"))
            })
        })
        .collect::<Result<_>>()?;
    let reduce_t = Timer::start();
    let pool = ExecutorPool::global(&manifest)?;
    let weighted = |collected: Vec<TaskPartial>| -> Vec<(Vec<f32>, f32)> {
        collected
            .into_iter()
            .map(|pt| match pt {
                TaskPartial::Eaglet { alod, weight } => (alod, weight),
                _ => unreachable!("workload-homogeneous job"),
            })
            .collect()
    };
    let summed = |collected: Vec<TaskPartial>| -> Vec<Vec<f32>> {
        collected
            .into_iter()
            .map(|pt| match pt {
                TaskPartial::Netflix { stats } => stats,
                _ => unreachable!("workload-homogeneous job"),
            })
            .collect()
    };
    let output = match workload {
        Workload::Eaglet => {
            let (alod, weight) =
                reduce_eaglet(pool.as_ref(), &p, weighted(collected))?;
            JobOutput::Eaglet { alod, weight }
        }
        Workload::Ssag => {
            let (alod, weight) =
                reduce_ssag(pool.as_ref(), &p, weighted(collected))?;
            JobOutput::Eaglet { alod, weight }
        }
        Workload::NetflixHi | Workload::NetflixLo => {
            let stats =
                reduce_netflix(pool.as_ref(), &p, summed(collected))?;
            JobOutput::Netflix(finalize_netflix(&p, &stats)?)
        }
        Workload::SeqAddr => {
            let stats =
                reduce_seqaddr(pool.as_ref(), &p, summed(collected))?;
            JobOutput::Netflix(finalize_seqaddr(&p, &stats)?)
        }
    };
    let reduce_s = reduce_t.secs();

    let report = JobReport {
        workload: workload.name().to_string(),
        platform: cfg.platform.clone(),
        tasks: n_tasks,
        samples: metas.len(),
        input_bytes: dataset.total_bytes(),
        startup_s,
        map_s,
        reduce_s,
        total_s: total_t.secs(),
        task_exec: metrics.exec_summary(),
        task_fetch: metrics.fetch_summary(),
        // the coordinator engine has no leader-side dispatch registry
        // (workers pull from a shared scheduler), so turnaround
        // mirrors exec and speculation counters stay zero
        task_turnaround: metrics.exec_summary(),
        speculated: 0,
        won_by_clone: 0,
        // the coordinator engine reduces on the leader only — no
        // executed shuffle, so these stay at their r=1 identities
        reduce_tasks: 1,
        shuffle_bytes: 0,
        shuffle_imbalance: 1.0,
        reduce_turnaround: crate::util::stats::summarize(&[0.0]),
        prefetch_hit_rate: metrics.hit_rate(),
        // the coordinator engine predates the cache layer; its store
        // runs uncached, so the rate is definitionally zero
        cache_hit_rate: 0.0,
        final_rf: dfs.replication_factor(),
        restarts: cfg.attempt - 1,
        // single-process engine: no wire, no frames
        frames_sent: 0,
        frames_batched: 0,
        wire_bytes: 0,
        blocks_zero_copy: 0,
    };
    Ok(JobResult {
        output,
        report,
        sched: sched.snapshot(),
        rf_trajectory,
        monitor_records: monitor.record_count(),
    })
}

/// One worker (map slot): claim → prefetch → fetch → assemble → execute
/// → emit partial. Owns a PJRT runtime and a prefetcher for its lifetime.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    w: usize,
    cfg: &JobConfig,
    sched: &TwoStepScheduler,
    dfs: Arc<Dfs>,
    manifest: Arc<Manifest>,
    monitor: Arc<MonitorSink>,
    metrics: &JobMetrics,
    failed: Arc<AtomicBool>,
    tx: mpsc::Sender<(usize, TaskPartial)>,
) -> Result<()> {
    let p = manifest.params.clone();
    let pool = ExecutorPool::global(&manifest)?;
    let mut pf = Prefetcher::new(dfs, cfg.prefetch_k);
    // Small claimed-task lookahead so the prefetcher has keys to pump
    // ("while a task is being processed, data required for the next k
    // tasks are pre-fetched").
    let mut lookahead: std::collections::VecDeque<TaskSpec> =
        std::collections::VecDeque::new();
    let mut done: u64 = 0;
    loop {
        if failed.load(Ordering::Relaxed) {
            // Another worker died: abandon the attempt promptly (the
            // whole job restarts anyway — that is job-level recovery).
            return Ok(());
        }
        // Top up the lookahead to the current prefetch depth.
        let want = pf.depth().max(1);
        while lookahead.len() < want {
            match sched.next(w) {
                Some(spec) => {
                    let kind = crate::data::block::kind_of(spec.workload);
                    pf.enqueue(spec.task.sample_ids.iter().map(|&id| {
                        BlockId { kind, sample: id }.key()
                    }));
                    lookahead.push_back(spec);
                }
                None => break,
            }
        }
        let Some(spec) = lookahead.pop_front() else {
            return Ok(());
        };
        pf.pump()?;

        // Fetch + decode this task's blocks.
        let fetch_t = Timer::start();
        let kind = crate::data::block::kind_of(spec.workload);
        let mut blocks = Vec::with_capacity(spec.task.sample_ids.len());
        for &id in &spec.task.sample_ids {
            let key = BlockId { kind, sample: id }.key();
            let bytes = pf.take(&key)?;
            blocks.push(Block::decode(&bytes)?);
        }
        let fetch_s = fetch_t.secs();

        // Execute (possibly in slices, for large tasks).
        let exec_t = Timer::start();
        let slices = MapTask::slices(&p, spec.workload, &blocks, spec.seed)?;
        let mut slice_partials = Vec::with_capacity(slices.len());
        for slice in slices {
            let entry = manifest
                .entry(slice.kind, slice.bucket)
                .ok_or_else(|| {
                    Error::Artifact(format!(
                        "no entry {} bucket {}",
                        slice.kind, slice.bucket
                    ))
                })?;
            // Hand the inputs to the executor pool by value (they are
            // consumed by the transfer anyway); keep a shell with the
            // row bookkeeping for output interpretation.
            let shell = MapTask {
                kind: slice.kind,
                real_rows: slice.real_rows,
                bucket: slice.bucket,
                inputs: Vec::new(),
            };
            let out = pool.execute(entry, slice.inputs)?;
            slice_partials.push(TaskPartial::from_map_output(
                &p, &shell, &out[0],
            )?);
        }
        let partial = TaskPartial::merge(slice_partials)?;
        let exec_s = exec_t.secs();

        pf.observe_exec(exec_s);
        metrics.observe_fetch(fetch_s);
        metrics.observe_exec(exec_s);
        metrics
            .prefetch_hits
            .store(pf.hits, Ordering::Relaxed);
        metrics
            .prefetch_misses
            .store(pf.misses, Ordering::Relaxed);
        monitor.record_task(w, spec.task.seq, fetch_s, exec_s, spec.task.bytes);
        sched.report(w, fetch_s, exec_s);
        // Shuffle: deliver the partial. A dropped receiver means the
        // master already gave up on this attempt.
        let _ = tx.send((spec.task.seq, partial));
        done += 1;

        if let Some(plan) = cfg.failure {
            if plan.worker == w
                && cfg.attempt == plan.on_attempt
                && done >= plan.after_tasks
            {
                failed.store(true, Ordering::Relaxed);
                return Err(Error::Scheduler(format!(
                    "injected node failure on worker {w} after {done} tasks"
                )));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let c = JobConfig::default();
        assert!(c.workers > 0);
        assert!(c.data_nodes > 0);
        assert_eq!(c.attempt, 1);
        assert!(c.failure.is_none());
    }

    // Full job runs (they need compiled artifacts) live in
    // rust/tests/integration_engine.rs and integration_recovery.rs.
}
