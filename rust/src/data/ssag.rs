//! Synthetic series dataset for scalable-subsampling aggregation
//! (Politis 2021): each sample is one stationary-but-correlated
//! series of `ssag_len` points. The kernel computes the variance of
//! non-overlapping block means at a ladder of block sizes, so the
//! generator gives each series its own AR(1) correlation — the
//! variance curve's decay rate genuinely differs per sample.

use super::block::{Block, BlockId, KIND_SSAG};
use super::params::ModelParams;
use super::{Dataset, SampleMeta, Workload};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SsagConfig {
    pub series: usize,
    pub seed: u64,
}

impl Default for SsagConfig {
    fn default() -> Self {
        SsagConfig { series: 256, seed: 0x55A6_0001 }
    }
}

/// One series sample.
#[derive(Debug, Clone)]
pub struct Series {
    pub id: u64,
    pub points: Vec<f32>, // [ssag_len]
}

#[derive(Debug, Clone)]
pub struct SsagDataset {
    pub params: ModelParams,
    pub config: SsagConfig,
    pub series: Vec<Series>,
    metas: Vec<SampleMeta>,
}

impl SsagDataset {
    pub fn generate(params: &ModelParams, config: SsagConfig) -> Self {
        let mut rng = Rng::new(config.seed);
        let len = params.ssag_len;
        let mut series = Vec::with_capacity(config.series);
        for id in 0..config.series as u64 {
            let mut r = rng.fork(id);
            let mean = 2.0 * r.f64() - 1.0;
            let rho = 0.9 * r.f64(); // per-series correlation
            let sigma = 0.5 + r.f64();
            let mut prev = 0.0f64;
            let mut points = Vec::with_capacity(len);
            for _ in 0..len {
                prev = rho * prev + r.normal_ms(0.0, sigma);
                points.push((mean + prev) as f32);
            }
            series.push(Series { id, points });
        }
        let bytes = len * 4;
        let metas = series
            .iter()
            .map(|s| SampleMeta { id: s.id, bytes, units: 1 })
            .collect();
        SsagDataset { params: params.clone(), config, series, metas }
    }

    /// Scale by appending series (job-size sweeps).
    pub fn scaled_to(&self, target_bytes: usize) -> SsagDataset {
        let need = target_bytes.div_ceil(self.params.ssag_len * 4);
        if need <= self.series.len() {
            return self.clone();
        }
        let config = SsagConfig { series: need, seed: self.config.seed };
        SsagDataset::generate(&self.params, config)
    }

    pub fn sample(&self, id: u64) -> Option<&Series> {
        self.series.get(id as usize).filter(|s| s.id == id)
    }
}

impl Dataset for SsagDataset {
    fn workload(&self) -> Workload {
        Workload::Ssag
    }

    fn metas(&self) -> &[SampleMeta] {
        &self.metas
    }

    fn encode_block(&self, id: u64) -> Block {
        let s = self.sample(id).expect("unknown series id");
        Block {
            id: BlockId { kind: KIND_SSAG, sample: id },
            units: 1,
            payload: s.points.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SsagDataset {
        SsagDataset::generate(
            &ModelParams::default(),
            SsagConfig { series: 32, ..Default::default() },
        )
    }

    #[test]
    fn deterministic() {
        assert_eq!(small().series[7].points, small().series[7].points);
    }

    #[test]
    fn block_round_trip_and_meta_bytes() {
        let d = small();
        let b = d.encode_block(3);
        assert_eq!(Block::decode(&b.encode()).unwrap(), b);
        assert_eq!(b.payload.len(), d.params.ssag_len);
        assert_eq!(b.payload.len() * 4, d.metas()[3].bytes);
        assert_eq!(b.units, 1);
    }

    #[test]
    fn scaled_to_is_prefix_stable() {
        let d = small();
        let s = d.scaled_to(d.total_bytes() * 4);
        assert!(s.series.len() >= d.series.len() * 4);
        assert_eq!(s.series[5].points, d.series[5].points);
    }

    #[test]
    fn block_size_ladder_fits() {
        // the largest ladder rung must still give >= 2 blocks, or the
        // block-means variance is degenerate
        let p = ModelParams::default();
        let b_max = p.ssag_b * p.ssag_points;
        assert!(p.ssag_len / b_max >= 2, "{} / {}", p.ssag_len, b_max);
    }
}
