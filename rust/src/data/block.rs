//! Block wire format: how one sample's tensors travel through the dfs.
//!
//! Layout (little-endian):
//!   magic  u32 = 0x42545342 ("BSTB")
//!   kind   u32   (0 = eaglet family, 1 = netflix movie,
//!                 2 = seqaddr series, 3 = ssag series)
//!   id     u64
//!   units  u32   (eaglet: chunk count; netflix: 1)
//!   nf32   u32   number of f32 payload words
//!   payload [nf32 × f32]
//!
//! EAGLET payload: per chunk, geno[M*I] then pos[M].
//! Netflix payload: vals[N], months[N], mask[N].
//! SeqAddr payload: series[sa_len]. Ssag payload: series[ssag_len].

use crate::error::{Error, Result};

use super::Workload;

pub const MAGIC: u32 = 0x4254_5342;
pub const KIND_EAGLET: u32 = 0;
pub const KIND_NETFLIX: u32 = 1;
pub const KIND_SEQADDR: u32 = 2;
pub const KIND_SSAG: u32 = 3;

/// Block kind for a workload's samples. Both Netflix confidence
/// levels share one dataset, hence one kind.
pub fn kind_of(workload: Workload) -> u32 {
    match workload {
        Workload::Eaglet => KIND_EAGLET,
        Workload::NetflixHi | Workload::NetflixLo => KIND_NETFLIX,
        Workload::SeqAddr => KIND_SEQADDR,
        Workload::Ssag => KIND_SSAG,
    }
}

/// Store key for one sample's block under a job namespace (`""` for
/// solo runs; [`crate::dfs::job_ns`] prefixes for multiplexed jobs).
/// Shared by the executors, the serve pool, and the scheduler's
/// cache-affinity scoring so key construction can never drift.
pub fn block_key(ns: &str, workload: Workload, sample: u64) -> String {
    format!("{ns}{}", BlockId { kind: kind_of(workload), sample }.key())
}

/// Identifies one sample's block in the store.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId {
    pub kind: u32,
    pub sample: u64,
}

impl BlockId {
    pub fn key(&self) -> String {
        format!("b{}:{}", self.kind, self.sample)
    }
}

/// A decoded block.
#[derive(Debug, Clone, PartialEq)]
pub struct Block {
    pub id: BlockId,
    pub units: u32,
    pub payload: Vec<f32>,
}

impl Block {
    pub fn encode(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(24 + self.payload.len() * 4);
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&self.id.kind.to_le_bytes());
        out.extend_from_slice(&self.id.sample.to_le_bytes());
        out.extend_from_slice(&self.units.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        for v in &self.payload {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<Block> {
        if bytes.len() < 24 {
            return Err(Error::Data("block too short".into()));
        }
        let rd_u32 = |o: usize| {
            u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap())
        };
        if rd_u32(0) != MAGIC {
            return Err(Error::Data("bad block magic".into()));
        }
        let kind = rd_u32(4);
        let sample = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
        let units = rd_u32(16);
        let nf32 = rd_u32(20) as usize;
        if bytes.len() != 24 + nf32 * 4 {
            return Err(Error::Data(format!(
                "block length {} != expected {}",
                bytes.len(),
                24 + nf32 * 4
            )));
        }
        let mut payload = Vec::with_capacity(nf32);
        for i in 0..nf32 {
            let o = 24 + i * 4;
            payload.push(f32::from_le_bytes(
                bytes[o..o + 4].try_into().unwrap(),
            ));
        }
        Ok(Block { id: BlockId { kind, sample }, units, payload })
    }

    pub fn byte_len(&self) -> usize {
        24 + self.payload.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let n = rng.below(512) as usize;
            let b = Block {
                id: BlockId {
                    kind: rng.below(2) as u32,
                    sample: rng.next_u64(),
                },
                units: rng.below(30) as u32 + 1,
                payload: (0..n).map(|_| rng.f32()).collect(),
            };
            let enc = b.encode();
            assert_eq!(enc.len(), b.byte_len());
            assert_eq!(Block::decode(&enc).unwrap(), b);
        }
    }

    #[test]
    fn rejects_corruption() {
        let b = Block {
            id: BlockId { kind: 0, sample: 7 },
            units: 2,
            payload: vec![1.0, 2.0],
        };
        let mut enc = b.encode();
        assert!(Block::decode(&enc[..10]).is_err()); // truncated header
        enc[0] ^= 0xFF; // bad magic
        assert!(Block::decode(&enc).is_err());
        let enc2 = b.encode();
        assert!(Block::decode(&enc2[..enc2.len() - 1]).is_err()); // short
    }

    #[test]
    fn key_is_unique_per_sample() {
        let a = BlockId { kind: 0, sample: 1 }.key();
        let b = BlockId { kind: 1, sample: 1 }.key();
        let c = BlockId { kind: 0, sample: 2 }.key();
        assert_ne!(a, b);
        assert_ne!(a, c);
    }
}
