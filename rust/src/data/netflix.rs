//! Synthetic Netflix-ratings dataset: per-movie rating tuples.
//!
//! Stands in for the Netflix Prize data (§4.1.1.2: tuples of
//! (date, user, rating) per movie; the workload estimates typical user
//! ratings by month, trading confidence for speed by subsample size).
//! Ratings-per-movie follows a power law (blockbusters vs long tail);
//! each movie has a latent per-month quality curve so subsampled monthly
//! means converge to something real.

use super::block::{Block, BlockId, KIND_NETFLIX};
use super::params::ModelParams;
use super::{Dataset, SampleMeta, Workload};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct NetflixConfig {
    pub movies: usize,
    pub seed: u64,
    /// Power-law exponent for ratings-per-movie.
    pub tail_alpha: f64,
    /// High (S_HI) vs low (S_LO) confidence subsampling.
    pub high_confidence: bool,
}

impl Default for NetflixConfig {
    fn default() -> Self {
        NetflixConfig {
            movies: 256,
            seed: 0x0EF11C5,
            tail_alpha: 1.3,
            high_confidence: false,
        }
    }
}

/// One movie sample, padded to `ratings_cap`.
#[derive(Debug, Clone)]
pub struct Movie {
    pub id: u64,
    pub n_ratings: u32,
    pub vals: Vec<f32>,   // [cap]
    pub months: Vec<f32>, // [cap], 0..12
    pub mask: Vec<f32>,   // [cap], 1.0 valid
}

#[derive(Debug, Clone)]
pub struct NetflixDataset {
    pub params: ModelParams,
    pub config: NetflixConfig,
    pub movies: Vec<Movie>,
    metas: Vec<SampleMeta>,
}

impl NetflixDataset {
    pub fn generate(params: &ModelParams, config: NetflixConfig) -> Self {
        let mut rng = Rng::new(config.seed);
        let cap = params.ratings_cap;
        let mut movies = Vec::with_capacity(config.movies);
        for id in 0..config.movies as u64 {
            let mut r = rng.fork(id);
            // ratings count: power law clamped to [8, cap]
            let raw = 8.0 * r.pareto(config.tail_alpha);
            let n = (raw.round() as usize).clamp(8, cap) as u32;
            // latent monthly quality curve around a base rating
            let base = 2.0 + 2.0 * r.f64();
            let seasonal: Vec<f64> = (0..params.months)
                .map(|_| r.normal_ms(0.0, 0.4))
                .collect();
            let mut vals = vec![0.0f32; cap];
            let mut months = vec![0.0f32; cap];
            let mut mask = vec![0.0f32; cap];
            for j in 0..n as usize {
                let mo = r.below(params.months as u64) as usize;
                let v = (base + seasonal[mo] + r.normal_ms(0.0, 0.8))
                    .clamp(1.0, 5.0);
                vals[j] = v as f32;
                months[j] = mo as f32;
                mask[j] = 1.0;
            }
            movies.push(Movie { id, n_ratings: n, vals, months, mask });
        }
        let bytes = params.movie_bytes();
        let metas = movies
            .iter()
            .map(|m| SampleMeta { id: m.id, bytes, units: 1 })
            .collect();
        NetflixDataset { params: params.clone(), config, movies, metas }
    }

    /// Scale by appending movies (job-size sweeps, Fig 15).
    pub fn scaled_to(&self, target_bytes: usize) -> NetflixDataset {
        let need = target_bytes.div_ceil(self.params.movie_bytes());
        if need <= self.movies.len() {
            return self.clone();
        }
        let config = NetflixConfig {
            movies: need,
            seed: self.config.seed,
            ..self.config.clone()
        };
        NetflixDataset::generate(&self.params, config)
    }

    pub fn movie(&self, id: u64) -> Option<&Movie> {
        self.movies.get(id as usize).filter(|m| m.id == id)
    }
}

impl Dataset for NetflixDataset {
    fn workload(&self) -> Workload {
        if self.config.high_confidence {
            Workload::NetflixHi
        } else {
            Workload::NetflixLo
        }
    }

    fn metas(&self) -> &[SampleMeta] {
        &self.metas
    }

    fn encode_block(&self, id: u64) -> Block {
        let m = self.movie(id).expect("unknown movie id");
        let mut payload =
            Vec::with_capacity(3 * self.params.ratings_cap);
        payload.extend_from_slice(&m.vals);
        payload.extend_from_slice(&m.months);
        payload.extend_from_slice(&m.mask);
        Block {
            id: BlockId { kind: KIND_NETFLIX, sample: id },
            units: 1,
            payload,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(hi: bool) -> NetflixDataset {
        NetflixDataset::generate(
            &ModelParams::default(),
            NetflixConfig {
                movies: 64,
                high_confidence: hi,
                ..Default::default()
            },
        )
    }

    #[test]
    fn deterministic() {
        assert_eq!(small(false).movies[9].vals, small(false).movies[9].vals);
    }

    #[test]
    fn ratings_within_bounds() {
        let d = small(false);
        for m in &d.movies {
            assert!(m.n_ratings >= 8);
            assert!(m.n_ratings as usize <= d.params.ratings_cap);
            for j in 0..m.n_ratings as usize {
                assert!(m.mask[j] == 1.0);
                assert!((1.0..=5.0).contains(&m.vals[j]));
                assert!((0.0..12.0).contains(&m.months[j]));
            }
            // padding is masked out
            for j in m.n_ratings as usize..d.params.ratings_cap {
                assert_eq!(m.mask[j], 0.0);
            }
        }
    }

    #[test]
    fn power_law_tail() {
        let d = NetflixDataset::generate(
            &ModelParams::default(),
            NetflixConfig { movies: 2000, ..Default::default() },
        );
        let counts: Vec<u32> = d.movies.iter().map(|m| m.n_ratings).collect();
        let capped = counts
            .iter()
            .filter(|&&c| c as usize == d.params.ratings_cap)
            .count();
        let small = counts.iter().filter(|&&c| c < 16).count();
        assert!(capped > 10, "expected some blockbusters, got {capped}");
        assert!(small > 200, "expected a long tail, got {small}");
    }

    #[test]
    fn confidence_sets_workload() {
        assert_eq!(small(true).workload(), Workload::NetflixHi);
        assert_eq!(small(false).workload(), Workload::NetflixLo);
    }

    #[test]
    fn block_round_trip_and_meta_bytes() {
        let d = small(false);
        let b = d.encode_block(3);
        assert_eq!(Block::decode(&b.encode()).unwrap(), b);
        assert_eq!(b.payload.len() * 4, d.metas()[3].bytes);
        assert_eq!(b.units, 1);
    }

    #[test]
    fn scaled_to_adds_movies() {
        let d = small(false);
        let s = d.scaled_to(d.total_bytes() * 4);
        assert!(s.movies.len() >= d.movies.len() * 4);
        // prefix is identical (same seed, same per-movie fork)
        assert_eq!(s.movies[5].vals, d.movies[5].vals);
    }
}
