//! Compiled model parameters — the rust mirror of python/compile/shapes.py.
//!
//! The defaults below MUST match shapes.py; at startup the runtime parses
//! artifacts/manifest.json and overrides them, so a drift between the two
//! sides is caught the moment shapes disagree (`Manifest::validate`).

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelParams {
    pub markers: usize,      // M: SNP markers per EAGLET chunk
    pub individuals: usize,  // I
    pub subsample: usize,    // S: markers per subsample round
    pub rounds: usize,       // R
    pub grid: usize,         // G: LOD grid points
    pub bandwidth: f64,
    pub ratings_cap: usize,  // N: padded ratings per movie
    pub months: usize,
    pub s_hi: usize,
    pub s_lo: usize,
    pub stat_fields: usize,
    pub buckets: Vec<usize>, // compiled samples-per-task buckets
    pub reduce_fan: usize,   // K: parts per reduce call
    pub chunk_bytes: usize,  // bytes per EAGLET chunk in the data layer
    // Sequential-addressing subsampling (Pan et al. 2021): windowed
    // means over a series of sa_len points, start offsets binned into
    // sa_bins address buckets.
    pub sa_len: usize,
    pub sa_window: usize,
    pub sa_bins: usize,
    pub sa_rounds: usize,
    // Scalable-subsampling aggregation (Politis 2021): variance of
    // non-overlapping block means at block sizes ssag_b * (1..=points).
    pub ssag_len: usize,
    pub ssag_b: usize,
    pub ssag_points: usize,
}

impl Default for ModelParams {
    fn default() -> Self {
        ModelParams {
            markers: 64,
            individuals: 8,
            subsample: 16,
            rounds: 8,
            grid: 32,
            bandwidth: 0.15,
            ratings_cap: 256,
            months: 12,
            s_hi: 128,
            s_lo: 16,
            stat_fields: 3,
            buckets: vec![1, 4, 16, 64],
            reduce_fan: 16,
            chunk_bytes: 64 * 8 * 4 + 64 * 4,
            sa_len: 512,
            sa_window: 32,
            sa_bins: 16,
            sa_rounds: 8,
            ssag_len: 256,
            ssag_b: 8,
            ssag_points: 8,
        }
    }
}

impl ModelParams {
    /// Parse the `params` block of artifacts/manifest.json.
    ///
    /// The seqaddr/ssag fields are optional (older manifests predate
    /// them) and fall back to the compiled defaults.
    pub fn from_json(j: &Json) -> crate::error::Result<Self> {
        let d = ModelParams::default();
        let opt = |k: &str, fallback: usize| {
            j.get(k).and_then(Json::as_usize).unwrap_or(fallback)
        };
        Ok(ModelParams {
            markers: j.req_usize("markers")?,
            individuals: j.req_usize("individuals")?,
            subsample: j.req_usize("subsample")?,
            rounds: j.req_usize("rounds")?,
            grid: j.req_usize("grid")?,
            bandwidth: j.req_f64("bandwidth")?,
            ratings_cap: j.req_usize("ratings_cap")?,
            months: j.req_usize("months")?,
            s_hi: j.req_usize("s_hi")?,
            s_lo: j.req_usize("s_lo")?,
            stat_fields: j.req_usize("stat_fields")?,
            buckets: j
                .req_arr("buckets")?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect(),
            reduce_fan: j.req_usize("reduce_fan")?,
            chunk_bytes: j.req_usize("chunk_bytes")?,
            sa_len: opt("sa_len", d.sa_len),
            sa_window: opt("sa_window", d.sa_window),
            sa_bins: opt("sa_bins", d.sa_bins),
            sa_rounds: opt("sa_rounds", d.sa_rounds),
            ssag_len: opt("ssag_len", d.ssag_len),
            ssag_b: opt("ssag_b", d.ssag_b),
            ssag_points: opt("ssag_points", d.ssag_points),
        })
    }

    /// Smallest compiled bucket that fits `units` samples, or None if the
    /// task must be split first.
    pub fn bucket_for(&self, units: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| units <= b)
    }

    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().expect("buckets non-empty")
    }

    /// Bytes of one Netflix movie sample in the data layer
    /// (vals + months + mask, f32 each).
    pub fn movie_bytes(&self) -> usize {
        self.ratings_cap * 3 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_chunk_bytes_consistent() {
        let p = ModelParams::default();
        assert_eq!(
            p.chunk_bytes,
            p.markers * p.individuals * 4 + p.markers * 4
        );
    }

    #[test]
    fn bucket_for_boundaries() {
        let p = ModelParams::default();
        assert_eq!(p.bucket_for(1), Some(1));
        assert_eq!(p.bucket_for(2), Some(4));
        assert_eq!(p.bucket_for(64), Some(64));
        assert_eq!(p.bucket_for(65), None);
        assert_eq!(p.max_bucket(), 64);
    }

    #[test]
    fn parses_from_json() {
        let p = ModelParams::default();
        let text = format!(
            r#"{{"markers":{},"individuals":{},"subsample":{},"rounds":{},
              "grid":{},"bandwidth":{},"ratings_cap":{},"months":{},
              "s_hi":{},"s_lo":{},"stat_fields":{},"buckets":[1,4,16,64],
              "reduce_fan":{},"chunk_bytes":{}}}"#,
            p.markers,
            p.individuals,
            p.subsample,
            p.rounds,
            p.grid,
            p.bandwidth,
            p.ratings_cap,
            p.months,
            p.s_hi,
            p.s_lo,
            p.stat_fields,
            p.reduce_fan,
            p.chunk_bytes
        );
        let j = Json::parse(&text).unwrap();
        assert_eq!(ModelParams::from_json(&j).unwrap(), p);
    }
}
