//! Synthetic series dataset for sequential-addressing subsampling
//! (Pan et al. 2021): each sample is one contiguous series of
//! `sa_len` points laid out in addressing order. The kernel draws
//! window start offsets and estimates the windowed mean per address
//! bin, so the generator bakes in a slow drift along the series —
//! different address bins genuinely see different means.

use super::block::{Block, BlockId, KIND_SEQADDR};
use super::params::ModelParams;
use super::{Dataset, SampleMeta, Workload};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SeqAddrConfig {
    pub series: usize,
    pub seed: u64,
}

impl Default for SeqAddrConfig {
    fn default() -> Self {
        SeqAddrConfig { series: 256, seed: 0x5E9A_DD60 }
    }
}

/// One series sample.
#[derive(Debug, Clone)]
pub struct Series {
    pub id: u64,
    pub points: Vec<f32>, // [sa_len]
}

#[derive(Debug, Clone)]
pub struct SeqAddrDataset {
    pub params: ModelParams,
    pub config: SeqAddrConfig,
    pub series: Vec<Series>,
    metas: Vec<SampleMeta>,
}

impl SeqAddrDataset {
    pub fn generate(params: &ModelParams, config: SeqAddrConfig) -> Self {
        let mut rng = Rng::new(config.seed);
        let len = params.sa_len;
        let mut series = Vec::with_capacity(config.series);
        for id in 0..config.series as u64 {
            let mut r = rng.fork(id);
            let base = 1.0 + 4.0 * r.f64();
            // drift across the address space plus AR(1) noise
            let drift = r.normal_ms(0.0, 2.0);
            let rho = 0.6 + 0.3 * r.f64();
            let mut prev = 0.0f64;
            let mut points = Vec::with_capacity(len);
            for t in 0..len {
                let frac = t as f64 / len.max(1) as f64;
                prev = rho * prev + r.normal_ms(0.0, 0.5);
                points.push((base + drift * frac + prev) as f32);
            }
            series.push(Series { id, points });
        }
        let bytes = len * 4;
        let metas = series
            .iter()
            .map(|s| SampleMeta { id: s.id, bytes, units: 1 })
            .collect();
        SeqAddrDataset { params: params.clone(), config, series, metas }
    }

    /// Scale by appending series (job-size sweeps).
    pub fn scaled_to(&self, target_bytes: usize) -> SeqAddrDataset {
        let need = target_bytes.div_ceil(self.params.sa_len * 4);
        if need <= self.series.len() {
            return self.clone();
        }
        let config =
            SeqAddrConfig { series: need, seed: self.config.seed };
        SeqAddrDataset::generate(&self.params, config)
    }

    pub fn sample(&self, id: u64) -> Option<&Series> {
        self.series.get(id as usize).filter(|s| s.id == id)
    }
}

impl Dataset for SeqAddrDataset {
    fn workload(&self) -> Workload {
        Workload::SeqAddr
    }

    fn metas(&self) -> &[SampleMeta] {
        &self.metas
    }

    fn encode_block(&self, id: u64) -> Block {
        let s = self.sample(id).expect("unknown series id");
        Block {
            id: BlockId { kind: KIND_SEQADDR, sample: id },
            units: 1,
            payload: s.points.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SeqAddrDataset {
        SeqAddrDataset::generate(
            &ModelParams::default(),
            SeqAddrConfig { series: 32, ..Default::default() },
        )
    }

    #[test]
    fn deterministic() {
        assert_eq!(small().series[7].points, small().series[7].points);
    }

    #[test]
    fn block_round_trip_and_meta_bytes() {
        let d = small();
        let b = d.encode_block(3);
        assert_eq!(Block::decode(&b.encode()).unwrap(), b);
        assert_eq!(b.payload.len(), d.params.sa_len);
        assert_eq!(b.payload.len() * 4, d.metas()[3].bytes);
        assert_eq!(b.units, 1);
    }

    #[test]
    fn scaled_to_is_prefix_stable() {
        let d = small();
        let s = d.scaled_to(d.total_bytes() * 4);
        assert!(s.series.len() >= d.series.len() * 4);
        assert_eq!(s.series[5].points, d.series[5].points);
    }

    #[test]
    fn drift_separates_address_bins() {
        // mean of the first window vs the last window must differ for
        // a healthy share of series, or the bins carry no signal
        let d = small();
        let w = d.params.sa_window;
        let differ = d
            .series
            .iter()
            .filter(|s| {
                let head: f32 =
                    s.points[..w].iter().sum::<f32>() / w as f32;
                let tail: f32 = s.points[s.points.len() - w..]
                    .iter()
                    .sum::<f32>()
                    / w as f32;
                (head - tail).abs() > 0.2
            })
            .count();
        assert!(differ > d.series.len() / 2, "differ={differ}");
    }
}
