//! Data layer: sample model, synthetic generators for both subsampling
//! workloads, and the block wire format stored in the distributed
//! in-memory store (`dfs`).
//!
//! Terminology follows the thesis (§3.1): input data is grouped by a
//! unique key into **samples** (an EAGLET *family*, a Netflix *movie*);
//! a **task** processes `task size` worth of samples in one software-
//! component invocation. EAGLET samples are measured in fixed-size
//! *chunks* (see python/compile/shapes.py) so heavy-tailed families —
//! including the paper's 15× and 7× outliers — are representable under
//! shape-static compiled artifacts.

pub mod block;
pub mod eaglet;
pub mod netflix;
pub mod params;
pub mod seqaddr;
pub mod ssag;

pub use block::{Block, BlockId};
pub use params::ModelParams;

/// Which subsampling workload a dataset/job belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Workload {
    Eaglet,
    /// Netflix with the high-confidence subsample size (S_HI).
    NetflixHi,
    /// Netflix with the low-confidence subsample size (S_LO).
    NetflixLo,
    /// Sequential-addressing subsampling under a memory constraint
    /// (Pan et al. 2021): windowed means over contiguous series
    /// offsets, binned by start address.
    SeqAddr,
    /// Scalable-subsampling aggregation (Politis 2021): block-means
    /// variance curve over a ladder of subsample block sizes.
    Ssag,
}

impl Workload {
    pub const ALL: [Workload; 5] = [
        Workload::Eaglet,
        Workload::NetflixHi,
        Workload::NetflixLo,
        Workload::SeqAddr,
        Workload::Ssag,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Workload::Eaglet => "eaglet",
            Workload::NetflixHi => "netflix_hi",
            Workload::NetflixLo => "netflix_lo",
            Workload::SeqAddr => "seqaddr",
            Workload::Ssag => "ssag",
        }
    }

    pub fn parse(s: &str) -> Option<Workload> {
        match s {
            "eaglet" => Some(Workload::Eaglet),
            "netflix_hi" | "netflix-hi" => Some(Workload::NetflixHi),
            "netflix_lo" | "netflix-lo" => Some(Workload::NetflixLo),
            "seqaddr" => Some(Workload::SeqAddr),
            "ssag" => Some(Workload::Ssag),
            _ => None,
        }
    }
}

/// Size/identity metadata for one sample — all the scheduler and the
/// kneepoint packer ever need (payloads stay in the data layer).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SampleMeta {
    pub id: u64,
    /// Payload size in bytes, as stored in the dfs block.
    pub bytes: usize,
    /// Compiled-shape units this sample occupies in a map batch
    /// (EAGLET: chunks; Netflix: always 1 movie row).
    pub units: u32,
}

/// A dataset the coordinator can run a job over.
pub trait Dataset: Send + Sync {
    fn workload(&self) -> Workload;
    fn metas(&self) -> &[SampleMeta];
    /// Encode sample `id` into its dfs block payload.
    fn encode_block(&self, id: u64) -> Block;
    fn total_bytes(&self) -> usize {
        self.metas().iter().map(|m| m.bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_name_round_trip() {
        for w in Workload::ALL {
            assert_eq!(Workload::parse(w.name()), Some(w));
        }
        assert_eq!(Workload::parse("hadoop"), None);
    }
}
