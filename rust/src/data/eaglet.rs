//! Synthetic EAGLET dataset: family-linkage samples with heavy-tailed
//! sizes and preserved outliers.
//!
//! Stands in for the thesis's bi-polar SNP study data (230 MB, 400
//! families, ~4000 individuals; one sample 15× the mean size and another
//! 7×; outlier tasks run 50× the mean). Per DESIGN.md §2 we preserve the
//! properties the platform actually reacts to: the sample-size
//! distribution, the outliers, random marker access in subsampling, and
//! the ×30-recompute job structure. Scaled datasets append statistically
//! similar synthetic families, exactly as §4.1.1.1 describes.

use super::block::{Block, BlockId, KIND_EAGLET};
use super::params::ModelParams;
use super::{Dataset, SampleMeta, Workload};
use crate::util::rng::Rng;

/// Shape of the family-size distribution (chunks per family).
#[derive(Debug, Clone)]
pub struct EagletConfig {
    pub families: usize,
    pub seed: u64,
    /// Pareto tail exponent for chunk counts (lower = heavier tail).
    pub tail_alpha: f64,
    /// Mean chunks/family before outliers.
    pub mean_chunks: f64,
    /// Inject the paper's 15× and 7× outlier samples.
    pub outliers: bool,
}

impl Default for EagletConfig {
    fn default() -> Self {
        EagletConfig {
            families: 400, // the original bi-polar study size
            seed: 0xEA61E7,
            tail_alpha: 2.6,
            mean_chunks: 2.0,
            outliers: true,
        }
    }
}

/// One family sample: `chunks` fixed-size chunk rows of genotype data.
#[derive(Debug, Clone)]
pub struct Family {
    pub id: u64,
    pub chunks: u32,
    /// geno, per chunk: markers × individuals f32
    pub geno: Vec<f32>,
    /// pos, per chunk: markers f32 in [0,1), sorted within a chunk
    pub pos: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct EagletDataset {
    pub params: ModelParams,
    pub config: EagletConfig,
    pub families: Vec<Family>,
    metas: Vec<SampleMeta>,
}

impl EagletDataset {
    pub fn generate(params: &ModelParams, config: EagletConfig) -> Self {
        let mut rng = Rng::new(config.seed);
        let mut families = Vec::with_capacity(config.families);
        for id in 0..config.families as u64 {
            let chunks = Self::draw_chunks(&mut rng, &config, id);
            families.push(Self::gen_family(
                params,
                &mut rng.fork(id),
                id,
                chunks,
            ));
        }
        let metas = families
            .iter()
            .map(|f| SampleMeta {
                id: f.id,
                bytes: f.chunks as usize * params.chunk_bytes,
                units: f.chunks,
            })
            .collect();
        EagletDataset { params: params.clone(), config, families, metas }
    }

    fn draw_chunks(rng: &mut Rng, config: &EagletConfig, id: u64) -> u32 {
        if config.outliers && id == 0 {
            return (15.0 * config.mean_chunks).round() as u32; // the 15× sample
        }
        if config.outliers && id == 1 {
            return (7.0 * config.mean_chunks).round() as u32; // the 7× sample
        }
        // Pareto-shaped tail shifted to the configured mean:
        // chunks = round(mean * pareto(alpha) / E[pareto]) clamped >= 1.
        let e_pareto = config.tail_alpha / (config.tail_alpha - 1.0);
        let x = config.mean_chunks * rng.pareto(config.tail_alpha) / e_pareto;
        (x.round() as u32).max(1)
    }

    fn gen_family(
        params: &ModelParams,
        rng: &mut Rng,
        id: u64,
        chunks: u32,
    ) -> Family {
        let m = params.markers;
        let i = params.individuals;
        let mut geno = Vec::with_capacity(chunks as usize * m * i);
        let mut pos = Vec::with_capacity(chunks as usize * m);
        for c in 0..chunks as usize {
            // Markers laid out along the genome segment [c, c+1)/chunks,
            // sorted (real SNP maps are ordered positions).
            let lo = c as f32 / chunks as f32;
            let hi = (c as f32 + 1.0) / chunks as f32;
            let mut p: Vec<f32> =
                (0..m).map(|_| lo + rng.f32() * (hi - lo)).collect();
            p.sort_by(|a, b| a.partial_cmp(b).unwrap());
            pos.extend_from_slice(&p);
            // Genotype scores: per-marker family effect + individual noise
            // (creates markers whose m^2/v score is informative).
            for _ in 0..m {
                let effect = rng.normal_ms(0.0, 1.0);
                for _ in 0..i {
                    geno.push((effect + rng.normal_ms(0.0, 0.6)) as f32);
                }
            }
        }
        Family { id, chunks, geno, pos }
    }

    /// Scale the dataset by appending synthetic families until it reaches
    /// roughly `target_bytes` (paper §4.1.1.1: simulated data statistically
    /// similar to the original; outliers preserved from the base set).
    pub fn scaled_to(&self, target_bytes: usize) -> EagletDataset {
        let mut out = self.clone();
        let mut rng = Rng::new(self.config.seed ^ 0x5ca1ab1e);
        let mut next_id = self.families.len() as u64;
        while out.total_bytes() < target_bytes {
            let chunks = Self::draw_chunks(
                &mut rng,
                &EagletConfig { outliers: false, ..self.config.clone() },
                next_id,
            );
            let fam = Self::gen_family(
                &self.params,
                &mut rng.fork(next_id),
                next_id,
                chunks,
            );
            out.metas.push(SampleMeta {
                id: fam.id,
                bytes: fam.chunks as usize * self.params.chunk_bytes,
                units: fam.chunks,
            });
            out.families.push(fam);
            next_id += 1;
        }
        out
    }

    /// Remove the outlier samples (the Fig-4 "no outliers" arm).
    pub fn without_outliers(&self) -> EagletDataset {
        let mean_units = self.metas.iter().map(|m| m.units as f64).sum::<f64>()
            / self.metas.len() as f64;
        let keep: Vec<bool> = self
            .metas
            .iter()
            .map(|m| (m.units as f64) <= 4.0 * mean_units)
            .collect();
        let mut out = self.clone();
        out.families = self
            .families
            .iter()
            .zip(&keep)
            .filter(|(_, k)| **k)
            .map(|(f, _)| f.clone())
            .collect();
        out.metas = self
            .metas
            .iter()
            .zip(&keep)
            .filter(|(_, k)| **k)
            .map(|(m, _)| m.clone())
            .collect();
        out
    }

    pub fn family(&self, id: u64) -> Option<&Family> {
        self.families.iter().find(|f| f.id == id)
    }
}

impl Dataset for EagletDataset {
    fn workload(&self) -> Workload {
        Workload::Eaglet
    }

    fn metas(&self) -> &[SampleMeta] {
        &self.metas
    }

    fn encode_block(&self, id: u64) -> Block {
        let f = self.family(id).expect("unknown family id");
        let m = self.params.markers;
        let i = self.params.individuals;
        let mut payload =
            Vec::with_capacity(f.chunks as usize * (m * i + m));
        for c in 0..f.chunks as usize {
            payload.extend_from_slice(&f.geno[c * m * i..(c + 1) * m * i]);
            payload.extend_from_slice(&f.pos[c * m..(c + 1) * m]);
        }
        Block {
            id: BlockId { kind: KIND_EAGLET, sample: id },
            units: f.chunks,
            payload,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> EagletDataset {
        EagletDataset::generate(
            &ModelParams::default(),
            EagletConfig { families: 60, ..Default::default() },
        )
    }

    #[test]
    fn deterministic_generation() {
        let a = small();
        let b = small();
        assert_eq!(a.families.len(), b.families.len());
        assert_eq!(a.families[5].geno, b.families[5].geno);
    }

    #[test]
    fn outliers_present_and_sized() {
        let d = small();
        let mean = d.metas.iter().skip(2).map(|m| m.units as f64).sum::<f64>()
            / (d.metas.len() - 2) as f64;
        assert!(
            d.metas[0].units as f64 > 5.0 * mean,
            "15x outlier missing: {} vs mean {mean}",
            d.metas[0].units
        );
        assert!(d.metas[1].units as f64 > 2.5 * mean);
    }

    #[test]
    fn without_outliers_drops_them() {
        let d = small();
        let no = d.without_outliers();
        assert!(no.families.len() >= d.families.len() - 2);
        let max_units = no.metas.iter().map(|m| m.units).max().unwrap();
        assert!(max_units < d.metas[0].units);
    }

    #[test]
    fn family_payload_dims_match_params() {
        let d = small();
        let p = &d.params;
        for f in &d.families {
            assert_eq!(f.geno.len(), f.chunks as usize * p.markers * p.individuals);
            assert_eq!(f.pos.len(), f.chunks as usize * p.markers);
        }
    }

    #[test]
    fn positions_sorted_within_chunks() {
        let d = small();
        let m = d.params.markers;
        let f = &d.families[3];
        for c in 0..f.chunks as usize {
            let seg = &f.pos[c * m..(c + 1) * m];
            assert!(seg.windows(2).all(|w| w[0] <= w[1]));
        }
    }

    #[test]
    fn block_round_trip() {
        let d = small();
        let b = d.encode_block(4);
        let back = Block::decode(&b.encode()).unwrap();
        assert_eq!(back, b);
        assert_eq!(
            b.payload.len() * 4,
            d.metas()[4].bytes,
            "block payload bytes should equal meta bytes"
        );
    }

    #[test]
    fn scaling_reaches_target() {
        let d = small();
        let target = d.total_bytes() * 3;
        let s = d.scaled_to(target);
        assert!(s.total_bytes() >= target);
        // base families (incl. outliers) preserved
        assert_eq!(s.families[0].geno, d.families[0].geno);
    }
}
